"""Fault tolerance demo: train -> simulate preemption -> resume exactly.

Shows the three pillars the large-scale posture depends on:
  1. step-granular async checkpoints with atomic publication
  2. bitwise-exact resume (same data order, same optimizer trajectory)
  3. elastic restore under a different sharding preset / mesh

    PYTHONPATH=src python examples/elastic_restart.py
"""
import dataclasses
import os
import shutil

import numpy as np

from repro import configs
from repro.config import TrainConfig
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop

OUT = "runs/elastic_demo"


def main():
    shutil.rmtree(OUT, ignore_errors=True)
    cfg = configs.get_smoke("gpt2_124m")
    tok = ByteTokenizer()
    base = TrainConfig(global_batch=4, seq_len=48, compute_dtype="float32",
                       total_steps=12, warmup_steps=0, learning_rate=1e-3,
                       schedule="constant", checkpoint_every=4,
                       attention_impl="streaming")
    ds = LMDataset(synthetic_wikitext(500), tok, base.seq_len)

    print("== reference: uninterrupted 12-step run")
    _, obs_ref = train_loop(cfg, base, out_dir=os.path.join(OUT, "ref"),
                            dataset=ds, print_fn=None)
    print(f"   final loss {obs_ref.rows[-1]['loss']:.6f}")

    print("== run A: 'preempted' after 8 steps (checkpoint at 4 and 8)")
    partial = dataclasses.replace(base, total_steps=8)
    train_loop(cfg, partial, out_dir=os.path.join(OUT, "work"), dataset=ds,
               print_fn=None)

    print("== run B: restart resumes from step 8 and finishes")
    _, obs_res = train_loop(cfg, base, out_dir=os.path.join(OUT, "work"),
                            dataset=ds, print_fn=None)
    print(f"   final loss {obs_res.rows[-1]['loss']:.6f}")
    match = np.isclose(obs_res.rows[-1]["loss"], obs_ref.rows[-1]["loss"],
                       rtol=1e-6)
    print(f"   resume == uninterrupted: {bool(match)}")
    assert match


if __name__ == "__main__":
    main()
