"""Batched serving example: prefill + greedy decode across model families.

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2_130m
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import TrainConfig
from repro.launch.serve import generate
from repro.models import registry
from repro.param import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=32)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 3,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = generate(params, prompts, cfg, tcfg, n_new=args.new_tokens)
    dt = time.time() - t0
    print(f"{cfg.name}: generated {toks.shape[0]}x{toks.shape[1]} tokens in "
          f"{dt:.2f}s ({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
