"""End-to-end driver: Full-FT of the REAL GPT2-124M on the WikiText-style LM
task (paper Fig 9 setting: seq 128, batch 8) for a few hundred steps.

This is the deliverable-(b) 100M-parameter training driver.  On the CPU
container a step takes seconds; pass --steps to trade time for fidelity.

    PYTHONPATH=src python examples/train_wikitext.py --steps 300
"""
import argparse

from repro import configs
from repro.config import TrainConfig
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-4)
    ap.add_argument("--out", default="runs/gpt2_wikitext")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config for CI-speed runs")
    args = ap.parse_args()

    cfg = configs.get_smoke("gpt2_124m") if args.smoke \
        else configs.get("gpt2_124m")
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq, learning_rate=args.lr,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        attention_impl="streaming", remat_policy="full", microbatches=1,
        compute_dtype="float32", checkpoint_every=max(args.steps // 4, 1),
    )
    tok = ByteTokenizer()
    dataset = LMDataset(synthetic_wikitext(6000), tok, tcfg.seq_len)
    state, obs = train_loop(cfg, tcfg, out_dir=args.out, dataset=dataset)
    import math
    l0, l1 = obs.rows[0]["loss"], obs.rows[-1]["loss"]
    print(f"\nFull-FT gpt2-124m: loss {l0:.3f} -> {l1:.3f} | "
          f"PPL {math.exp(l0):.1f} -> {math.exp(l1):.1f} | "
          f"peak RSS {obs.peak_rss_mb:.0f} MB | "
          f"energy {obs.energy_kj:.1f} kJ")


if __name__ == "__main__":
    main()
