"""Fine-tune with segment-wise state offload (paper §4.1.1, C1).

The phone realization of the paper's parameter-sharding optimization:
(param, m, v) live in memory-mapped segment files; the AdamW update streams
them through a 2-segment LRU window with double-buffered prefetch, so peak
resident optimizer state no longer scales with model size.  Compare the
reported peak window against the full state size printed at the end.

    PYTHONPATH=src python examples/offload_train.py
"""
from repro import configs
from repro.config import TrainConfig
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop


def main():
    cfg = configs.get_smoke("gpt2_124m")

    tcfg = TrainConfig(
        global_batch=8, seq_len=64, microbatches=2,
        attention_impl="streaming", remat_policy="full",
        learning_rate=3e-3, total_steps=20, warmup_steps=2,
        compute_dtype="float32",
        # C1 phone realization: page (p, m, v) out to 8 segment files,
        # keep a 2-segment LRU window resident, prefetch one ahead
        offload_segments=8, offload_resident=2,
    )

    tok = ByteTokenizer()
    dataset = LMDataset(synthetic_wikitext(800), tok, tcfg.seq_len)

    state, obs = train_loop(cfg, tcfg, out_dir="runs/offload_example",
                            dataset=dataset)
    ostate = state["offload"]
    s = ostate.stats()
    print(f"\nfinal loss {obs.rows[-1]['loss']:.4f} "
          f"(from {obs.rows[0]['loss']:.4f})")
    print(f"state on disk {s['store_bytes']/1e6:.2f} MB | peak resident "
          f"window {s['peak_resident_bytes']/1e6:.2f} MB | "
          f"prefetch hit rate "
          f"{s['prefetch_hits']}/{s['prefetch_hits'] + s['sync_loads']}")

    # full-depth variant: layer-streamed fwd/bwd — params page through the
    # window during compute too (segments become layer-aligned), and bf16
    # moments halve the m/v bytes.  Same loop API, one flag.
    import dataclasses
    scfg = dataclasses.replace(tcfg, offload_stream_params=True,
                               offload_moment_dtype="bfloat16",
                               remat_policy="none")
    state, obs = train_loop(cfg, scfg, out_dir="runs/offload_example_stream",
                            dataset=dataset)
    s = state["offload"].stats()
    print(f"\n[layer-streamed] final loss {obs.rows[-1]['loss']:.4f} | "
          f"state on disk {s['store_bytes']/1e6:.2f} MB | peak resident "
          f"param window {s['peak_resident_bytes']/1e6:.2f} MB")
    # the streamed step is an overlap pipeline by default: dirty segments
    # write back on a background thread (flush/snapshots stay barriers) and
    # block i+1 stages onto the device while block i computes.  Disable to
    # compare:  offload_async_writeback=False, offload_staging=False
    print(f"  async write-back blocked only "
          f"{s['t_write_block_s']*1e3:.0f} ms total "
          f"(background writer busy {s['writeback_busy_s']*1e3:.0f} ms)")

    # PEFT variant: LoRA over the streamed engine — the frozen base pages
    # through read-only param-only segments (no m/v, no write-back) while
    # the tiny adapter + its AdamW stay memory-resident; the bare adapter
    # lands in <out>/adapter.safetensors.
    lcfg = dataclasses.replace(scfg, lora_rank=8, lora_alpha=16.0,
                               offload_moment_dtype="float32")
    state, obs = train_loop(cfg, lcfg, out_dir="runs/offload_example_lora",
                            dataset=dataset)
    s = state["offload"].stats()
    print(f"\n[streamed LoRA r8] final loss {obs.rows[-1]['loss']:.4f} | "
          f"frozen base on disk {s['store_bytes']/1e6:.2f} MB (read-only) | "
          f"peak resident param window {s['peak_resident_bytes']/1e6:.2f} MB")

    # QLoRA variant: the frozen base segments are int8 per-channel quantized
    # and stay encoded in the window — the jitted per-block program
    # dequantizes on the fly, so flash AND resident bytes drop ~4x again.
    qcfg = dataclasses.replace(lcfg, base_quant="int8")
    state, obs = train_loop(cfg, qcfg, out_dir="runs/offload_example_qlora",
                            dataset=dataset)
    s = state["offload"].stats()
    print(f"\n[streamed QLoRA r8 int8] final loss {obs.rows[-1]['loss']:.4f}"
          f" | frozen base on disk {s['store_bytes']/1e6:.2f} MB int8 | "
          f"peak resident param window {s['peak_resident_bytes']/1e6:.2f} MB")


if __name__ == "__main__":
    main()
