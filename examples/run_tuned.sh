#!/usr/bin/env bash
# Tuned launch profile for phone-budget streamed training (README:
# "Tuned launch profile").  Wraps any command — default: a long-seq
# activation-offload smoke run — with the allocator + XLA environment
# from repro.launch.env:
#
#   bash examples/run_tuned.sh                                   # demo run
#   bash examples/run_tuned.sh python benchmarks/bench_memchain.py --quick
#
# tcmalloc only engages when a system copy exists (no install step); the
# profile degrades gracefully without it.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="${PYTHONPATH:+$PYTHONPATH:}src"

# one source of truth for the env overlay (tcmalloc LD_PRELOAD, large-alloc
# report threshold, XLA step markers, TF log silencing)
eval "$(python -m repro.launch.env --print)"

if [ "$#" -gt 0 ]; then
    exec "$@"
fi

exec python -m repro.launch.train \
    --arch gpt2_124m --smoke --steps 8 --batch 4 --seq 512 \
    --offload-stream-params --offload-activations --activation-codec bf16
