"""Quickstart: fine-tune a small LM on device-local data in ~40 lines.

Mirrors the paper's Listing-1 usage flow: DataLoader -> model -> optimizer ->
train() — realized with the repro public API.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro import configs
from repro.config import TrainConfig
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop


def main():
    # 1. the model (a reduced Qwen2.5 config — the paper's base model family)
    cfg = configs.get_smoke("qwen25_05b")

    # 2. the resource-aware runtime: ME attention (C4), activation
    #    checkpointing (C3), gradient accumulation (C2)
    tcfg = TrainConfig(
        global_batch=8, seq_len=64, microbatches=2,
        attention_impl="streaming", remat_policy="full",
        learning_rate=3e-3, total_steps=20, warmup_steps=2,
        compute_dtype="float32",
    )

    # 3. the data loader (local corpus; nothing leaves the machine)
    tok = ByteTokenizer()
    dataset = LMDataset(synthetic_wikitext(800), tok, tcfg.seq_len)

    # 4. train() — observer prints loss/PPL/RSS/energy per step
    state, obs = train_loop(cfg, tcfg, out_dir="runs/quickstart",
                            dataset=dataset)
    print(f"\nfinal loss {obs.rows[-1]['loss']:.4f} "
          f"(from {obs.rows[0]['loss']:.4f}) — dashboard at "
          f"runs/quickstart/dashboard.html")


if __name__ == "__main__":
    main()
