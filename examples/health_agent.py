"""Private campus health agent (paper §5 + §8 case study).

End-to-end on-device pipeline:
  1. per-user wearable statistics stream (synthetic; never leaves this process)
  2. template-based local QA construction (CHQA, 5 categories)
  3. LoRA fine-tune of a Qwen2.5-family model on the user's pairs
  4. before/after evaluation on held-out pairs (answer-token loss/acc as the
     stand-in for the paper's LLM-judge score)
  5. adapter export (safetensors) for subsequent agent inference

    PYTHONPATH=src python examples/health_agent.py --users 2 --steps 30
"""
import argparse
import os

import jax
import numpy as np

from repro import configs
from repro.checkpoint import save_safetensors
from repro.config import TrainConfig
from repro.core.step import make_eval_step
from repro.data.corpus import chqa_pairs
from repro.data.dataset import QADataset, packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop
from repro.param import flatten_names


def eval_loss(cfg, tcfg, state, dataset):
    ev = jax.jit(make_eval_step(cfg, tcfg))
    losses, accs = [], []
    for batch in packed_batches(dataset, tcfg.global_batch, epochs=1):
        batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        m = ev(state, batch)
        losses.append(float(m["loss"]))
        accs.append(float(m["accuracy"]))
    return float(np.mean(losses)), float(np.mean(accs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--users", type=int, default=2)
    ap.add_argument("--pairs", type=int, default=96)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--out", default="runs/health_agent")
    args = ap.parse_args()

    cfg = configs.get_smoke("qwen25_05b")  # paper: Qwen2.5-0.5B base
    tok = ByteTokenizer()
    tcfg = TrainConfig(global_batch=8, seq_len=96, lora_rank=8,
                       lora_alpha=16.0, learning_rate=1e-2,
                       total_steps=args.steps, warmup_steps=2,
                       compute_dtype="float32", attention_impl="streaming")

    for user in range(args.users):
        # local QA construction — raw records stay inside chqa_pairs()
        pairs = chqa_pairs(user, args.pairs)
        train_ds = QADataset(pairs[: int(len(pairs) * 0.8)], tok, tcfg.seq_len)
        test_ds = QADataset(pairs[int(len(pairs) * 0.8):], tok, tcfg.seq_len)

        from repro.core.step import init_state
        base_state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
        l_before, a_before = eval_loss(cfg, tcfg, base_state, test_ds)

        state, obs = train_loop(cfg, tcfg, out_dir=None, dataset=train_ds,
                                print_fn=None)
        l_after, a_after = eval_loss(cfg, tcfg, state, test_ds)

        # export the personalized adapter (stays on the phone)
        os.makedirs(args.out, exist_ok=True)
        adapter = {n: np.asarray(v) for n, v in flatten_names(state["lora"])}
        path = os.path.join(args.out, f"user{user}_adapter.safetensors")
        save_safetensors(path, adapter, metadata={"user": str(user),
                                                  "rank": "8"})
        print(f"user {user}: held-out answer loss {l_before:.3f} -> "
              f"{l_after:.3f} | acc {a_before:.3f} -> {a_after:.3f} | "
              f"adapter -> {path}")


if __name__ == "__main__":
    main()
