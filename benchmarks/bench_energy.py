"""Paper Fig 11: energy-aware computation scheduling trace.

K=1, mu=60%, rho=50% on a simulated battery: the per-step interval must
stretch from t to t/(1-rho) = 2t once the battery crosses the threshold
(paper: 0.081 h -> 0.164 h at step 53).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row
from repro.core.energy import EnergyGovernor, SimulatedBattery


def main(fast: bool = False):
    steps = 40 if fast else 120
    step_time = 0.081  # hours, as in the paper's trace (units arbitrary)
    drain = 45.0 / steps  # crosses the 60% threshold ~8/9 into the run
    gov = EnergyGovernor(check_every=1, threshold=0.60, reduction=0.50,
                         monitor=SimulatedBattery(level=100.0,
                                                  drain_per_unit=drain),
                         sleep_fn=lambda s: None)
    for step in range(steps):
        gov.after_step(step, step_time)
    hist = gov.history
    cross = next((h["step"] for h in hist if h["throttled"]), None)
    pre = np.mean([h["interval"] for h in hist if not h["throttled"]])
    post = np.mean([h["interval"] for h in hist if h["throttled"]])
    row("fig11_energy_schedule", 0.0,
        f"threshold crossed at step {cross}; interval {pre:.3f} -> "
        f"{post:.3f} (x{post/pre:.2f}; paper: 0.081 -> 0.164 = x2.02)")


if __name__ == "__main__":
    main()
