"""Paper Fig 9 + Tab 4/5: Full-FT and LoRA correctness trajectories.

Fig 9: Full-FT loss/PPL decreasing on the LM task (GPT2-family).
Tab 4: LoRA vs Full-FT final loss / accuracy / PPL + system metrics
       (time, energy model, peak RSS) on LM + QA tasks.
"""
from __future__ import annotations

import math


from benchmarks.common import row
from repro import configs
from repro.config import TrainConfig
from repro.data.corpus import chqa_pairs, synthetic_wikitext
from repro.data.dataset import LMDataset, QADataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop


def _tcfg(steps, **kw):
    base = dict(global_batch=8, seq_len=64, compute_dtype="float32",
                attention_impl="streaming", attn_chunk=32,
                total_steps=steps, warmup_steps=2, learning_rate=3e-3)
    base.update(kw)
    return TrainConfig(**base)


def _dataset(task, tok, seq):
    if task == "wikitext":
        return LMDataset(synthetic_wikitext(600), tok, seq)
    return QADataset(chqa_pairs(0, 128, seed=1), tok, seq)


def bench_fullft_fig9(steps: int = 20):
    """Fig 9 analogue: Full-FT on gpt2-smoke @ LM task."""
    cfg = configs.get_smoke("gpt2_124m")
    tok = ByteTokenizer()
    ds = _dataset("wikitext", tok, 64)
    tcfg = _tcfg(steps)
    state, obs = train_loop(cfg, tcfg, out_dir=None, dataset=ds,
                            print_fn=None)
    l0, l1 = obs.rows[0]["loss"], obs.rows[-1]["loss"]
    us = sum(r["step_time_s"] for r in obs.rows) / len(obs.rows) * 1e6
    row("fig9_fullft_gpt2_lm", us,
        f"loss {l0:.3f}->{l1:.3f} ppl {math.exp(l0):.1f}->{math.exp(l1):.1f}"
        f" decreasing={l1 < l0}")


def bench_lora_tab4(steps: int = 20):
    """Tab 4 analogue: LoRA vs Full-FT across models x tasks."""
    tok = ByteTokenizer()
    for arch in ("gpt2_124m", "qwen25_05b", "gemma3_270m"):
        cfg = configs.get_smoke(arch)
        for task in ("wikitext", "chqa"):
            ds = _dataset(task, tok, 64)
            for mode, rank in (("fullft", 0), ("lora", 8)):
                tcfg = _tcfg(steps, lora_rank=rank,
                             learning_rate=1e-2 if rank else 3e-3)
                state, obs = train_loop(cfg, tcfg, out_dir=None, dataset=ds,
                                        print_fn=None)
                l0, l1 = obs.rows[0]["loss"], obs.rows[-1]["loss"]
                acc = obs.rows[-1]["accuracy"]
                us = sum(r["step_time_s"] for r in obs.rows) / len(obs.rows) * 1e6
                row(f"tab4_{mode}_{arch}_{task}", us,
                    f"loss {l0:.3f}->{l1:.3f} acc {acc:.3f} "
                    f"peakRSS {obs.peak_rss_mb:.0f}MB "
                    f"energy {obs.energy_kj:.3f}kJ")


def main(fast: bool = False):
    steps = 8 if fast else 20
    bench_fullft_fig9(steps)
    bench_lora_tab4(steps)


if __name__ == "__main__":
    main()
