"""Serving-path benchmark (paper §3.3 inference support).

Two sections:

1. Per-family decode-step latency — batched greedy decode throughput of the
   raw jitted serve step across model families (the original rows).
2. Multi-adapter continuous-batching throughput — ``repro.serve.ServeEngine``
   tok/s as the number of *concurrent adapters* grows (1/4/16 requests, each
   with its own LoRA adapter, all in flight at once), for both bases:

     fp32_inmem    shared fp32 base held in memory
     int8_stream   frozen int8 base streamed through the read-only offload
                   window (the phone-sized deployment: base on flash,
                   adapters hot-swapped per user)

   Full runs write the grid to ``BENCH_serving.json`` (committed artifact).
   ``--quick`` is the CI smoke gate: both bases with 3 concurrent adapters,
   asserting tok/s > 0 and that batched multi-adapter decode is
   token-for-token identical to serving each request alone — a correctness
   gate on the continuous-batching path, not just a speed probe.

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick] [--json F]
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro import configs
from repro.config import TrainConfig
from repro.core.lora import lora_specs
from repro.core.step import make_serve_step
from repro.checkpoint.safetensors import save_adapter
from repro.models import registry
from repro.offload.state import LayerStreamedState
from repro.param import init_params
from repro.serve import AdapterCache, Request, ServeEngine, StreamedBase

_COMMITTED_JSON = "BENCH_serving.json"
RANK, ALPHA, TARGETS = 4, 16.0, ("wq", "wv")


def _decode_step_rows(fast: bool):
    """Section 1: raw serve-step latency per family (original bench)."""
    archs = ("qwen15_05b", "mamba2_130m") if fast else (
        "qwen15_05b", "mamba2_130m", "hymba_15b", "whisper_large_v3",
        "dbrx_132b")
    for arch in archs:
        cfg = configs.get_smoke(arch)
        tcfg = TrainConfig(compute_dtype="float32",
                           attention_impl="streaming", attn_chunk=16)
        params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
        b, max_len = 4, 40
        cache = init_params(jax.random.PRNGKey(1),
                            registry.cache_specs(cfg, b, max_len,
                                                 jnp.float32))
        serve = jax.jit(make_serve_step(cfg, tcfg))
        tok = jnp.ones((b, 1), jnp.int32)
        us = time_call(lambda: serve(params, cache, tok, jnp.int32(8))[0])
        row(f"serve_decode_{arch}", us,
            f"batch {b}; {b / (us/1e6):.0f} tok/s (smoke cfg, CPU)")


def _write_adapters(cfg, workdir: str, n: int, base_quant: str,
                    base_tag: str):
    """n distinct adapter.safetensors files, exercising the real on-disk
    load + validation path the engine serves from."""
    os.makedirs(workdir, exist_ok=True)
    specs = lora_specs(registry.param_specs(cfg), TARGETS, RANK)
    paths = []
    for i in range(n):
        lt = init_params(jax.random.PRNGKey(1000 + i), specs)
        lt = jax.tree.map(lambda a, i=i: a + 0.01 * (i + 1), lt)
        p = os.path.join(workdir, f"adapter_{i}.safetensors")
        save_adapter(p, lt, rank=RANK, alpha=ALPHA, targets=TARGETS,
                     base_quant=base_quant, base_tag=base_tag)
        paths.append(p)
    return paths


def _requests(paths, prompt_len: int, max_new: int):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    tokens=rng.integers(3, 200, prompt_len).tolist(),
                    max_new=max_new, adapter=p)
            for i, p in enumerate(paths)]


def _run_engine(cfg, tcfg, base, paths, reqs, *, slots, max_len, chunk):
    """(wall_s over run(), outputs, stats) — engine built fresh so compile
    happens inside, then timed over a fully warmed second run."""
    def build():
        ac = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                          base_quant=base.base_quant
                          if hasattr(base, "base_quant") else "",
                          capacity=max(2, len(paths)))
        return ServeEngine(cfg, tcfg, base, slots=slots, max_len=max_len,
                           chunk=chunk, adapters=ac)
    eng = build()
    for r in reqs:                           # warm: compiles + loads adapters
        eng.submit(Request(**vars(r)))
    eng.run()
    eng2 = build()
    for r in reqs:
        eng2.submit(Request(**vars(r)))
    t0 = time.perf_counter()
    out = eng2.run()
    wall = time.perf_counter() - t0
    return wall, out, eng2.stats()


def _engine_grid(fast: bool, results: dict):
    """Section 2: ServeEngine tok/s vs concurrent adapters, both bases."""
    arch = "qwen15_05b"
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    prompt_len, max_new, chunk = (8, 6, 8) if fast else (16, 16, 8)
    counts = (3,) if fast else (1, 4, 16)
    max_len = prompt_len + max_new + 1
    results.update({"arch": arch, "prompt_len": prompt_len,
                    "max_new": max_new, "adapter_rank": RANK, "grid": []})

    with tempfile.TemporaryDirectory() as d:
        n_stores = [0]

        def int8_base():
            # each StreamedBase owns (and closes) its own frozen store
            n_stores[0] += 1
            return StreamedBase(LayerStreamedState.create_frozen(
                params, os.path.join(d, f"int8_base_{n_stores[0]}"),
                max_resident=2, quant="int8", base_tag="bench"))

        bases = {"fp32_inmem": (lambda: params, ""),
                 "int8_stream": (int8_base, "int8")}
        for bname, (mk, quant) in bases.items():
            apaths = _write_adapters(cfg, os.path.join(d, f"ad_{bname}"),
                                     max(counts), quant, "")
            for n in counts:
                reqs = _requests(apaths[:n], prompt_len, max_new)
                base = mk()
                wall, out, st = _run_engine(
                    cfg, tcfg, base, apaths[:n], reqs,
                    slots=n, max_len=max_len, chunk=chunk)
                if hasattr(base, "close"):
                    base.close()
                toks = sum(len(v) for v in out.values())
                tps = toks / max(wall, 1e-9)
                results["grid"].append(
                    {"base": bname, "adapters": n, "wall_s": wall,
                     "new_tokens": toks, "tokens_per_s": tps,
                     "decode_steps": st["decode_steps"],
                     "prefill_chunks": st["prefill_chunks"]})
                row(f"serve_engine_{bname}_a{n}", wall * 1e6,
                    f"{n} adapters in flight; {tps:.0f} tok/s (smoke cfg)")
                if fast:
                    # CI gate: batched multi-adapter == each request alone
                    assert tps > 0, f"{bname}: no serving throughput"
                    for r in reqs:
                        solo_base = mk()
                        s_eng = ServeEngine(
                            cfg, tcfg, solo_base, slots=1, max_len=max_len,
                            chunk=chunk,
                            adapters=AdapterCache(
                                cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                                base_quant=quant, capacity=2))
                        s_eng.submit(Request(**vars(r)))
                        ref = s_eng.run()[r.rid]
                        s_eng.close()
                        assert np.array_equal(out[r.rid], ref), (
                            f"{bname}: batched decode diverged from the "
                            f"isolated run for request {r.rid}")
                    row(f"serve_gate_{bname}", 0.0,
                        f"ok: batched == isolated for all {n} adapters, "
                        f"{tps:.0f} tok/s > 0")


def main(fast: bool = False, out_json: str = _COMMITTED_JSON):
    _decode_step_rows(fast)
    results: dict = {}
    _engine_grid(fast, results)
    if fast and out_json == _COMMITTED_JSON:
        # quick-mode numbers must never clobber the committed artifact
        out_json = None
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        row("serving_json", 0.0, out_json)


def main_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="CI smoke: both bases, 3 concurrent adapters, "
                         "batched == isolated correctness gate")
    ap.add_argument("--json", default=_COMMITTED_JSON,
                    help="results JSON path (--quick skips the default so "
                         "the committed artifact is never clobbered)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.quick, out_json=args.json)


if __name__ == "__main__":
    main_cli()
