"""Serving-path benchmark (paper §3.3 inference support): batched greedy
decode throughput per family + decode == teacher-forcing exactness."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro import configs
from repro.config import TrainConfig
from repro.core.step import make_serve_step
from repro.models import registry
from repro.param import init_params


def main(fast: bool = False):
    archs = ("qwen15_05b", "mamba2_130m") if fast else (
        "qwen15_05b", "mamba2_130m", "hymba_15b", "whisper_large_v3",
        "dbrx_132b")
    for arch in archs:
        cfg = configs.get_smoke(arch)
        tcfg = TrainConfig(compute_dtype="float32",
                           attention_impl="streaming", attn_chunk=16)
        params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
        b, max_len = 4, 40
        cache = init_params(jax.random.PRNGKey(1),
                            registry.cache_specs(cfg, b, max_len,
                                                 jnp.float32))
        serve = jax.jit(make_serve_step(cfg, tcfg))
        tok = jnp.ones((b, 1), jnp.int32)
        us = time_call(lambda: serve(params, cache, tok, jnp.int32(8))[0])
        row(f"serve_decode_{arch}", us,
            f"batch {b}; {b / (us/1e6):.0f} tok/s (smoke cfg, CPU)")


if __name__ == "__main__":
    main()
