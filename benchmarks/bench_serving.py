"""Serving-path benchmark (paper §3.3 inference support).

Three sections:

1. Per-family decode-step latency — batched greedy decode throughput of the
   raw jitted serve step across model families (the original rows).
2. Multi-adapter continuous-batching throughput — ``repro.serve.ServeEngine``
   tok/s as the number of *concurrent adapters* grows (1/4/16 requests, each
   with its own LoRA adapter, all in flight at once), for three bases:

     fp32_inmem        shared fp32 base held in memory (the ceiling)
     int8_stream_sync  frozen int8 base streamed through the read-only
                       offload window with the pre-staging decode
                       discipline: synchronous h2d (staging=False), the
                       head segment re-pulled every step, and a per-step
                       host token sync (defer_tokens=False)
     int8_stream       same store with the full decode-side pipeline:
                       block i+1 staged host->device behind block i's
                       compute, head tree staged once per run, argmax
                       deferred on device until reap

   Every row reports end-to-end tok/s AND decode-only tok/s (the engine
   splits prefill and decode wall-clock; end-to-end folds prefill into the
   denominator and hides decode-side wins), plus the base provider's
   pipeline stats (prefetch-hit rate, staging/h2d time) measured over the
   timed run.
3. Paged-KV admission — at a fixed page budget, how many mixed-length
   requests run concurrently vs the dense worst-case slot count the same
   bytes would buy (full runs; recorded in the JSON).

Full runs write everything to ``BENCH_serving.json`` (committed artifact).
``--quick`` is the CI smoke + regression gate: all three bases with 3
concurrent adapters, asserting

  - batched multi-adapter decode == each request served alone (both bases)
  - the staged walk's tokens == the sync walk's tokens (staging moves
    work, never changes it)
  - staged decode tok/s >= sync decode tok/s, and >= the committed sync
    row's decode tok/s (the staging win must not silently evaporate)
  - the int8-streamed/in-memory decode ratio is within 0.1 of the
    committed ratio (mirrors the stream-throughput overlap gate)

    PYTHONPATH=src python -m benchmarks.bench_serving [--quick] [--json F]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro import configs
from repro.config import TrainConfig
from repro.core.lora import lora_specs
from repro.core.step import make_serve_step
from repro.checkpoint.safetensors import save_adapter
from repro.models import registry
from repro.offload.state import LayerStreamedState
from repro.param import init_params
from repro.serve import AdapterCache, Request, ServeEngine, StreamedBase

_COMMITTED_JSON = "BENCH_serving.json"
RANK, ALPHA, TARGETS = 4, 16.0, ("wq", "wv")


def _decode_step_rows(fast: bool):
    """Section 1: raw serve-step latency per family (original bench)."""
    archs = ("qwen15_05b", "mamba2_130m") if fast else (
        "qwen15_05b", "mamba2_130m", "hymba_15b", "whisper_large_v3",
        "dbrx_132b")
    for arch in archs:
        cfg = configs.get_smoke(arch)
        tcfg = TrainConfig(compute_dtype="float32",
                           attention_impl="streaming", attn_chunk=16)
        params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
        b, max_len = 4, 40
        cache = init_params(jax.random.PRNGKey(1),
                            registry.cache_specs(cfg, b, max_len,
                                                 jnp.float32))
        serve = jax.jit(make_serve_step(cfg, tcfg))
        tok = jnp.ones((b, 1), jnp.int32)
        us = time_call(lambda: serve(params, cache, tok, jnp.int32(8))[0])
        row(f"serve_decode_{arch}", us,
            f"batch {b}; {b / (us/1e6):.0f} tok/s (smoke cfg, CPU)")


def _write_adapters(cfg, workdir: str, n: int, base_quant: str,
                    base_tag: str):
    """n distinct adapter.safetensors files, exercising the real on-disk
    load + validation path the engine serves from."""
    os.makedirs(workdir, exist_ok=True)
    specs = lora_specs(registry.param_specs(cfg), TARGETS, RANK)
    paths = []
    for i in range(n):
        lt = init_params(jax.random.PRNGKey(1000 + i), specs)
        lt = jax.tree.map(lambda a, i=i: a + 0.01 * (i + 1), lt)
        p = os.path.join(workdir, f"adapter_{i}.safetensors")
        save_adapter(p, lt, rank=RANK, alpha=ALPHA, targets=TARGETS,
                     base_quant=base_quant, base_tag=base_tag)
        paths.append(p)
    return paths


def _requests(paths, prompt_len: int, max_new: int):
    rng = np.random.default_rng(7)
    return [Request(rid=i,
                    tokens=rng.integers(3, 200, prompt_len).tolist(),
                    max_new=max_new, adapter=p)
            for i, p in enumerate(paths)]


def _base_stats_delta(base, before):
    """Numeric base-provider stats accrued over the timed run (the warm run
    also touched the window, so absolutes would be misleading)."""
    after = base.stats()
    d = {k: (v - before.get(k, 0)) for k, v in after.items()
         if isinstance(v, (int, float))}
    hits, loads = d.get("prefetch_hits", 0), d.get("sync_loads", 0)
    d["prefetch_hit_rate"] = hits / (hits + loads) if (hits + loads) else 1.0
    return d


def _run_engine(cfg, tcfg, base, paths, reqs, *, slots, max_len, chunk,
                defer=True):
    """(wall_s over run(), outputs, engine stats, base stats over the timed
    run) — engine built fresh so compile happens inside, then timed over a
    fully warmed second run."""
    def build():
        ac = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                          base_quant=base.base_quant
                          if hasattr(base, "base_quant") else "",
                          capacity=max(2, len(paths)))
        return ServeEngine(cfg, tcfg, base, slots=slots, max_len=max_len,
                           chunk=chunk, adapters=ac, defer_tokens=defer)
    eng = build()
    for r in reqs:                           # warm: compiles + loads adapters
        eng.submit(Request(**vars(r)))
    eng.run()
    eng2 = build()
    for r in reqs:
        eng2.submit(Request(**vars(r)))
    b0 = eng2.base.stats()
    t0 = time.perf_counter()
    out = eng2.run()
    wall = time.perf_counter() - t0
    return wall, out, eng2.stats(), _base_stats_delta(eng2.base, b0)


def _engine_grid(fast: bool, results: dict):
    """Section 2: ServeEngine tok/s vs concurrent adapters, three bases."""
    arch = "qwen15_05b"
    # phone-shaped blocks with a paper-real untied vocabulary (GPT-2's
    # 50257) at reduced depth — the head segment and per-block streams are
    # the sizes the pipeline has to hide; depth only repeats the steady
    # state (same sizing idea as bench_stream_throughput)
    cfg = dataclasses.replace(configs.get_smoke(arch), d_model=512,
                              n_heads=8, n_kv_heads=8, head_dim=64,
                              d_ff=2048, n_layers=2, vocab_size=50257,
                              max_seq_len=64, tie_embeddings=False)
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    prompt_len, max_new, chunk = (8, 6, 8) if fast else (16, 16, 8)
    counts = (3,) if fast else (1, 4, 16)
    max_len = prompt_len + max_new + 1
    results.update({"arch": arch, "d_model": cfg.d_model,
                    "n_layers": cfg.n_layers, "vocab_size": cfg.vocab_size,
                    "prompt_len": prompt_len, "max_new": max_new,
                    "adapter_rank": RANK, "grid": []})
    decode_tps: dict = {}            # (base, n) -> decode tok/s
    outputs: dict = {}               # (base, n) -> outputs

    with tempfile.TemporaryDirectory() as d:
        n_stores = [0]

        def int8_base(staging=True):
            # each StreamedBase owns (and closes) its own frozen store;
            # the segment read transport comes from $REPRO_OFFLOAD_IO
            # (the tuned launcher exports the probed raw backend)
            n_stores[0] += 1
            base = StreamedBase(LayerStreamedState.create_frozen(
                params, os.path.join(d, f"int8_base_{n_stores[0]}"),
                max_resident=2, quant="int8", base_tag="bench"),
                staging=staging)
            if n_stores[0] == 1:
                results["io_backend"] = base.lstate.store.io_backend
                row("serve_io_backend", 0.0,
                    f"streamed-base segment reads via "
                    f"{base.lstate.store.io_backend}")
            return base

        # (factory, adapter base_quant, defer_tokens): the sync row runs
        # the whole pre-staging discipline, not just synchronous h2d
        bases = {"fp32_inmem": (lambda: params, "", True),
                 "int8_stream_sync": (lambda: int8_base(False), "int8",
                                      False),
                 "int8_stream": (int8_base, "int8", True)}
        for bname, (mk, quant, defer) in bases.items():
            apaths = _write_adapters(cfg, os.path.join(d, f"ad_{bname}"),
                                     max(counts), quant, "")
            for n in counts:
                reqs = _requests(apaths[:n], prompt_len, max_new)
                base = mk()
                wall, out, st, bd = _run_engine(
                    cfg, tcfg, base, apaths[:n], reqs,
                    slots=n, max_len=max_len, chunk=chunk, defer=defer)
                if hasattr(base, "close"):
                    base.close()
                toks = sum(len(v) for v in out.values())
                tps = toks / max(wall, 1e-9)
                dtps = st["decoded_tokens"] / max(st["decode_wall_s"], 1e-9)
                decode_tps[(bname, n)] = dtps
                outputs[(bname, n)] = out
                results["grid"].append(
                    {"base": bname, "adapters": n, "wall_s": wall,
                     "new_tokens": toks, "tokens_per_s": tps,
                     "decode_tok_s": dtps,
                     "decode_wall_s": st["decode_wall_s"],
                     "prefill_wall_s": st["prefill_wall_s"],
                     "decode_steps": st["decode_steps"],
                     "prefill_chunks": st["prefill_chunks"],
                     "base_stats": bd})
                row(f"serve_engine_{bname}_a{n}", wall * 1e6,
                    f"{n} adapters in flight; {tps:.0f} tok/s e2e, "
                    f"{dtps:.0f} tok/s decode (phone-shaped cfg)")
                if fast and bname != "int8_stream_sync":
                    # CI gate: batched multi-adapter == each request alone
                    # (the sync row is instead gated against the staged row
                    # token-for-token below)
                    assert tps > 0, f"{bname}: no serving throughput"
                    for r in reqs:
                        solo_base = mk()
                        s_eng = ServeEngine(
                            cfg, tcfg, solo_base, slots=1, max_len=max_len,
                            chunk=chunk,
                            adapters=AdapterCache(
                                cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                                base_quant=quant, capacity=2))
                        s_eng.submit(Request(**vars(r)))
                        ref = s_eng.run()[r.rid]
                        s_eng.close()
                        assert np.array_equal(out[r.rid], ref), (
                            f"{bname}: batched decode diverged from the "
                            f"isolated run for request {r.rid}")
                    row(f"serve_gate_{bname}", 0.0,
                        f"ok: batched == isolated for all {n} adapters, "
                        f"{tps:.0f} tok/s > 0")

    for n in counts:
        sp = decode_tps[("int8_stream", n)] / \
            max(decode_tps[("int8_stream_sync", n)], 1e-9)
        results.setdefault("staged_vs_sync_decode", {})[str(n)] = sp
        row(f"serve_staging_speedup_a{n}", 0.0,
            f"staged decode x{sp:.2f} vs sync int8-streamed walk")

    if fast:
        _quick_gates(results, counts[0], decode_tps, outputs)


def _quick_gates(results, n, decode_tps, outputs):
    """CI regression gates over the in-run rows + the committed JSON
    (mirrors bench_stream_throughput's overlap gate)."""
    staged, sync = (decode_tps[("int8_stream", n)],
                    decode_tps[("int8_stream_sync", n)])
    fp32 = decode_tps[("fp32_inmem", n)]
    out_staged, out_sync = (outputs[("int8_stream", n)],
                            outputs[("int8_stream_sync", n)])
    for rid, toks in out_staged.items():
        assert np.array_equal(toks, out_sync[rid]), (
            f"staged and sync streamed walks diverged for request {rid}")
    assert staged >= sync, (
        f"staged int8-streamed decode {staged:.0f} tok/s is SLOWER than the "
        f"sync walk {sync:.0f} tok/s — staging is costing more than it "
        "hides")
    floor, ratio_floor = 0.0, 0.0
    committed = os.path.join(os.path.dirname(__file__), "..",
                             _COMMITTED_JSON)
    if os.path.exists(committed):
        with open(committed) as f:
            ref = json.load(f)
        rows = {(g["base"], g["adapters"]): g for g in ref.get("grid", [])
                if "decode_tok_s" in g}
        if rows:
            # the committed grid's *smallest* adapter count is the
            # conservative reference: decode tok/s and the streamed/inmem
            # ratio both improve with batch, and the quick config runs 3
            # rows vs the committed minimum of 1
            nmin = min(a for _, a in rows)
            if ("int8_stream_sync", nmin) in rows:
                floor = rows[("int8_stream_sync", nmin)]["decode_tok_s"]
            if ("int8_stream", nmin) in rows and \
                    ("fp32_inmem", nmin) in rows:
                ratio_floor = (
                    rows[("int8_stream", nmin)]["decode_tok_s"]
                    / max(rows[("fp32_inmem", nmin)]["decode_tok_s"], 1e-9)
                    - 0.1)
    assert staged >= floor, (
        f"staged int8-streamed decode {staged:.0f} tok/s < committed sync "
        f"value {floor:.0f} tok/s — the staging win evaporated")
    ratio = staged / max(fp32, 1e-9)
    assert ratio >= ratio_floor, (
        f"int8-streamed/in-memory decode ratio {ratio:.2f} < "
        f"{ratio_floor:.2f} (committed ratio minus 0.1 slack) — the "
        "streamed serving path regressed vs the in-memory ceiling")
    row("serve_perf_gate", 0.0,
        f"ok: staged {staged:.0f} >= sync {sync:.0f} and committed "
        f"{floor:.0f} tok/s; stream/inmem {ratio:.2f} >= {ratio_floor:.2f}")


def _paged_admission(results: dict):
    """Section 3: concurrency at a fixed page budget, mixed-length traffic.

    The dense worst-case cache would spend the same bytes on
    budget / ceil(max_len / page_size) slots; the paged pool lets short
    requests pack, so more run concurrently.
    """
    cfg = configs.get_smoke("qwen15_05b")
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    page_size, max_len = 16, 48
    width = -(-max_len // page_size)             # 3 pages worst case
    dense_slots = 4
    budget = dense_slots * width                 # 12 pages = 4 dense slots
    # mixed traffic: alternating short (1 page) and long (2 page) requests
    reqs = []
    for i in range(16):
        if i % 2 == 0:
            reqs.append(Request(rid=i, tokens=list(range(3, 11)), max_new=8))
        else:
            reqs.append(Request(rid=i, tokens=list(range(3, 19)),
                                max_new=16))
    eng = ServeEngine(cfg, tcfg, params, slots=16, max_len=max_len,
                      chunk=8, page_size=page_size, pool_pages=budget)
    for r in reqs:
        eng.submit(r)
    out = eng.run()
    st = eng.stats()
    assert len(out) == 16 and st["completed"] == 16
    results["paged_admission"] = {
        "page_size": page_size, "max_len": max_len,
        "budget_pages": budget,
        "dense_equiv_slots": dense_slots,
        "paged_peak_active": st["peak_active"],
        "peak_pages_used": st["peak_pages_used"],
        "admission_waits": st["admission_waits"],
    }
    row("serve_paged_admission", 0.0,
        f"{st['peak_active']} concurrent mixed-length requests on a "
        f"{budget}-page budget (dense worst-case: {dense_slots} slots)")
    assert st["peak_active"] > dense_slots, (
        "paged KV should admit more concurrent requests than the "
        "dense-equivalent slot count at the same byte budget")


def main(fast: bool = False, out_json: str = _COMMITTED_JSON):
    _decode_step_rows(fast)
    results: dict = {}
    _engine_grid(fast, results)
    if not fast:
        _paged_admission(results)
    if fast and out_json == _COMMITTED_JSON:
        # quick-mode numbers must never clobber the committed artifact
        out_json = None
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        row("serving_json", 0.0, out_json)


def main_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="CI smoke: three bases, 3 concurrent adapters, "
                         "batched == isolated + staged-vs-sync + committed "
                         "regression gates")
    ap.add_argument("--json", default=_COMMITTED_JSON,
                    help="results JSON path (--quick skips the default so "
                         "the committed artifact is never clobbered)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.quick, out_json=args.json)


if __name__ == "__main__":
    main_cli()
