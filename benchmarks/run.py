"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows per the harness convention.

  bench_correctness  Fig 9 (Full-FT trajectory) + Tab 4 (LoRA vs Full-FT)
  bench_memchain     Fig 10 + Tab 6 (optimization-chain peak memory)
  bench_stream_throughput  streamed-trainer wall-clock + overlap breakdown
  bench_accum        Tab 7 (gradient-accumulation ablation)
  bench_attention    Tab 8 / §4.1.4 (ME attention vs naive)
  bench_energy       Fig 11 (energy-aware scheduling trace)
  bench_serving      §3.3 (batched decode across families)
  bench_kernels      Pallas kernels vs oracles (interpret mode)
  bench_roofline     §Roofline (reads the dry-run cache)
"""
import argparse
import sys
import traceback

from benchmarks import (bench_accum, bench_attention, bench_correctness,
                        bench_energy, bench_kernels, bench_memchain,
                        bench_roofline, bench_serving,
                        bench_stream_throughput)

ALL = [
    ("correctness", bench_correctness),
    ("memchain", bench_memchain),
    ("stream_throughput", bench_stream_throughput),
    ("accum", bench_accum),
    ("attention", bench_attention),
    ("energy", bench_energy),
    ("serving", bench_serving),
    ("kernels", bench_kernels),
    ("roofline", bench_roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in ALL:
        if args.only and name != args.only:
            continue
        try:
            mod.main(fast=args.fast)
        except Exception:
            failures += 1
            print(f"{name},0.0,BENCH-ERROR")
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == '__main__':
    main()
