"""Paper Fig 10 + Tab 6: peak memory under optimization chains.

Chains (cumulative, as in the paper):
  base   no optimization (naive attention, no remat, no accum, replicated)
  (1)    + memory-efficient attention        (C4)
  (1,2)  + activation checkpointing          (C3)
  (1,2,3)+ gradient accumulation x4          (C2)
  (1,2,3,4) + parameter sharding (FSDP 16x16 analytic per-device)  (C1)
  offload   C1 *phone* realization: segment-wise state offload — measured
            peak resident (p, m, v) bytes + segment-stream throughput vs the
            everything-resident baseline (repro/offload/)
  stream    C1 full depth: layer-streamed fwd/bwd — measured peak resident
            param bytes while *computing* (block segments paged through the
            window) + the analytic depth-independent bound
            (repro/core/stream.py)
  stream_lora  C6 over C1: LoRA over a frozen param-only base layout
            (read-only window, no m/v segments) with the adapter's AdamW
            memory-resident — the PEFT-on-a-phone-budget rows
  stream_qlora  streamed LoRA over an int8-quantized frozen base
            (--base-quant int8): the window holds the *encoded* segments
            and the jitted per-block program dequantizes — measured +
            analytic resident bytes and the on-flash base bytes next to
            their fp32 frozen-base counterparts

Measured on the REAL gpt2-124m config (paper's model) by compiling the
train step on CPU and reading memory_analysis().temp bytes — compile-only,
no allocation; chain 4 adds the analytic ZeRO per-device accounting (the
sharded compile itself runs in the dry-run harness).

    PYTHONPATH=src python -m benchmarks.bench_memchain [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro import configs
from repro.config import TrainConfig
from repro.core.step import (init_state, make_stream_step, make_train_step,
                             state_specs)
from repro.core.lora import lora_specs
from repro.core.zero import (bytes_per_device, frozen_base_bytes,
                             lora_stream_resident_bytes,
                             offload_resident_bytes, stream_resident_bytes)
from repro.models import registry
from repro.offload import LayerStreamedState, OffloadedTrainState
from repro.param import tree_bytes


def _compile_temp_bytes(cfg, tcfg):
    step = make_train_step(cfg, tcfg)
    sspecs = state_specs(cfg, tcfg)
    st = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
                      sspecs, is_leaf=lambda x: hasattr(x, "axes"))
    shapes = registry.batch_shapes(cfg, tcfg.global_batch, tcfg.seq_len)
    batch = {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}
    compiled = jax.jit(step, donate_argnums=(0,)).lower(st, batch).compile()
    mem = compiled.memory_analysis()
    return (getattr(mem, "temp_size_in_bytes", 0) or 0,
            getattr(mem, "argument_size_in_bytes", 0) or 0)


def main(fast: bool = False):
    arch = "gpt2_124m"
    cfg = configs.get_smoke(arch) if fast else configs.get(arch)
    seq = 64 if fast else 256
    base = TrainConfig(global_batch=8, seq_len=seq, compute_dtype="float32",
                       attention_impl="naive", remat_policy="none",
                       microbatches=1, lora_rank=8, attn_chunk=seq // 4)
    chains = [
        ("base_naive", base),
        ("chain1_me_attn", dataclasses.replace(
            base, attention_impl="streaming")),
        ("chain12_+remat", dataclasses.replace(
            base, attention_impl="streaming", remat_policy="full")),
        ("chain123_+accum4", dataclasses.replace(
            base, attention_impl="streaming", remat_policy="full",
            microbatches=4)),
    ]
    results = {}
    for name, tcfg in chains:
        temp, args = _compile_temp_bytes(cfg, tcfg)
        results[name] = temp
        row(f"fig10_{name}", 0.0,
            f"temp {temp/1e6:.1f}MB args {args/1e6:.1f}MB")
    # chain 4: ZeRO parameter sharding — analytic per-device param+opt bytes
    specs = state_specs(cfg, chains[-1][1])

    class M16:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    class M1:
        axis_names = ("data", "model")
        devices = np.empty((1, 1))

    repl = bytes_per_device(specs, M1(), "dp", dtype_bytes=4)
    shard = bytes_per_device(specs, M16(), "fsdp_tp", dtype_bytes=4)
    row("fig10_chain1234_+shard", 0.0,
        f"state/device {repl/1e6:.1f}MB -> {shard/1e6:.1f}MB "
        f"(x{repl/max(shard,1):.0f} reduction)")
    saved = (1 - results["chain123_+accum4"] /
             max(results["base_naive"], 1)) * 100
    row("fig10_summary", 0.0,
        f"activation temp saved by chain123: {saved:.0f}%")
    offload_rows(fast)
    stream_rows(fast)
    stream_lora_rows(fast)
    stream_qlora_rows(fast)
    act_offload_rows(fast)


def offload_rows(fast: bool = False, num_segments: int = 8, window: int = 2):
    """C1 phone realization: measured resident (p,m,v) bytes + stream
    throughput of the segment-wise offload engine vs everything-in-RAM."""
    arch = "gpt2_124m"
    steps = 2 if fast else 5
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(global_batch=4, seq_len=64, compute_dtype="float32",
                       total_steps=steps, warmup_steps=1)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    params_b = tree_bytes(state["params"])
    opt_b = tree_bytes(state["opt"]["m"]) + tree_bytes(state["opt"]["v"])
    grads = jax.tree.map(lambda p: jnp.full_like(p, 1e-3), state["params"])
    with tempfile.TemporaryDirectory() as d:
        ost = OffloadedTrainState.create(state, d, num_segments,
                                         max_resident=window)
        ost.apply_update(grads, lr=1e-4)       # warm the jit caches
        warm = ost.stats()
        t0 = time.perf_counter()
        for _ in range(steps):
            ost.apply_update(grads, lr=1e-4)
        dt = time.perf_counter() - t0
        s = ost.stats()
        # counters are cumulative: bill only the timed steady-state loop
        s["bytes_read"] -= warm["bytes_read"]
        s["bytes_written"] -= warm["bytes_written"]
        ost.close()
    # resident state = full params (fwd/bwd needs them) + the segment window;
    # baseline keeps params + both fp32 moments resident
    resident = params_b + s["peak_resident_bytes"]
    baseline = params_b + opt_b              # everything-resident: p + m + v
    streamed = (s["bytes_read"] + s["bytes_written"]) / max(dt, 1e-9)
    row("offload_resident_measured", dt / steps * 1e6,
        f"state resident {baseline/1e6:.2f}MB -> {resident/1e6:.2f}MB "
        f"(x{baseline/max(resident,1):.1f}) segs {num_segments} window "
        f"{window} prefetch_hit {s['prefetch_hits']}"
        f"/{s['prefetch_hits'] + s['sync_loads']}")
    row("offload_stream_throughput", 0.0,
        f"{streamed/1e6:.0f} MB/s over {steps} segment-wise updates")
    # analytic, on the paper-scale model (no allocation)
    full_cfg = configs.get(arch)
    specs = registry.param_specs(full_cfg)
    full, res = offload_resident_bytes(specs, num_segments, window)
    row("offload_resident_analytic_124m", 0.0,
        f"state {full/1e6:.0f}MB -> resident {res/1e6:.0f}MB "
        f"(segs {num_segments} window {window})")


def stream_rows(fast: bool = False, window: int = 2):
    """C1 full depth: layer-streamed fwd/bwd — measured peak resident param
    bytes while computing (head segment + a window of block segments) vs
    everything-resident, plus the analytic depth-independent bound."""
    arch = "gpt2_124m"
    steps = 2 if fast else 4
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(global_batch=4, seq_len=64, compute_dtype="float32",
                       total_steps=steps, warmup_steps=1,
                       offload_resident=window)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg,
                                tcfg.global_batch, tcfg.seq_len)
    batch["labels"] = batch["tokens"]
    with tempfile.TemporaryDirectory() as d:
        lst = LayerStreamedState.create(state, d + "/segs",
                                        max_resident=window)
        step = make_stream_step(cfg, tcfg, lst, d + "/grads")
        step(batch, 0)                  # warm the per-stage jit caches
        t0 = time.perf_counter()
        for i in range(steps):
            step(batch, i + 1)
        dt = time.perf_counter() - t0
        s = step.stats()
        full = lst.store.total_bytes
        row("stream_resident_measured", dt / steps * 1e6,
            f"state resident {full/1e6:.2f}MB -> "
            f"{s['param_peak_resident_bytes']/1e6:.2f}MB "
            f"(x{full/max(s['param_peak_resident_bytes'],1):.1f}) "
            f"segs {lst.n_layers}+head window {window} prefetch_hit "
            f"{s['param_prefetch_hits']}"
            f"/{s['param_prefetch_hits'] + s['param_sync_loads']}")
        step.close()
        lst.close()
    # analytic, on the paper-scale model (no allocation): bound is
    # head + (window + 1) layer segments, independent of n_layers
    specs = registry.param_specs(configs.get(arch))
    full, res = stream_resident_bytes(specs, window)
    _, res_b16 = stream_resident_bytes(specs, window, moment_bytes=4)
    _, res_async = stream_resident_bytes(specs, window,
                                         write_queue=2 * window)
    row("stream_resident_analytic_124m", 0.0,
        f"state {full/1e6:.0f}MB -> resident {res/1e6:.0f}MB "
        f"(window {window}; {res_b16/1e6:.0f}MB with bf16 moments; "
        f"{res_async/1e6:.0f}MB with the async write queue)")


def stream_lora_rows(fast: bool = False, window: int = 2, rank: int = 8):
    """C6 over C1: streamed LoRA — frozen param-only base segments (no m/v,
    read-only window) + memory-resident adapter AdamW.  Measured peak
    resident state vs the Full-FT streamed figure, plus the analytic
    frozen-layout bound."""
    arch = "gpt2_124m"
    steps = 2 if fast else 4
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(global_batch=4, seq_len=64, compute_dtype="float32",
                       total_steps=steps, warmup_steps=1,
                       offload_resident=window, lora_rank=rank)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    adapter = {"lora": state["lora"], "opt": state["opt"],
               "step": state["step"]}
    adapter_b = tree_bytes(state["lora"]) + tree_bytes(
        state["opt"]["m"]) + tree_bytes(state["opt"]["v"])
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg,
                                tcfg.global_batch, tcfg.seq_len)
    batch["labels"] = batch["tokens"]
    with tempfile.TemporaryDirectory() as d:
        lst = LayerStreamedState.create_frozen(state["base"], d + "/segs",
                                               max_resident=window)
        step = make_stream_step(cfg, tcfg, lst, "", adapter=adapter)
        step(batch, 0)                  # warm the per-stage jit caches
        t0 = time.perf_counter()
        for i in range(steps):
            step(batch, i + 1)
        dt = time.perf_counter() - t0
        s = step.stats()
        full = lst.store.total_bytes
        resident = s["param_peak_resident_bytes"] + adapter_b
        row("stream_lora_resident_measured", dt / steps * 1e6,
            f"base {full/1e6:.2f}MB read-only -> resident "
            f"{resident/1e6:.2f}MB (adapter {adapter_b/1e6:.2f}MB in RAM) "
            f"r{rank} segs {lst.n_layers}+head window {window} "
            f"written_back {s['param_bytes_written']}B")
        step.close()
        lst.close()
    # analytic, on the paper-scale model: p-only segments (~1/3 the Full-FT
    # streamed bound) + the memory-resident adapter state
    full_cfg = configs.get(arch)
    specs = registry.param_specs(full_cfg)
    lspecs = lora_specs(specs, tcfg.lora_targets, rank)
    full, res = lora_stream_resident_bytes(specs, lspecs, window)
    _, res_fullft = stream_resident_bytes(specs, window)
    row("stream_lora_resident_analytic_124m", 0.0,
        f"state {full/1e6:.0f}MB -> resident {res/1e6:.0f}MB "
        f"(r{rank} window {window}; Full-FT streamed {res_fullft/1e6:.0f}MB)")


def stream_qlora_rows(fast: bool = False, window: int = 2, rank: int = 8):
    """Streamed QLoRA: int8 per-channel quantized frozen base — the window
    holds the encoded segments (int8 codes + scales) and the jitted
    per-block program dequantizes, so both the on-flash base bytes and the
    resident window shrink ~4x vs the fp32 frozen base.  Measured rows run
    the smoke config; analytic rows account the paper-scale model."""
    arch = "gpt2_124m"
    steps = 2 if fast else 4
    cfg = configs.get_smoke(arch)
    tcfg = TrainConfig(global_batch=4, seq_len=64, compute_dtype="float32",
                       total_steps=steps, warmup_steps=1,
                       offload_resident=window, lora_rank=rank,
                       base_quant="int8")
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    adapter = {"lora": state["lora"], "opt": state["opt"],
               "step": state["step"]}
    adapter_b = tree_bytes(state["lora"]) + tree_bytes(
        state["opt"]["m"]) + tree_bytes(state["opt"]["v"])
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg,
                                tcfg.global_batch, tcfg.seq_len)
    batch["labels"] = batch["tokens"]
    with tempfile.TemporaryDirectory() as d:
        lst32 = LayerStreamedState.create_frozen(state["base"], d + "/f32",
                                                 max_resident=window)
        flash32 = lst32.store.total_bytes
        lst32.close()
        lst = LayerStreamedState.create_frozen(state["base"], d + "/i8",
                                               max_resident=window,
                                               quant="int8")
        step = make_stream_step(cfg, tcfg, lst, "", adapter=adapter)
        step(batch, 0)                  # warm the per-stage jit caches
        t0 = time.perf_counter()
        for i in range(steps):
            step(batch, i + 1)
        dt = time.perf_counter() - t0
        s = step.stats()
        flash8 = lst.store.total_bytes
        resident = s["param_peak_resident_bytes"] + adapter_b
        row("stream_qlora_resident_measured", dt / steps * 1e6,
            f"base {flash8/1e6:.2f}MB int8 read-only -> resident "
            f"{resident/1e6:.2f}MB (adapter {adapter_b/1e6:.2f}MB in RAM) "
            f"r{rank} window {window} written_back "
            f"{s['param_bytes_written']}B")
        row("stream_qlora_flash_measured", 0.0,
            f"on-flash frozen base {flash32/1e6:.2f}MB fp32 -> "
            f"{flash8/1e6:.2f}MB int8 (x{flash32/max(flash8,1):.2f})")
        step.close()
        lst.close()
    # analytic, on the paper-scale model: int8 base segments + scales, fp32
    # norms/biases, memory-resident adapter state — next to the fp32 figures
    full_cfg = configs.get(arch)
    specs = registry.param_specs(full_cfg)
    lspecs = lora_specs(specs, tcfg.lora_targets, rank)
    _, res32 = lora_stream_resident_bytes(specs, lspecs, window)
    _, res8 = lora_stream_resident_bytes(specs, lspecs, window,
                                         base_quant="int8")
    seg32, head32, n_layers = frozen_base_bytes(specs)
    seg8, head8, _ = frozen_base_bytes(specs, base_quant="int8")
    fl32 = seg32 * n_layers + head32
    fl8 = seg8 * n_layers + head8
    row("stream_qlora_resident_analytic_124m", 0.0,
        f"resident {res32/1e6:.0f}MB fp32-base -> {res8/1e6:.0f}MB int8-base "
        f"(x{res32/max(res8,1):.1f}; r{rank} window {window})")
    row("stream_qlora_flash_analytic_124m", 0.0,
        f"on-flash frozen base {fl32/1e6:.0f}MB -> {fl8/1e6:.0f}MB "
        f"(x{fl32/max(fl8,1):.2f})")


def act_offload_rows(fast: bool = False, window: int = 2):
    """Long-sequence activation offload: constant-token seq-len sweep.

    The streamed driver made resident *params* depth-independent, but the
    device-resident boundary activations still cost (L+1) * B * S * D
    fp32 — the remaining wall for long documents.  This sweep holds the
    token budget constant (one long document vs many short chats, the
    paper's on-device corpus framing) and stretches seq_len 512 -> 32k on
    a deep-narrow ssm config (the sub-quadratic family the repo's long-seq
    cells run), comparing measured boundary-activation residency and tok/s
    with and without ``--offload-activations --activation-codec bf16``.

    Gates (the CI perf job runs ``--quick``):
      - act-offload resident < no-offload resident at seq 4096;
      - full sweep: the 32k act-offload figure stays within 1.35x the
        seq-512 act-offload figure, while no-offload at 32k is >= 10x it.
    """
    from repro.config import ModelConfig

    n_layers = 12 if fast else 32
    tokens = 4096 if fast else 32768
    seqs = [512, 4096] if fast else [512, 4096, 32768]
    cfg = ModelConfig(
        name="mamba-deep-bench", family="ssm",
        n_layers=n_layers, d_model=32, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=256, head_dim=8, pos_variant="none", tie_embeddings=True,
        ssm_state=16, ssm_head_dim=8, ssm_expand=2, ssm_chunk=256,
        max_seq_len=65536)
    steps = 2
    specs = registry.param_specs(cfg)
    measured = {}   # (seq, offload) -> resident bytes
    results = {"config": {"n_layers": n_layers, "d_model": cfg.d_model,
                          "tokens_per_step": tokens, "window": window,
                          "codec": "bf16", "family": cfg.family},
               "rows": {}}
    for seq in seqs:
        batch = tokens // seq
        for off in (False, True):
            tcfg = TrainConfig(
                global_batch=batch, seq_len=seq, compute_dtype="float32",
                total_steps=steps + 1, warmup_steps=1,
                offload_resident=window, offload_stream_params=True,
                offload_activations=off,
                activation_codec="bf16" if off else "fp32")
            state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
            b = registry.make_batch(jax.random.PRNGKey(1), cfg, batch, seq)
            b["labels"] = b["tokens"]
            with tempfile.TemporaryDirectory() as d:
                lst = LayerStreamedState.create(state, d + "/segs",
                                                max_resident=window)
                step = make_stream_step(cfg, tcfg, lst, d + "/grads")
                step(b, 0)              # warm the per-stage jit caches
                t0 = time.perf_counter()
                for i in range(steps):
                    step(b, i + 1)
                dt = time.perf_counter() - t0
                s = step.stats()
                res = s["act_resident_peak_bytes"]
                measured[(seq, off)] = res
                tag = "bf16_offload" if off else "resident"
                hit = (f" hit {s.get('act_write_hits', 0) + s.get('act_prefetch_hits', 0)}"
                       f"/{s.get('act_takes', 0)}" if off else "")
                row(f"act_sweep_seq{seq}_{tag}", dt / steps * 1e6,
                    f"acts resident {res/1e6:.2f}MB "
                    f"{tokens * steps / dt:.0f} tok/s "
                    f"(B{batch} S{seq} L{n_layers}){hit}")
                results["rows"][f"seq{seq}_{tag}"] = {
                    "batch": batch, "seq_len": seq,
                    "act_resident_peak_bytes": int(res),
                    "tokens_per_s": tokens * steps / dt,
                    "step_ms": dt / steps * 1e3,
                    "act_takes": int(s.get("act_takes", 0)),
                    "act_hits": int(s.get("act_write_hits", 0)
                                    + s.get("act_prefetch_hits", 0)),
                }
                step.close()
                lst.close()
        # analytic (same geometry): device-resident vs spilled bound
        _, a_res = stream_resident_bytes(
            specs, window, write_queue=2 * window, batch=batch, seq_len=seq,
            d_model=cfg.d_model)
        _, a_off = stream_resident_bytes(
            specs, window, write_queue=2 * window, batch=batch, seq_len=seq,
            d_model=cfg.d_model, act_offload=True, act_bytes=2)
        row(f"act_sweep_seq{seq}_analytic", 0.0,
            f"resident {a_res/1e6:.2f}MB -> offload {a_off/1e6:.2f}MB "
            f"(B{batch} S{seq})")
        results["rows"][f"seq{seq}_analytic"] = {
            "resident_bytes": int(a_res), "offload_bytes": int(a_off)}
    assert measured[(4096, True)] < measured[(4096, False)], (
        "act-offload resident must beat device-resident acts at seq 4096: "
        f"{measured}")
    base512 = measured[(512, True)]
    if not fast:
        grow_off = measured[(32768, True)] / max(base512, 1)
        grow_res = measured[(32768, False)] / max(base512, 1)
        row("act_sweep_summary", 0.0,
            f"32k/512 act-offload x{grow_off:.2f} (<= 1.35) vs "
            f"device-resident x{grow_res:.1f} (>= 10)")
        assert grow_off <= 1.35, measured
        assert grow_res >= 10.0, measured
        results["summary"] = {"growth_offload_32k_over_512": grow_off,
                              "growth_resident_32k_over_512": grow_res}
        # quick-mode numbers never land in the committed artifact
        with open("BENCH_act_offload.json", "w") as f:
            json.dump(results, f, indent=1)
        row("act_sweep_json", 0.0, "BENCH_act_offload.json")


def main_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="reduced smoke config (CI perf-regression job)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.quick)


if __name__ == "__main__":
    main_cli()
