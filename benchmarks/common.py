"""Shared benchmark helpers.  Every bench prints ``name,us_per_call,derived``
CSV rows (one per configuration) so ``python -m benchmarks.run`` emits one
table per paper artifact."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3,
              **kw) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.1f},{derived}")
