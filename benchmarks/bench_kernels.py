"""Kernel-level benchmark: flash-attention / SSD Pallas kernels (interpret
mode on CPU — correctness + op-count shape; wall-clock MFU lives on TPU) vs
their jnp counterparts."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.kernels.ssd.ref import ssd_ref


def main(fast: bool = False):
    b, s, h, d = 2, 64, 4, 32
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
    out_flash = flash_attention(q, k, v, causal=True, block_q=16, block_k=16,
                                interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    err = float(jnp.abs(out_flash.transpose(0, 2, 1, 3) - ref).max())
    us = time_call(lambda: flash_attention(q, k, v, causal=True, block_q=16,
                                           block_k=16, interpret=True),
                   iters=1)
    row("kernel_flash_fwd_interpret", us, f"max_err_vs_ref {err:.2e}")

    nh, hd, ds, chunk = 4, 16, 32, 16
    xh = jax.random.normal(jax.random.PRNGKey(0), (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (nh,)) * 0.5)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, s, ds))
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, s, ds))
    y_pal, _ = ssd_chunked_pallas(xh, dt, A, B_, C_, chunk=chunk,
                                  interpret=True)
    y_ref, _ = ssd_ref(xh, dt, A, B_, C_)
    err = float(jnp.abs(y_pal - y_ref).max())
    us = time_call(lambda: ssd_chunked_pallas(xh, dt, A, B_, C_, chunk=chunk,
                                              interpret=True)[0], iters=1)
    row("kernel_ssd_interpret", us, f"max_err_vs_seq_ref {err:.2e}")


if __name__ == "__main__":
    main()
