"""Paper Tab 7: gradient-accumulation ablation (b4a2 / b2a4 / b1a8).

Same total batch (8), different micro-batch splits: final loss / PPL must be
(numerically) unchanged and gradients must match the full-batch gradient.
"""
from __future__ import annotations

import math

import jax

from benchmarks.common import row
from repro import configs
from repro.config import TrainConfig
from repro.core.accumulate import value_and_grad_accumulated
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.train import train_loop
from repro.models import registry
from repro.param import init_params


def main(fast: bool = False):
    cfg = configs.get_smoke("gemma3_270m")
    tok = ByteTokenizer()
    ds = LMDataset(synthetic_wikitext(400), tok, 64)
    steps = 6 if fast else 16

    # gradient equivalence vs the full batch
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    tc0 = TrainConfig(global_batch=8, seq_len=64, compute_dtype="float32",
                      attn_chunk=16)
    batch = {k: jax.numpy.asarray(v) for k, v in ds.example(0).items()}
    batch = {k: jax.numpy.stack([v] * 8) for k, v in batch.items()}
    def loss_fn(p, b):
        return registry.loss_fn(cfg)(p, b, cfg, tc0)
    _, _, g_full = value_and_grad_accumulated(loss_fn, params, batch, 1)

    for tag, micro in (("b8a1", 1), ("b4a2", 2), ("b2a4", 4), ("b1a8", 8)):
        tcfg = TrainConfig(global_batch=8, seq_len=64,
                           compute_dtype="float32", attn_chunk=16,
                           microbatches=micro, total_steps=steps,
                           warmup_steps=1, learning_rate=3e-3)
        _, _, g = value_and_grad_accumulated(loss_fn, params, batch, micro)
        gdiff = max(float(jax.numpy.abs(a - b).max()) for a, b in
                    zip(jax.tree.leaves(g_full), jax.tree.leaves(g)))
        state, obs = train_loop(cfg, tcfg, out_dir=None, dataset=ds,
                                print_fn=None)
        us = sum(r["step_time_s"] for r in obs.rows) / len(obs.rows) * 1e6
        row(f"tab7_{tag}", us,
            f"final_loss {obs.rows[-1]['loss']:.4f} "
            f"ppl {math.exp(obs.rows[-1]['loss']):.2f} "
            f"max_grad_diff_vs_full {gdiff:.2e}")


if __name__ == "__main__":
    main()
