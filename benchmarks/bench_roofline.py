"""§Roofline summary: reads the dry-run result cache and prints the
per-(arch x shape x mesh) three-term roofline table as CSV rows."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import row

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def main(fast: bool = False, tag: str = "v2"):
    paths = sorted(glob.glob(os.path.join(RESULTS, f"*__{tag}.json")))
    if not paths:
        row("roofline_missing", 0.0,
            "run: PYTHONPATH=src python -m repro.launch.dryrun --all --tag v2")
        return
    for p in paths:
        r = json.load(open(p))
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        if r["status"] != "OK":
            row(name, 0.0, r["status"])
            continue
        rf = r["roofline"]
        us = rf["step_time_bound_s"] * 1e6
        row(name, us,
            f"dom={rf['dominant']} frac={rf['roofline_fraction']:.3f} "
            f"tc={rf['t_compute_s']:.3g} tm={rf['t_memory_s']:.3g} "
            f"tl={rf['t_collective_s']:.3g} "
            f"useful={rf['useful_flops_ratio']:.2f}")


if __name__ == "__main__":
    main()
