"""Paper Tab 8 + §4.1.4: memory-efficient attention vs the naive baseline.

The paper's Termux comparison measures its native runtime vs an unoptimized
pipeline; the controlled analogue here is the same exact-attention operator
with and without the C4 optimization: step time + the quadratic-vs-streaming
intermediate footprint across sequence lengths.

``flash_rows`` extends the table to long sequences (1k/8k/32k): the Pallas
flash kernel vs its streaming numerics oracle (``impl="ref"``), reporting
wall time and the analytic peak score-intermediate bytes each path
materializes (naive S^2 / streaming q-chunk x kv-chunk / flash tile).  Full
runs land in ``BENCH_attention.json`` (committed artifact); on CPU the
Pallas kernel executes in interpret mode, so the committed wall numbers are
an algorithmic (not kernel-level) comparison — the memory column is the
portable story.

    PYTHONPATH=src python -m benchmarks.bench_attention [--quick] [--json F]
"""
from __future__ import annotations

import argparse
import json

import jax

from benchmarks.common import row, time_call
from repro.core.attention import attention

_COMMITTED_JSON = "BENCH_attention.json"


def main(fast: bool = False):
    b, h, d = 4, 8, 64
    seqs = (128, 256) if fast else (128, 256, 512, 1024)
    chunk = 128
    for s in seqs:
        q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        f_naive = jax.jit(lambda q, k, v: attention(q, k, v, impl="naive"))
        f_stream = jax.jit(lambda q, k, v: attention(
            q, k, v, impl="streaming", chunk=chunk))
        us_n = time_call(f_naive, q, k, v)
        us_s = time_call(f_stream, q, k, v)
        naive_mb = b * h * s * s * 4 / 1e6
        stream_mb = b * h * min(chunk // 2, s) * chunk * 4 / 1e6
        row(f"tab8_naive_s{s}", us_n, f"scores {naive_mb:.1f}MB")
        row(f"tab8_streaming_s{s}", us_s,
            f"scores {stream_mb:.1f}MB ({naive_mb/stream_mb:.0f}x smaller)")


def flash_rows(fast: bool = False, out_json: str = _COMMITTED_JSON):
    """Flash (Pallas) vs ref (streaming oracle) at long seq: wall + the
    peak score-intermediate bytes each path holds.  ``--quick`` runs 1k
    only (CI); the full 1k/8k/32k sweep writes the committed artifact."""
    b, h, d = 1, 2, 64
    chunk = 512
    block = 128                      # the kernel's query/key tile edge
    seqs = (1024,) if fast else (1024, 8192, 32768)
    iters = 3 if fast else 1         # 32k interpret-mode calls are heavy
    results = {"geometry": {"batch": b, "heads": h, "head_dim": d,
                            "chunk": chunk, "block": block},
               "rows": {}}
    for s in seqs:
        q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        f_ref = jax.jit(lambda q, k, v: attention(
            q, k, v, causal=True, impl="ref", chunk=chunk))
        f_flash = jax.jit(lambda q, k, v: attention(
            q, k, v, causal=True, impl="flash"))
        us_ref = time_call(f_ref, q, k, v, iters=iters)
        us_flash = time_call(f_flash, q, k, v, iters=iters)
        naive_mb = b * h * s * s * 4 / 1e6          # what S^2 would cost
        ref_mb = b * h * min(chunk // 2, s) * chunk * 4 / 1e6
        flash_mb = b * h * block * block * 4 / 1e6  # one VMEM tile
        row(f"flash_ref_s{s}", us_ref,
            f"scores {ref_mb:.2f}MB (naive would be {naive_mb:.0f}MB)")
        row(f"flash_pallas_s{s}", us_flash,
            f"tile {flash_mb:.2f}MB ({ref_mb/flash_mb:.0f}x under ref, "
            f"{naive_mb/flash_mb:.0f}x under naive)")
        results["rows"][str(s)] = {
            "ref_wall_us": us_ref, "flash_wall_us": us_flash,
            "naive_scores_mb": naive_mb, "ref_scores_mb": ref_mb,
            "flash_tile_mb": flash_mb,
        }
    if fast and out_json == _COMMITTED_JSON:
        # quick-mode numbers must never clobber the committed artifact
        out_json = None
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        row("flash_json", 0.0, out_json)


def main_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="reduced sweep (CI)")
    ap.add_argument("--json", default=_COMMITTED_JSON,
                    help="output artifact path (full runs only)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.quick)
    flash_rows(fast=args.quick, out_json=args.json)


if __name__ == "__main__":
    main_cli()
