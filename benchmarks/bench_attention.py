"""Paper Tab 8 + §4.1.4: memory-efficient attention vs the naive baseline.

The paper's Termux comparison measures its native runtime vs an unoptimized
pipeline; the controlled analogue here is the same exact-attention operator
with and without the C4 optimization: step time + the quadratic-vs-streaming
intermediate footprint across sequence lengths.
"""
from __future__ import annotations

import jax

from benchmarks.common import row, time_call
from repro.core.attention import attention


def main(fast: bool = False):
    b, h, d = 4, 8, 64
    seqs = (128, 256) if fast else (128, 256, 512, 1024)
    chunk = 128
    for s in seqs:
        q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, d))
        k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
        v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
        f_naive = jax.jit(lambda q, k, v: attention(q, k, v, impl="naive"))
        f_stream = jax.jit(lambda q, k, v: attention(
            q, k, v, impl="streaming", chunk=chunk))
        us_n = time_call(f_naive, q, k, v)
        us_s = time_call(f_stream, q, k, v)
        naive_mb = b * h * s * s * 4 / 1e6
        stream_mb = b * h * min(chunk // 2, s) * chunk * 4 / 1e6
        row(f"tab8_naive_s{s}", us_n, f"scores {naive_mb:.1f}MB")
        row(f"tab8_streaming_s{s}", us_s,
            f"scores {stream_mb:.1f}MB ({naive_mb/stream_mb:.0f}x smaller)")


if __name__ == "__main__":
    main()
