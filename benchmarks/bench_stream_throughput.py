"""Wall-clock throughput of the layer-streamed trainer (overlap pipeline).

PRs 1–4 made the streamed path *memory*-correct; this benchmark measures
*time*: tokens/sec and step wall-clock for the streamed variants next to
the in-memory jit ceiling, plus the overlap breakdown from the engine
timers — how much wall-clock the step spent *blocked* on segment reads,
write-backs and host->device staging vs. compute that successfully hid the
I/O.  The headline comparison is the async pipeline (background write-back
+ device staging, the defaults) against the synchronous non-staged path
(``--no-offload-async-writeback --no-offload-staging``) on the same
config, same machine.  The deferred host syncs are unconditional, so the
sync row keeps them — it isolates exactly what the two flags buy.

Rows (``name,us_per_call,derived`` like every bench):

  inmem_jit           fully in-memory jitted step (the ceiling)
  stream_sync         streamed Full-FT, synchronous non-staged path
  stream_async        streamed Full-FT, full overlap pipeline
  stream_speedup      async vs sync tokens/sec on the same config
  stream_lora_async   streamed LoRA (frozen read-only base)
  stream_qlora_async  streamed QLoRA (int8-encoded frozen base)
  read_<backend>      per-backend segment-read row (mmap/pread/direct/
  read_<backend>_cold uring), warm page cache vs cold — see below

The per-backend rows isolate the *read transport* (offload/readers.py):
a frozen-base streamed LoRA step on the synchronous, prefetch-off path,
so every segment pull is a sync load billed to ``read_block_s`` — the row
is the read time, not the pipeline's ability to hide it.  ``_cold`` rows
call ``store.drop_cache()`` (fsync + ``posix_fadvise(DONTNEED)``) between
steps, so they measure flash, not the page cache — warm-mmap numbers are
RAM bandwidth in disguise, and the raw backends (pread/O_DIRECT/io_uring)
only show their worth once the cache is actually cold.

Results also land in ``BENCH_stream_throughput.json`` (rows + breakdown +
``cold_read_block_s`` per backend).  ``--quick`` runs the reduced config
and *asserts* pipeline health — prefetch hit rate >= 0.9 and a nonzero
compute/IO overlap fraction — so a regression in the overlap pipeline
fails CI instead of just slowing it.  ``--cold-cache`` drops the segment
page cache between steps of every streamed row; with ``--quick`` it also
runs the per-backend cold rows and gates them (tok/s > 0 per backend,
pread/direct cold ``read_block_s`` no worse than the committed mmap cold
figure).

    PYTHONPATH=src python -m benchmarks.bench_stream_throughput \
        [--quick] [--cold-cache]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import tempfile
import time

import jax

from benchmarks.common import row
from repro import configs
from repro.config import TrainConfig
from repro.core.step import init_state, make_stream_step, make_train_step
from repro.models import registry
from repro.offload.readers import IO_BACKENDS, backend_available
from repro.offload.state import LayerStreamedState


def _make_batch(cfg, tcfg):
    b = registry.make_batch(jax.random.PRNGKey(1), cfg, tcfg.global_batch,
                            tcfg.seq_len)
    b["labels"] = b["tokens"]
    return b


def _bench_inmem(cfg, tcfg, steps: int):
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = _make_batch(cfg, tcfg)
    state, m = step_fn(state, batch)         # warm the jit cache
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(steps):
        state, m = step_fn(state, batch)
        jax.block_until_ready(m["loss"])
    return time.perf_counter() - t0


def _bench_stream(cfg, tcfg, steps: int, workdir: str, *,
                  cold: bool = False):
    """(wall_s, pipeline breakdown dict) for ``steps`` streamed steps.
    Stats are deltas over the timed loop only (the warm-up step also warms
    the window, prefetcher and write queue).  ``cold=True`` drops the
    segment page cache before every timed step, so the reads in the loop
    come from flash — the fadvise itself is in the wall (it is cheap for
    clean read-only stores; Full-FT pays its own dirty-page flush, which
    is honest: that is what a cold device would pay too)."""
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    if tcfg.lora_rank > 0:
        adapter = {"lora": state["lora"], "opt": state["opt"],
                   "step": state["step"]}
        lstate = LayerStreamedState.create_frozen(
            state["base"], os.path.join(workdir, "segs"),
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch, quant=tcfg.base_quant,
            io_backend=tcfg.offload_io)
        step_fn = make_stream_step(cfg, tcfg, lstate, "", adapter=adapter)
    else:
        lstate = LayerStreamedState.create(
            state, os.path.join(workdir, "segs"),
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            async_writeback=tcfg.offload_async_writeback,
            io_backend=tcfg.offload_io)
        step_fn = make_stream_step(cfg, tcfg, lstate,
                                   os.path.join(workdir, "grads"))
    del state
    batch = _make_batch(cfg, tcfg)
    step_fn(batch, 0)                        # warm jit + window + pipeline
    warm = step_fn.pipeline_stats()
    warm_hits = step_fn.stats()["param_prefetch_hits"]
    warm_loads = step_fn.stats()["param_sync_loads"]
    t0 = time.perf_counter()
    for i in range(steps):
        if cold:
            lstate.store.drop_cache()
        step_fn(batch, i + 1)
    wall = time.perf_counter() - t0
    ps = step_fn.pipeline_stats()
    s = step_fn.stats()
    bd = {k: ps[k] - warm[k] for k in
          ("read_block_s", "write_block_s", "stage_h2d_s",
           "writeback_busy_s")}
    hits = s["param_prefetch_hits"] - warm_hits
    loads = s["param_sync_loads"] - warm_loads
    bd["prefetch_hit_rate"] = hits / (hits + loads) if (hits + loads) else 1.0
    blocked = bd["read_block_s"] + bd["write_block_s"]
    bd["overlap_frac"] = max(0.0, 1.0 - blocked / max(wall, 1e-9))
    io_backend = lstate.store.io_backend
    step_fn.close()
    lstate.close()
    return wall, bd, io_backend


def _fmt(bd):
    return (f"hit {bd['prefetch_hit_rate']:.2f} overlap "
            f"{bd['overlap_frac']:.2f} read-blk {bd['read_block_s']*1e3:.0f}ms "
            f"write-blk {bd['write_block_s']*1e3:.0f}ms h2d "
            f"{bd['stage_h2d_s']*1e3:.0f}ms bg-write "
            f"{bd['writeback_busy_s']*1e3:.0f}ms")


_COMMITTED_JSON = "BENCH_stream_throughput.json"


def _backend_read_rows(cfg, base: dict, steps: int, report, results,
                       *, cold_only: bool):
    """Per-backend segment-read rows: a frozen-base streamed LoRA step on
    the synchronous prefetch-off path, one row per available backend, warm
    and cold.  With prefetch and staging off, every pull is a sync load —
    ``read_block_s`` in the breakdown *is* the segment read time, so the
    rows compare transports, not the pipeline's ability to hide them."""
    read_cfg = dict(base, offload_stream_params=True, lora_rank=8,
                    offload_prefetch=False, offload_async_writeback=False,
                    offload_staging=False)
    results["io_backends"] = []
    results["cold_read_block_s"] = {}
    for backend in IO_BACKENDS:
        with tempfile.TemporaryDirectory() as d:
            if not backend_available(backend, d):
                # explicit skip line so the CI log shows *why* the matrix
                # is narrower on this kernel/filesystem
                row(f"read_{backend}_cold", 0.0,
                    "skip: backend unavailable on this kernel/fs")
                continue
            results["io_backends"].append(backend)
            modes = ("cold",) if cold_only else ("warm", "cold")
            for mode in modes:
                wall, bd, actual = _bench_stream(
                    cfg, TrainConfig(**read_cfg, offload_io=backend),
                    steps, d, cold=(mode == "cold"))
                assert actual == backend, \
                    f"probed backend {backend} degraded to {actual}"
                name = f"read_{backend}" + ("_cold" if mode == "cold"
                                            else "")
                report(name, wall, bd)
                if mode == "cold":
                    results["cold_read_block_s"][backend] = \
                        bd["read_block_s"]
    cold = results["cold_read_block_s"]
    raw = {b: v for b, v in cold.items() if b != "mmap"}
    if raw and "mmap" in cold:
        best = min(raw, key=raw.get)
        results["best_cold_backend"] = best
        row("read_cold_best", 0.0,
            f"{best} cold read-blk {raw[best]*1e3:.0f}ms vs mmap "
            f"{cold['mmap']*1e3:.0f}ms "
            f"(x{cold['mmap'] / max(raw[best], 1e-9):.2f})")


def main(fast: bool = False, out_json: str = _COMMITTED_JSON,
         cold_cache: bool = False):
    arch = "gpt2_124m"
    smoke = configs.get_smoke(arch)
    if fast:
        # CI gate config: tiny blocks, deep enough that the steady-state
        # block pipeline dominates the head/tail
        cfg = dataclasses.replace(smoke, n_layers=4)
    else:
        # gpt2-124m-sized *blocks* (d768/ff3072 — the segment bytes and
        # per-block compute the paper's model streams) at reduced depth so
        # the row finishes on CPU; depth only repeats the steady state
        cfg = dataclasses.replace(smoke, d_model=768, n_heads=12,
                                  n_kv_heads=12, d_ff=3072, n_layers=6,
                                  vocab_size=8192, max_seq_len=256)
    steps = 3
    base = dict(global_batch=4, seq_len=64 if fast else 128,
                compute_dtype="float32", total_steps=steps + 1,
                warmup_steps=1, offload_resident=2)
    tokens = base["global_batch"] * base["seq_len"] * steps
    results = {"arch": arch, "n_layers": cfg.n_layers,
               "d_model": cfg.d_model, "seq_len": base["seq_len"],
               "global_batch": base["global_batch"], "steps": steps,
               "tokens_per_step": tokens // steps, "rows": {}}

    def report(name, wall, bd=None):
        tps = tokens / max(wall, 1e-9)
        results["rows"][name] = {"wall_s": wall, "step_ms": wall / steps * 1e3,
                                 "tokens_per_s": tps,
                                 **({"breakdown": bd} if bd else {})}
        row(name, wall / steps * 1e6,
            f"{tps:.0f} tok/s" + (f" | {_fmt(bd)}" if bd else ""))
        return tps

    results["cold_cache"] = cold_cache
    wall = _bench_inmem(cfg, TrainConfig(**base), steps)
    report("inmem_jit", wall)

    with tempfile.TemporaryDirectory() as d:
        wall, bd, _ = _bench_stream(
            cfg, TrainConfig(**base, offload_stream_params=True,
                             offload_async_writeback=False,
                             offload_staging=False), steps, d,
            cold=cold_cache)
    tps_sync = report("stream_sync", wall, bd)

    with tempfile.TemporaryDirectory() as d:
        wall, bd_async, io_backend = _bench_stream(
            cfg, TrainConfig(**base, offload_stream_params=True), steps, d,
            cold=cold_cache)
    results["io_backend"] = io_backend   # what $REPRO_OFFLOAD_IO resolved to
    tps_async = report("stream_async", wall, bd_async)
    speedup = tps_async / max(tps_sync, 1e-9)
    results["speedup_async_vs_sync"] = speedup
    row("stream_speedup", 0.0,
        f"async pipeline x{speedup:.2f} tokens/sec vs synchronous path "
        f"(io={io_backend}{', cold cache' if cold_cache else ''})")

    with tempfile.TemporaryDirectory() as d:
        wall, bd, _ = _bench_stream(
            cfg, TrainConfig(**base, offload_stream_params=True,
                             lora_rank=8), steps, d, cold=cold_cache)
    report("stream_lora_async", wall, bd)

    with tempfile.TemporaryDirectory() as d:
        wall, bd, _ = _bench_stream(
            cfg, TrainConfig(**base, offload_stream_params=True,
                             lora_rank=8, base_quant="int8"), steps, d,
            cold=cold_cache)
    report("stream_qlora_async", wall, bd)

    # per-backend read transport rows: always part of the committed (full)
    # artifact; in --quick they only run under --cold-cache (the CI gate)
    if not fast or cold_cache:
        _backend_read_rows(cfg, base, steps, report, results,
                           cold_only=fast)

    if fast and out_json == _COMMITTED_JSON:
        # the CI-gate config's tiny-block numbers must never clobber the
        # committed representative results; pass --json to write anyway
        out_json = None
    if out_json:
        with open(out_json, "w") as f:
            json.dump(results, f, indent=1)
        row("stream_throughput_json", 0.0, out_json)

    if fast:
        # CI pipeline-health gate: a regression in prefetch or overlap shows
        # up as a hard failure, not as slowly creeping CI minutes
        hr = bd_async["prefetch_hit_rate"]
        ov = bd_async["overlap_frac"]
        assert hr >= 0.9, (
            f"streamed prefetch hit rate {hr:.2f} < 0.9 — the read pipeline "
            "is no longer running ahead of compute")
        # regression gate against the committed artifact: the async
        # pipeline's overlap fraction may drift with machine noise, but a
        # drop of more than 0.1 below the committed measurement means the
        # pipeline stopped hiding I/O behind compute
        floor = 0.0
        committed = os.path.join(os.path.dirname(__file__), "..",
                                 _COMMITTED_JSON)
        if os.path.exists(committed):
            with open(committed) as f:
                ref = json.load(f)
            # the committed artifact is a warm-cache run; a cold-cache
            # quick run legitimately overlaps less (every read really hits
            # flash), so the regression slack widens accordingly
            slack = 0.25 if cold_cache else 0.1
            floor = max(floor, ref["rows"]["stream_async"]["breakdown"]
                        ["overlap_frac"] - slack)
        assert ov > floor, (
            f"compute/IO overlap fraction {ov:.2f} <= {floor:.2f} "
            f"(committed {_COMMITTED_JSON} minus 0.1 slack) — the overlap "
            "pipeline regressed")
        # cold-cache mode adds a fixed drop_cache cost to both paths and
        # the quick config's reads are tiny, so the async edge compresses
        # to noise there — the gate then only rejects a real (>10%) loss
        async_floor = 0.9 * tps_sync if cold_cache else tps_sync
        assert tps_async >= async_floor, (
            f"async pipeline {tps_async:.0f} tok/s is SLOWER than the "
            f"synchronous path {tps_sync:.0f} tok/s — the overlap pipeline "
            "is costing more than it hides")
        row("stream_pipeline_gate", 0.0,
            f"ok: hit {hr:.2f} >= 0.9, overlap {ov:.2f} > {floor:.2f}, "
            f"async x{speedup:.2f} vs sync")

    if fast and cold_cache:
        # reader-backend gate: every probed backend must actually move
        # tokens on a cold cache, and the raw read backends must not be
        # slower than the committed *cold mmap* figure — the quick config
        # reads far fewer bytes than the committed full run, so a raw
        # backend exceeding the full run's mmap cold read time means the
        # transport itself broke (syscall storm, lost batching), not noise
        for b in results["io_backends"]:
            tps = results["rows"][f"read_{b}_cold"]["tokens_per_s"]
            assert tps > 0, f"cold-cache {b} read row moved 0 tok/s"
        ref_mmap_cold = None
        committed = os.path.join(os.path.dirname(__file__), "..",
                                 _COMMITTED_JSON)
        if os.path.exists(committed):
            with open(committed) as f:
                ref_mmap_cold = json.load(f).get(
                    "cold_read_block_s", {}).get("mmap")
        if ref_mmap_cold is not None:
            for b in ("pread", "direct"):
                if b not in results["cold_read_block_s"]:
                    continue
                rb = results["cold_read_block_s"][b]
                assert rb <= ref_mmap_cold + 0.25, (
                    f"cold {b} read_block {rb:.2f}s exceeds the committed "
                    f"mmap cold figure {ref_mmap_cold:.2f}s (+0.25s slack) "
                    "on a far smaller config — raw read transport "
                    "regressed")
        row("stream_cold_gate", 0.0,
            f"ok: backends {'/'.join(results['io_backends'])} cold tok/s "
            f"> 0"
            + (f", pread/direct read-blk <= committed mmap cold "
               f"{ref_mmap_cold:.2f}s" if ref_mmap_cold is not None
               else ", no committed cold figure yet"))


def main_cli():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", "--fast", action="store_true", dest="quick",
                    help="reduced config + pipeline-health assertions "
                         "(CI regression gate)")
    ap.add_argument("--cold-cache", action="store_true", dest="cold_cache",
                    help="drop the segment page cache between steps of "
                         "every streamed row (reads measure flash, not "
                         "RAM); with --quick also runs + gates the "
                         "per-backend cold read rows")
    ap.add_argument("--json", default=_COMMITTED_JSON,
                    help="where to write the results JSON (a --quick run "
                         "skips the default path so the committed artifact "
                         "is never clobbered)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    main(fast=args.quick, out_json=args.json, cold_cache=args.cold_cache)


if __name__ == "__main__":
    main_cli()
