"""Byte-level tokenizer with optional learned merges (BPE-lite).

Matches the paper's tokenizer/model-compatibility goal in spirit: a
self-contained tokenizer with exact encode/decode round-trip (property
tested), special tokens, and vocabulary export.
"""
from __future__ import annotations

import collections
import json
from typing import Iterable, List, Optional, Tuple

PAD, BOS, EOS = 0, 1, 2
N_SPECIAL = 3


class ByteTokenizer:
    """ids = [specials] + [bytes 0..255] + [merges...]."""

    def __init__(self, merges: Optional[List[Tuple[int, int]]] = None):
        self.merges = list(merges or [])
        self._merge_rank = {tuple(m): i for i, m in enumerate(self.merges)}

    @property
    def vocab_size(self) -> int:
        return N_SPECIAL + 256 + len(self.merges)

    # ---- training -----------------------------------------------------
    @classmethod
    def train(cls, corpus: Iterable[str], n_merges: int = 0) -> "ByteTokenizer":
        tok = cls()
        if n_merges <= 0:
            return tok
        seqs = [tok._bytes(s) for s in corpus]
        merges: List[Tuple[int, int]] = []
        for _ in range(n_merges):
            counts = collections.Counter()
            for seq in seqs:
                counts.update(zip(seq, seq[1:]))
            if not counts:
                break
            pair, n = counts.most_common(1)[0]
            if n < 2:
                break
            new_id = N_SPECIAL + 256 + len(merges)
            merges.append(pair)
            seqs = [cls._apply_merge(seq, pair, new_id) for seq in seqs]
        return cls(merges)

    @staticmethod
    def _apply_merge(seq, pair, new_id):
        out, i = [], 0
        while i < len(seq):
            if i + 1 < len(seq) and (seq[i], seq[i + 1]) == pair:
                out.append(new_id)
                i += 2
            else:
                out.append(seq[i])
                i += 1
        return out

    # ---- encode / decode ------------------------------------------------
    def _bytes(self, text: str) -> List[int]:
        return [N_SPECIAL + b for b in text.encode("utf-8")]

    def encode(self, text: str, bos: bool = False, eos: bool = False):
        seq = self._bytes(text)
        for rank, pair in enumerate(self.merges):
            seq = self._apply_merge(seq, pair, N_SPECIAL + 256 + rank)
        if bos:
            seq = [BOS] + seq
        if eos:
            seq = seq + [EOS]
        return seq

    def _expand(self, tid: int) -> bytes:
        if tid < N_SPECIAL:
            return b""
        if tid < N_SPECIAL + 256:
            return bytes([tid - N_SPECIAL])
        a, b = self.merges[tid - N_SPECIAL - 256]
        return self._expand(a) + self._expand(b)

    def decode(self, ids: Iterable[int]) -> str:
        return b"".join(self._expand(int(t)) for t in ids).decode(
            "utf-8", errors="replace")

    # ---- persistence ----------------------------------------------------
    def save(self, path: str):
        with open(path, "w") as f:
            json.dump({"merges": self.merges}, f)

    @classmethod
    def load(cls, path: str) -> "ByteTokenizer":
        with open(path) as f:
            d = json.load(f)
        return cls([tuple(m) for m in d["merges"]])
