"""Datasets + packing dataloader.

LMDataset   — next-token language modeling over a text corpus (WikiText-style
              task; reports loss/PPL like the paper's text-generation track).
QADataset   — instruction QA (CHQA / multiple-choice style): loss masked over
              the prompt, computed on the answer tokens only.
packed_batches — fixed-shape (batch, seq) batches with shifted labels, -1 at
              ignored positions, deterministic epoch shuffling.
"""
from __future__ import annotations

from typing import Dict, Iterator, Sequence

import numpy as np

from repro.data.tokenizer import PAD, ByteTokenizer

IGNORE = -1


class LMDataset:
    def __init__(self, text: str, tokenizer: ByteTokenizer, seq_len: int):
        self.tok = tokenizer
        ids = tokenizer.encode(text, bos=True, eos=True)
        n = (len(ids) - 1) // seq_len
        self.seq_len = seq_len
        ids = np.asarray(ids[: n * seq_len + 1], np.int32)
        self.inputs = ids[:-1].reshape(n, seq_len)
        self.targets = ids[1:].reshape(n, seq_len)

    def __len__(self):
        return len(self.inputs)

    def example(self, i: int) -> Dict[str, np.ndarray]:
        return {"tokens": self.inputs[i], "labels": self.targets[i]}


class QADataset:
    """Each item: loss on answer tokens only (prompt labels = IGNORE)."""

    def __init__(self, pairs: Sequence[Dict[str, str]],
                 tokenizer: ByteTokenizer, seq_len: int):
        self.tok = tokenizer
        self.seq_len = seq_len
        self.items = []
        for p in pairs:
            q = tokenizer.encode("Q: " + p["question"] + "\nA: ", bos=True)
            a = tokenizer.encode(p["answer"], eos=True)
            ids = (q + a)[:seq_len + 1]
            toks = np.full(seq_len + 1, PAD, np.int32)
            toks[: len(ids)] = ids
            labels = np.full(seq_len, IGNORE, np.int32)
            # labels are next-token targets; answer region starts at len(q)-1
            astart = min(len(q) - 1, seq_len)
            aend = min(len(ids) - 1, seq_len)
            labels[astart:aend] = toks[astart + 1: aend + 1]
            self.items.append({"tokens": toks[:seq_len], "labels": labels})

    def __len__(self):
        return len(self.items)

    def example(self, i: int):
        return self.items[i]


def packed_batches(dataset, batch_size: int, *, seed: int = 0,
                   epochs: int = 1, drop_last: bool = True
                   ) -> Iterator[Dict[str, np.ndarray]]:
    n = len(dataset)
    for epoch in range(epochs):
        order = np.random.default_rng(seed + epoch).permutation(n)
        for i in range(0, n - (batch_size - 1 if drop_last else 0),
                       batch_size):
            idx = order[i: i + batch_size]
            if len(idx) < batch_size:
                if drop_last:
                    break
                idx = np.concatenate([idx, order[: batch_size - len(idx)]])
            exs = [dataset.example(int(j)) for j in idx]
            yield {k: np.stack([e[k] for e in exs]) for k in exs[0]}
