"""Offline corpora: a WikiText-like synthetic text stream and the paper's
CHQA (Campus Health QA) template pipeline (§5.2).

The container has no network, so WikiText-2 itself cannot be downloaded; we
generate a deterministic pseudo-natural corpus with Zipfian vocabulary and
sentence structure — sufficient for the correctness-style experiments the
paper runs (loss/PPL decreasing, Full-FT vs LoRA comparisons), which depend
on the *pipeline*, not on the particular English text.

CHQA generation follows the paper exactly: GPT-generated *templates* with
abstract slots (no personal data), filled locally from per-user wearable
statistics drawn from a per-user random stream; 5 categories.
"""
from __future__ import annotations

import numpy as np

_SUBJECTS = ("the model", "a system", "the network", "this method", "the device",
             "a framework", "the runtime", "an agent", "the pipeline",
             "the dataset", "a kernel", "the scheduler", "this paper",
             "the memory", "a battery", "the processor", "an operator")
_VERBS = ("improves", "reduces", "computes", "stores", "updates", "evaluates",
          "streams", "shards", "accumulates", "checkpoints", "schedules",
          "monitors", "fine-tunes", "quantizes", "profiles", "compiles")
_OBJECTS = ("the gradients", "attention scores", "parameter segments",
            "activation memory", "the optimizer state", "training loss",
            "energy consumption", "peak usage", "the learning rate",
            "token embeddings", "the key cache", "batch statistics",
            "layer outputs", "residual streams", "expert routing")
_MODIFIERS = ("efficiently", "on device", "during training", "at runtime",
              "per step", "with low overhead", "under constraints",
              "in parallel", "incrementally", "asynchronously")


def synthetic_wikitext(n_sentences: int = 2000, seed: int = 0) -> str:
    """Deterministic Zipf-weighted pseudo-text."""
    rng = np.random.default_rng(seed)

    def pick(options):
        # Zipf-ish: earlier entries more likely
        w = 1.0 / (1 + np.arange(len(options)))
        w /= w.sum()
        return options[rng.choice(len(options), p=w)]

    sents = []
    for _ in range(n_sentences):
        s = f"{pick(_SUBJECTS)} {pick(_VERBS)} {pick(_OBJECTS)}"
        if rng.random() < 0.6:
            s += f" {pick(_MODIFIERS)}"
        if rng.random() < 0.3:
            s += f" and {pick(_VERBS)} {pick(_OBJECTS)}"
        sents.append(s.capitalize() + ".")
    return " ".join(sents)


# ----------------------------------------------------------------------------
# CHQA templates (paper §5.2 / Appendix E)
# ----------------------------------------------------------------------------
CHQA_CATEGORIES = ("activity_summary", "goal_adjustment", "habit_coaching",
                   "metric_insight", "plan_recommendation")

_TEMPLATES = {
    "activity_summary": (
        "Have I been moving enough recently?",
        "Yes. Your recent activity level looks {level}, with an average of "
        "{steps} steps per day and a {trend} percent change compared with "
        "your previous stretch. Keep the pace steady."),
    "goal_adjustment": (
        "Should my current step goal be higher or lower?",
        "A realistic goal would be around {goal} steps per day. This is "
        "slightly below your recent average of {steps}, so it remains "
        "achievable while encouraging consistency."),
    "habit_coaching": (
        "Do my recent activity habits look regular?",
        "Your overall level is {level}, but the pattern fluctuates between "
        "regular days and peak days near {peak} steps. Keep a stable daily "
        "floor rather than relying on occasional highs."),
    "metric_insight": (
        "Can you interpret my recent activity intensity?",
        "Your intensity looks {level}. Over {days} logged days you averaged "
        "{steps} steps and {calories} active calories per day, which "
        "suggests consistent activity."),
    "plan_recommendation": (
        "Based on this step pattern, how far should I run tomorrow morning?",
        "A conservative run of {km} kilometers would be reasonable. Your "
        "recent average of {steps} steps is already {trend} percent higher "
        "than before, so maintain consistency rather than adding load."),
}


def chqa_pairs(user_id: int, n_pairs: int = 64, seed: int = 0):
    """Per-user QA pairs: templates filled from that user's synthetic
    wearable-statistics stream (records never leave this function — the
    privacy structure of the paper's pipeline)."""
    rng = np.random.default_rng(seed * 1000 + user_id)
    base_steps = rng.integers(6000, 14000)
    out = []
    for i in range(n_pairs):
        cat = CHQA_CATEGORIES[i % len(CHQA_CATEGORIES)]
        steps = int(base_steps + rng.integers(-1500, 2500))
        stats = {
            "steps": steps,
            "peak": int(steps * rng.uniform(1.2, 1.6)),
            "trend": int(rng.integers(-20, 80)),
            "days": int(rng.integers(3, 7)),
            "calories": int(steps * 0.025),
            "goal": int(steps * 0.92 // 100 * 100),
            "km": round(float(rng.uniform(1.5, 3.0)), 1),
            "level": rng.choice(["strong", "moderate", "relatively high"]),
        }
        q, a = _TEMPLATES[cat]
        out.append({"category": cat, "question": q,
                    "answer": a.format(**stats), "user": user_id})
    return out
