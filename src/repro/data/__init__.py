from repro.data.tokenizer import ByteTokenizer  # noqa: F401
from repro.data.dataset import LMDataset, QADataset, packed_batches  # noqa: F401
