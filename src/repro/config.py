"""Configuration dataclasses for the repro framework.

One ``ModelConfig`` covers every assigned architecture family; family-specific
fields are simply unused by other families.  ``TrainConfig`` carries the
resource-aware runtime knobs that reproduce the paper's optimization chain
(①memory-efficient attention ②activation checkpointing ③gradient accumulation
④parameter sharding) plus the energy governor (§4.2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 256
    head_dim: int = 0              # 0 -> d_model // n_heads
    max_seq_len: int = 4096

    # --- activation / norm flavour ---
    mlp_variant: str = "swiglu"    # swiglu | gelu | geglu
    norm_variant: str = "rmsnorm"  # rmsnorm | layernorm
    qkv_bias: bool = False          # qwen1.5 style
    attn_out_bias: bool = False
    qk_norm: bool = False           # gemma3 style per-head RMS on q/k
    mlp_bias: bool = False
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- positional encoding ---
    rope_theta: float = 10000.0
    pos_variant: str = "rope"      # rope | mrope | learned | none
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # qwen2-vl t/h/w split of head_dim/2

    # --- attention pattern ---
    sliding_window: int = 0        # 0 -> full attention
    global_layer_every: int = 0    # hybrid: stride of full-attention layers

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / hymba) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    enc_seq_ratio: int = 4         # encoder frames = seq // ratio (conv stub downsample)

    # --- vlm ---
    n_vision_tokens: int = 0       # patch-embedding stub tokens prepended

    # --- hybrid (hymba) ---
    n_meta_tokens: int = 0         # learnable meta tokens prepended

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 256 so the unembedding TP-shards on
        any mesh (MaxText-standard; pad logits are masked in unembed)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (no full-attention S^2 term)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (exact for our construction)."""
        from repro.param import tree_param_count
        from repro.models import registry
        return tree_param_count(registry.param_specs(self))

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6*N_active*D model flops)."""
        if self.family != "moe" or self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        # expert ffn params counted total; replace with top_k/ n_experts share
        expert_ffn = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active_ffn = self.n_layers * self.top_k * 3 * self.d_model * self.d_ff
        return total - expert_ffn + active_ffn


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False
    # axis sizes are fixed by the harness: (2,16,16) or (16,16)
    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class TrainConfig:
    # --- batch geometry ---
    global_batch: int = 8
    seq_len: int = 128
    microbatches: int = 1          # paper C2: gradient accumulation steps

    # --- optimizer ---
    learning_rate: float = 2e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 10
    total_steps: int = 100
    schedule: str = "cosine"       # cosine | linear | constant

    # --- dtype policy ---
    param_dtype: str = "float32"     # master weights
    compute_dtype: str = "bfloat16"  # activations / matmuls
    grad_reduce_dtype: str = ""      # "" -> compute dtype; "bfloat16" = compression

    # --- resource-aware runtime (the paper's optimization chain) ---
    attention_impl: str = "streaming"  # naive | streaming (alias: ref) |
                                       # flash (Pallas kernel)   (paper C4)
    remat_policy: str = "none"         # none | dots | full        (paper C3)
    shard_preset: str = "fsdp_tp"      # dp | fsdp | tp | fsdp_tp | fsdp_dp (C1)
    moe_dispatch_dtype: str = ""       # "" -> compute; float8_e4m3fn halves a2a
    moe_seq_chunks: int = 1            # sequence-chunked MoE (bounds expert
                                       # hidden/dispatch buffers at long seq)
    donate: bool = True
    scan_layers: bool = True
    attn_chunk: int = 512              # streaming attention KV-chunk

    # --- segment-wise parameter offload (paper C1, phone realization) ---
    offload_segments: int = 0          # 0 -> in-memory; N -> page (p,m,v) to N segment files
    offload_dir: str = ""              # "" -> <out_dir>/offload (or runs/offload)
    offload_resident: int = 2          # LRU window size in segments
    offload_prefetch: bool = True      # background double-buffered prefetch
    offload_stream_params: bool = False  # layer-streamed fwd/bwd: segments are
                                       # layer-aligned (one per block + head) and
                                       # params page through the window during
                                       # compute, not just the optimizer update
    offload_moment_dtype: str = "float32"  # float32 | bfloat16 (halves m/v segment
                                       # bytes; bf16 segment codec, fp32 math)
    offload_async_writeback: bool = True  # bounded background dirty-segment
                                       # writer: eviction no longer blocks on
                                       # encode+msync (flush/snapshot barrier)
    offload_staging: bool = True       # double-buffered host->device staging:
                                       # block i+1 converts to device arrays
                                       # while block i computes (the deferred
                                       # loss/grad-norm syncs are always on)
    base_quant: str = ""               # "" | int8: quantize the *frozen* base
                                       # segments of streamed LoRA per channel
                                       # (QLoRA-style; ~4x less flash + window)
    offload_activations: bool = False  # spill layer-boundary activations to a
                                       # per-step scratch store during the
                                       # forward sweep, re-pulled in reverse
                                       # order for backward — resident acts
                                       # stop scaling with depth (long seq)
    activation_codec: str = "fp32"     # fp32 | bf16 | int8 (per-token absmax)
                                       # storage precision of spilled acts;
                                       # fp32 is a bit-exact spill
    offload_io: str = ""               # segment read backend: "" (defer to
                                       # $REPRO_OFFLOAD_IO, else mmap) | mmap |
                                       # pread | direct (O_DIRECT) | uring |
                                       # auto (probe uring -> direct -> pread);
                                       # all backends are bit-identical

    # --- LoRA (paper C6) ---
    lora_rank: int = 0                 # 0 -> Full-FT
    lora_alpha: float = 32.0
    lora_dropout: float = 0.0
    lora_targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo")

    # --- energy governor (paper C5) ---
    energy_check_every: int = 1        # K
    energy_threshold: float = 0.60     # mu (battery fraction)
    energy_reduction: float = 0.50     # rho

    # --- fault tolerance ---
    checkpoint_every: int = 0          # 0 -> disabled
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3

    @property
    def micro_batch(self) -> int:
        assert self.global_batch % self.microbatches == 0
        return self.global_batch // self.microbatches


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16,
            "float8_e4m3fn": jnp.float8_e4m3fn}[name]


# ----------------------------------------------------------------------------
# Input shape suites assigned by the harness (per-arch cells).
# ----------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def cells_for(cfg: ModelConfig):
    """The (shape) cells that apply to an architecture.

    long_500k requires sub-quadratic attention (prompt rule) — skipped for
    pure full-attention archs and recorded as such in the roofline table.
    """
    out = []
    for name, spec in SHAPES.items():
        if name == "long_500k" and not cfg.sub_quadratic:
            out.append((name, "SKIP(full-attention)"))
        else:
            out.append((name, "RUN"))
    return out
