"""Pure-jnp oracle for the flash-attention kernel.

Full-materialization exact attention in fp32 with the same mask semantics
(causal / sliding window / kv_len padding / GQA / q_offset).  This is the
ground truth the Pallas kernel is swept against (shapes x dtypes x flags).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                  kv_len=None):
    """q: (B, H, Sq, D); k, v: (B, KVH, Skv, D) -> (B, H, Sq, D) fp32."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    if kv_len is None:
        kv_len = skv
    qf = q.astype(jnp.float32).reshape(b, kvh, g, sq, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) * (d ** -0.5)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(skv)
    m = (k_pos < kv_len)[None, :]
    if causal:
        m = m & (q_pos[:, None] >= k_pos[None, :])
    if window > 0:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksd->bkgqd", p, vf)
    return o.reshape(b, h, sq, d)
