"""Pallas TPU flash-attention kernels (paper C4, TPU-native adaptation).

MobileFineTuner §4.1.4 streams *one query row* at a time on a phone CPU and
recomputes row softmax statistics in the backward pass.  On TPU the same
exact-attention algorithm is re-blocked so the MXU sees 128-aligned
(block_q x block_k) tiles staged through VMEM:

  forward   online softmax over kv blocks; scratch carries (m, l, acc) across
            the sequential kv grid dimension; emits O and the LSE.
  backward  recomputes P = exp(S - LSE) blockwise (nothing quadratic is ever
            stored — exactly the paper's recompute strategy) and accumulates
            dQ, dK, dV.

Layouts: q (B, H, Sq, D); k, v (B, KVH, Skv, D); GQA maps q-head h to kv-head
h // (H // KVH) inside the BlockSpec index maps.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pos(i, block, n, offset=0):
    return offset + i * block + jax.lax.broadcasted_iota(jnp.int32, (block, 1), 0)[:, 0]


def _mask_block(iq, ik, *, block_q, block_k, causal, window, q_offset, kv_len):
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    m = k_pos < kv_len
    if causal:
        m = m & (q_pos >= k_pos)
    if window > 0:
        m = m & (q_pos - k_pos < window)
    return m


# ----------------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------------
def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale, causal, window, q_offset,
                kv_len, block_q, block_k, n_kv):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)            # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask_block(pl.program_id(2), ik, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, q_offset=q_offset,
                       kv_len=kv_len)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
    m_scr[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _out():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(denom)).astype(lse_ref.dtype)


def flash_fwd(q, k, v, *, scale, causal, window, q_offset, kv_len,
              block_q=128, block_k=128, interpret=False):
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    nq = sq // block_q
    nk = skv // block_k
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, block_q=block_q, block_k=block_k,
        n_kv=nk)
    out_shape = [
        jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        jax.ShapeDtypeStruct((b, h, sq), jnp.float32),
    ]
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, iq, ik: (b_, h_, iq)),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ----------------------------------------------------------------------------
# Backward: recompute P blockwise from (q, k, LSE) — paper §4.1.4 strategy
# ----------------------------------------------------------------------------
def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               dq_scr, *, scale, causal, window, q_offset, kv_len,
               block_q, block_k, n_kv):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask_block(pl.program_id(2), ik, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, q_offset=q_offset,
                       kv_len=kv_len)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dq_scr[...] += jax.lax.dot(ds, k)

    @pl.when(ik == n_kv - 1)
    def _out():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, dk_scr, dv_scr, *, scale, causal, window,
                q_offset, kv_len, block_q, block_k, n_q):
    iq = pl.program_id(3)
    ik = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0].astype(jnp.float32)
    delta = delta_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
    mask = _mask_block(iq, ik, block_q=block_q, block_k=block_k,
                       causal=causal, window=window, q_offset=q_offset,
                       kv_len=kv_len)
    s = jnp.where(mask, s, NEG_INF)
    p = jnp.exp(s - lse[:, None])                   # (BQ, BK)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
    ds = p * (dp - delta[:, None]) * scale
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0,), (0,)), ((), ())))

    @pl.when(iq == n_q - 1)
    def _out():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


def flash_bwd(q, k, v, o, lse, do, *, scale, causal, window, q_offset,
              kv_len, block_q=128, block_k=128, interpret=False):
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    nq, nk = sq // block_q, skv // block_k
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, n_kv=nk),
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, iq, ik: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, iq, ik: (b_, h_, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, iq, ik: (b_, h_, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v, do, lse, delta)

    # dk/dv computed per q-head then group-summed (GQA)
    dk_h, dv_h = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, kv_len=kv_len,
                          block_q=block_q, block_k=block_k, n_q=nq),
        grid=(b, h, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, ik, iq: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ik, iq: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ik, iq: (b_, h_ // g, ik, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b_, h_, ik, iq: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, ik, iq: (b_, h_, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b_, h_, ik, iq: (b_, h_, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ik, iq: (b_, h_, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b_, h_, ik, iq: (b_, h_, ik, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        out_shape=[jax.ShapeDtypeStruct((b, h, skv, d), q.dtype),
                   jax.ShapeDtypeStruct((b, h, skv, d), q.dtype)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    dk = dk_h.reshape(b, kvh, g, skv, d).sum(axis=2)
    dv = dv_h.reshape(b, kvh, g, skv, d).sum(axis=2)
    return dq, dk, dv
