"""jit'd wrapper for the Pallas flash-attention kernel with custom_vjp.

Public entry: ``flash_attention(q, k, v, ...)`` in the model layout
(B, S, H, D) — transposes to the kernel layout, pads sequences to block
multiples, and installs the recompute backward (paper §4.1.4).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention as K


class _Meta(NamedTuple):
    scale: float
    causal: bool
    window: int
    q_offset: int
    kv_len: int
    block_q: int
    block_k: int
    interpret: bool


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash(q, k, v, meta: _Meta):
    o, _ = K.flash_fwd(q, k, v, scale=meta.scale, causal=meta.causal,
                       window=meta.window, q_offset=meta.q_offset,
                       kv_len=meta.kv_len, block_q=meta.block_q,
                       block_k=meta.block_k, interpret=meta.interpret)
    return o


def _flash_fwd_rule(q, k, v, meta: _Meta):
    o, lse = K.flash_fwd(q, k, v, scale=meta.scale, causal=meta.causal,
                         window=meta.window, q_offset=meta.q_offset,
                         kv_len=meta.kv_len, block_q=meta.block_q,
                         block_k=meta.block_k, interpret=meta.interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd_rule(meta: _Meta, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = K.flash_bwd(q, k, v, o, lse, do, scale=meta.scale,
                             causal=meta.causal, window=meta.window,
                             q_offset=meta.q_offset, kv_len=meta.kv_len,
                             block_q=meta.block_q, block_k=meta.block_k,
                             interpret=meta.interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret", "q_offset"))
def flash_attention(q, k, v, *, q_pos=None, kv_pos=None, causal=True,
                    window=0, q_offset=-1, block_q=128, block_k=128,
                    interpret=False):
    """Model-layout entry: q (B, Sq, H, D); k, v (B, Skv, KVH, D).

    Positions are assumed contiguous: q at offset (Skv - Sq) by default
    (training: 0; decode: cache length), kv at 0..Skv.  ``q_pos``/``kv_pos``
    are accepted for API parity with core.attention but must follow that
    contiguous pattern (asserted by the allclose test suite, not at runtime —
    they may be traced).
    """
    b, sq, h, d = q.shape
    skv = k.shape[1]
    if q_offset < 0:
        q_offset = skv - sq
    scale = d ** -0.5
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq = min(block_q, max(_next_pow2(sq), 8))
    bk = min(block_k, max(_next_pow2(skv), 8))
    qt = _pad_to(qt, 2, bq)
    kt = _pad_to(kt, 2, bk)
    vt = _pad_to(vt, 2, bk)
    meta = _Meta(scale=scale, causal=causal, window=window,
                 q_offset=q_offset, kv_len=skv, block_q=bq, block_k=bk,
                 interpret=interpret)
    o = _flash(qt, kt, vt, meta)
    return o[:, :, :sq].transpose(0, 2, 1, 3).astype(q.dtype)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p
