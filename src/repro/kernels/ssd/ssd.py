"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk quadratic block.

The chunkwise SSD algorithm's hot spot is the per-chunk quadratic form
(scores = C B^T masked by the decay kernel L) — an attention-shaped matmul
that belongs on the MXU.  Grid = (B*NH, n_chunks); each program holds one
(Q, HD) x-tile, one (Q, DS) B/C tile in VMEM and emits:

  y_intra (Q, HD)   the within-chunk output contribution
  state   (HD, DS)  this chunk's local state contribution
  cs      (Q,)      cumulative log-decay (host combines chunks: the tiny
                    inter-chunk recurrence + cross-chunk y term stay in jnp)

The cumulative sum is computed as tril-ones @ dA — a matmul, not a serial
scan, so it also maps to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, da_ref, b_ref, c_ref,
                y_ref, state_ref, cs_ref, *, chunk):
    x = x_ref[0, 0].astype(jnp.float32)      # (Q, HD)
    dt = dt_ref[0, 0].astype(jnp.float32)    # (Q,)
    da = da_ref[0, 0].astype(jnp.float32)    # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)      # (Q, DS)
    c = c_ref[0, 0].astype(jnp.float32)      # (Q, DS)

    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cs = jax.lax.dot(tril, da[:, None])[:, 0]            # inclusive cumsum
    lmat = jnp.exp(cs[:, None] - cs[None, :])             # decay j -> i
    lmat = jnp.where(jnp.tril(jnp.ones((chunk, chunk), bool)), lmat, 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # (Q, Q)
    m = scores * lmat
    y_ref[0, 0] = jax.lax.dot(m, x * dt[:, None]).astype(y_ref.dtype)

    decay_to_end = jnp.exp(cs[-1] - cs)                   # sum_{m>q} da_m
    w = dt * decay_to_end                                  # (Q,)
    state = jax.lax.dot_general(x * w[:, None], b,
                                (((0,), (0,)), ((), ())))  # (HD, DS)
    state_ref[0, 0] = state.astype(state_ref.dtype)
    cs_ref[0, 0] = cs.astype(cs_ref.dtype)


def ssd_intra(xh, dt, dA, B_, C_, *, chunk, interpret=False):
    """xh: (BH, n, Q, HD); dt, dA: (BH, n, Q); B_, C_: (G, n, Q, DS) where
    BH = B * NH and G = B (B/C shared across heads; index map bh -> bh // NH
    handled by the caller reshaping, here BH == G * NH)."""
    bh, n, q, hd = xh.shape
    g = B_.shape[0]
    nh = bh // g
    ds = B_.shape[-1]

    kernel = functools.partial(_ssd_kernel, chunk=q)
    y, state, cs = pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[
            pl.BlockSpec((1, 1, q, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1, q, ds), lambda i, j: (i // nh, j, 0, 0)),
            pl.BlockSpec((1, 1, q, ds), lambda i, j: (i // nh, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, hd, ds), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, n, q, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, hd, ds), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, q), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dt, dA, B_, C_)
    return y, state, cs
