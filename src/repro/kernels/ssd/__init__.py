from repro.kernels.ssd.ops import ssd_chunked_pallas  # noqa: F401
