"""jit'd wrapper: full chunked SSD via the Pallas intra-chunk kernel.

Same contract as models/mamba2.ssd_chunked: the kernel computes the per-chunk
quadratic part + local chunk states; the (tiny) inter-chunk recurrence and
cross-chunk output term are composed here in jnp.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_intra


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(xh, dt, A, B_, C_, chunk: int, initial_state=None,
                       interpret: bool = False):
    """xh: (B, S, NH, HD); dt: (B, S, NH) positive; A: (NH,) negative;
    B_, C_: (B, S, DS).  Returns (y (B,S,NH,HD) fp32, final (B,NH,HD,DS))."""
    b, s, nh, hd = xh.shape
    ds = B_.shape[-1]
    n = s // chunk
    assert n * chunk == s, (s, chunk)

    dtf = dt.astype(jnp.float32)
    dA = dtf * A                                           # (B, S, NH)
    # kernel layout: (B*NH, n, Q, ...)
    xk = xh.transpose(0, 2, 1, 3).reshape(b * nh, n, chunk, hd)
    dtk = dtf.transpose(0, 2, 1).reshape(b * nh, n, chunk)
    dak = dA.transpose(0, 2, 1).reshape(b * nh, n, chunk)
    bk = B_.reshape(b, n, chunk, ds)
    ck = C_.reshape(b, n, chunk, ds)

    y_intra, states, cs = ssd_intra(xk.astype(jnp.float32), dtk, dak,
                                    bk.astype(jnp.float32),
                                    ck.astype(jnp.float32),
                                    chunk=chunk, interpret=interpret)

    # inter-chunk recurrence (sequential over n, tiny state)
    chunk_decay = jnp.exp(cs[:, :, -1])                    # (BH, n)
    s0 = (initial_state.astype(jnp.float32).reshape(b * nh, hd, ds)
          if initial_state is not None
          else jnp.zeros((b * nh, hd, ds), jnp.float32))

    def body(prev, inp):
        st, dec = inp
        return prev * dec[:, None, None] + st, prev

    final, prev_states = jax.lax.scan(
        body, s0, (states.transpose(1, 0, 2, 3), chunk_decay.T))
    prev_states = prev_states.transpose(1, 0, 2, 3)        # (BH, n, HD, DS)

    # cross-chunk contribution: y_q += C_q . prev_state * exp(cs_q)
    decay_from_start = jnp.exp(cs)                         # (BH, n, Q)
    ck_h = jnp.broadcast_to(
        ck.astype(jnp.float32)[:, None], (b, nh, n, chunk, ds)
    ).reshape(b * nh, n, chunk, ds)
    y_inter = jnp.einsum("gnqs,gnhs,gnq->gnqh", ck_h, prev_states,
                         decay_from_start)

    y = (y_intra + y_inter).reshape(b, nh, s, hd).transpose(0, 2, 1, 3)
    return y, final.reshape(b, nh, hd, ds)
