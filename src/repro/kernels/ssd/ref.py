"""Pure-jnp oracle for the SSD kernel: the *definitional* sequential SSM.

    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t (x) B_t
    y_t = C_t . h_t  (+ no D/residual here — that lives in the model layer)

This is the strongest possible reference: both the chunked jnp implementation
(models/mamba2.ssd_chunked) and the Pallas kernel must match it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(xh, dt, A, B_, C_, initial_state=None):
    """xh: (B, S, NH, HD); dt: (B, S, NH); A: (NH,); B_, C_: (B, S, DS).

    Returns y: (B, S, NH, HD) fp32, final_state: (B, NH, HD, DS) fp32.
    """
    b, s, nh, hd = xh.shape
    ds = B_.shape[-1]
    xh = xh.astype(jnp.float32)
    dt = dt.astype(jnp.float32)
    B_ = B_.astype(jnp.float32)
    C_ = C_.astype(jnp.float32)
    h0 = (initial_state.astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, nh, hd, ds), jnp.float32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp           # (B,NH,HD), (B,NH), (B,DS), (B,DS)
        decay = jnp.exp(dt_t * A)           # (B, NH)
        upd = jnp.einsum("bhp,bh,bs->bhps", x_t, dt_t, b_t)
        h = h * decay[:, :, None, None] + upd
        y_t = jnp.einsum("bhps,bs->bhp", h, c_t)
        return h, y_t

    xs = (xh.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B_.transpose(1, 0, 2), C_.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2, 3), h
