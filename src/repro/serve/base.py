"""Base-weight providers for the serving engine.

The engine walks the model per block (repro/serve/program.py), so all it
needs from the base is ``block(i)`` / ``head()`` plus pipeline hints.  Two
providers share that interface:

- ``InMemoryBase``   an ordinary param pytree, pre-split per block once
- ``StreamedBase``   a frozen ``LayerStreamedState`` — block segments pull
  through the read-only offload window (int8-resident when quantized; the
  program dequantizes inside the jit), and the head segment is *pinned*
  in the window: it is touched twice per decode step (input embedding +
  logits head), and without the pin the layer walk would evict it every
  step, paying a head-segment re-read per token.

``StreamedBase`` runs the decode-side half of PR 5's trainer overlap
pipeline (core/stream.py), three deep and three *threads* deep: the
prefetcher pages segment ``i+2`` in from flash, a dedicated staging worker
pulls block ``i+1`` through the window and converts its leaves to device
arrays, and the main thread dispatches block ``i``'s compute.  ``stage(i)``
only *submits* the conversion; ``block(i)`` joins the future — so the
host->device copy genuinely runs on another core while the engine
dispatches, instead of merely being reordered on the dispatch thread
(which buys nothing: the conversion serializes either way).  Every window
``acquire`` is routed through the single staging worker, so the offload
engine never sees concurrent pulls.  At most two staged blocks are alive,
and the head device tree is staged **once per run** — the frozen base
never changes, so re-converting embed/ln_f every step was pure
host->device traffic.  ``staging=False`` keeps the fully synchronous walk
(the bench's sync-vs-staged comparison row).

The flash side of the walk rides the store's pluggable read backend
(``io_backend`` on the ``LayerStreamedState`` constructors, or
``$REPRO_OFFLOAD_IO``): with ``pread``/``uring`` each block pull reads
straight into the window's recycled buffers instead of faulting through
the page cache — ``stats()`` carries the backend name (``io_backend``)
and the reader's ``io_*`` counters alongside the engine's.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict

import jax


class InMemoryBase:
    """Shared fp32/bf16 base held fully in memory."""

    base_quant = ""

    def __init__(self, params):
        blocks = params["blocks"]
        self.n_layers = int(jax.tree.leaves(blocks)[0].shape[0])
        self._blocks = [jax.tree.map(lambda a, i=i: a[i], blocks)
                        for i in range(self.n_layers)]
        self._head = {k: v for k, v in params.items() if k != "blocks"}

    def block(self, i: int):
        return self._blocks[i]

    def head(self):
        return self._head

    def prefetch(self, i: int):
        pass

    def stage(self, i: int):
        pass

    def stats(self):
        return {}

    def close(self):
        pass


class StreamedBase:
    """Frozen base streamed from layer-aligned segment files (read-only
    window, shared by every request).  Owns the ``LayerStreamedState`` it
    wraps: ``close()`` closes it."""

    def __init__(self, lstate, *, staging: bool = True):
        if not getattr(lstate, "frozen", False):
            raise ValueError("StreamedBase requires a frozen (read-only) "
                             "layer-streamed store; got a trainable layout")
        self.lstate = lstate
        self.base_quant = lstate.base_quant or ""
        self.n_layers = int(lstate.n_layers)
        self.staging = bool(staging)
        # the staged-future map is touched from the dispatch thread while
        # the worker completes futures, and close() may race a late
        # stage() — the only shared mutable state here, so it gets a lock
        self._lock = threading.Lock()
        self._staged: Dict[int, Future] = {}  # guarded-by: _lock
        self._closed = False                  # guarded-by: _lock
        self._head_dev = None                 # head tree, staged once per run
        self.t_h2d_s = 0.0                    # host->device conversion time
        # one worker: window pulls + conversions run off the dispatch
        # thread, and the offload engine never sees concurrent acquires
        self._worker = ThreadPoolExecutor(max_workers=1) if self.staging \
            else None
        # the head segment is hot on every step — exempt it from LRU
        lstate.engine.pin(lstate.head_segment)

    # ------------------------------------------------------------------
    def _timed_pull(self, fn):
        """Window pull + device conversion, billing only the *conversion*
        share to ``t_h2d_s`` — the engine already bills its own acquire
        wait to ``t_read_block_s``, and the breakdown must not
        double-count (same discipline as core/stream.py)."""
        eng = self.lstate.engine
        t0 = time.perf_counter()
        b0 = eng.t_read_block_s + eng.t_write_block_s
        out = fn()
        blocked = (eng.t_read_block_s + eng.t_write_block_s) - b0
        self.t_h2d_s += max(0.0, (time.perf_counter() - t0) - blocked)
        return out

    def _pull_block(self, i: int):
        return self._timed_pull(lambda: self.lstate.layer_params(i))

    def block(self, i: int):
        """Block ``i``'s device param tree: join the staged future when the
        pipeline ran ahead, else pull + convert (still via the worker, so
        acquires stay single-threaded)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("StreamedBase is closed")
            fut = self._staged.pop(i, None)
        if fut is not None:
            return fut.result()
        if self._worker is not None:
            return self._worker.submit(self._pull_block, i).result()
        return self._pull_block(i)

    def head(self):
        if not self.staging:
            return self.lstate.head_params()
        if self._head_dev is None:
            self._head_dev = self._worker.submit(
                self._timed_pull, self.lstate.head_params).result()
        return self._head_dev

    def prefetch(self, i: int):
        if 0 <= i < self.n_layers:
            self.lstate.prefetch_layer(i)

    def stage(self, i: int):
        """Queue block ``i``'s window pull + host->device conversion on the
        staging worker — called right after the previous block's compute is
        dispatched, so the copy runs on another core while that compute
        (and the engine's dispatch loop) proceed.  Bounded to two staged
        blocks (the one consumed next and this one)."""
        if not self.staging or not (0 <= i < self.n_layers):
            return
        with self._lock:
            if self._closed or i in self._staged:
                return  # closed: a late stage() must not resurrect the pool
            self._staged[i] = self._worker.submit(self._pull_block, i)
            while len(self._staged) > 2:
                # dropped futures are cache evictions, not lost errors: a
                # failed pull re-raises when block(i) re-pulls it
                self._staged.pop(next(iter(self._staged)))

    def stats(self):
        s = dict(self.lstate.stats())
        s["stage_h2d_s"] = self.t_h2d_s
        # flash-level reads of the pinned head segment: 1 initial read,
        # zero re-reads, or the pin is broken (tested under window
        # pressure in tests/test_paged_serving.py)
        s["head_reads"] = self.lstate.engine.seg_misses.get(
            self.lstate.head_segment, 0)
        # the one non-numeric stat: which transport served the walk (the
        # serving bench prints it next to the per-backend read rows)
        s["io_backend"] = self.lstate.engine.store.io_backend
        return s

    def close(self):
        """Shutdown ordering: mark closed (so no new stage() lands), drain
        the worker (so no pull is mid-flight when the store unmaps), then
        release the window.  An in-flight stage future that failed is
        re-raised *after* cleanup — a conversion error must not vanish
        with the pool, and must not leak the store either."""
        with self._lock:
            already = self._closed
            self._closed = True
            staged = list(self._staged.values())
            self._staged.clear()
        if self._worker is not None:
            # drain in-flight conversions before the store goes away
            self._worker.shutdown(wait=True)
            self._worker = None
        self._head_dev = None
        if not already:
            self.lstate.engine.unpin(self.lstate.head_segment)
            self.lstate.close()
        err = next((f.exception() for f in staged
                    if f.done() and not f.cancelled() and f.exception()),
                   None)
        if err is not None:
            raise RuntimeError("staged block pull failed during close") \
                from err
