"""Base-weight providers for the serving engine.

The engine walks the model per block (repro/serve/program.py), so all it
needs from the base is ``block(i)`` / ``head()`` plus a prefetch hint.  Two
providers share that interface:

- ``InMemoryBase``   an ordinary param pytree, pre-split per block once
- ``StreamedBase``   a frozen ``LayerStreamedState`` — block segments pull
  through the read-only offload window (int8-resident when quantized; the
  program dequantizes inside the jit), ``prefetch`` double-buffers the next
  block behind the current block's compute, and the head segment is *pinned*
  in the window: it is touched twice per decode step (input embedding +
  logits head), and without the pin the layer walk would evict it every
  step, paying a head-segment re-read per token.
"""
from __future__ import annotations

import jax


class InMemoryBase:
    """Shared fp32/bf16 base held fully in memory."""

    base_quant = ""

    def __init__(self, params):
        blocks = params["blocks"]
        self.n_layers = int(jax.tree.leaves(blocks)[0].shape[0])
        self._blocks = [jax.tree.map(lambda a, i=i: a[i], blocks)
                        for i in range(self.n_layers)]
        self._head = {k: v for k, v in params.items() if k != "blocks"}

    def block(self, i: int):
        return self._blocks[i]

    def head(self):
        return self._head

    def prefetch(self, i: int):
        pass

    def stats(self):
        return {}

    def close(self):
        pass


class StreamedBase:
    """Frozen base streamed from layer-aligned segment files (read-only
    window, shared by every request).  Owns the ``LayerStreamedState`` it
    wraps: ``close()`` closes it."""

    def __init__(self, lstate):
        if not getattr(lstate, "frozen", False):
            raise ValueError("StreamedBase requires a frozen (read-only) "
                             "layer-streamed store; got a trainable layout")
        self.lstate = lstate
        self.base_quant = lstate.base_quant or ""
        self.n_layers = int(lstate.n_layers)
        # the head segment is hot on every step — exempt it from LRU
        lstate.engine.pin(lstate.head_segment)

    def block(self, i: int):
        return self.lstate.layer_params(i)

    def head(self):
        return self.lstate.head_params()

    def prefetch(self, i: int):
        if 0 <= i < self.n_layers:
            self.lstate.prefetch_layer(i)

    def stats(self):
        return self.lstate.stats()

    def close(self):
        self.lstate.engine.unpin(self.lstate.head_segment)
        self.lstate.close()
