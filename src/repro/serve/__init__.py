"""Streamed multi-adapter serving tier (paper end goal: phones that both
fine-tune and *use* personalized models).

One shared read-only base — in-memory, or streamed through the offload
window with the int8 codec — serves many concurrent users, each with their
own tiny ``adapter.safetensors``:

- ``ServeProgram``  per-block jitted decode/prefill entry points, vmapped
  over batch rows with per-row LoRA adapters (rows with different adapters
  decode together in one dispatch)
- ``ServeEngine``   continuous batching over paged KV cache slots —
  requests join/leave mid-flight, chunked prefill interleaves with decode
- ``PagePool``      fixed-size-page KV accounting (per-slot page tables,
  lifetime reservation at admit, backpressure on exhaustion)
- ``AdapterCache``  bounded LRU of loaded adapters with hot-swap, validated
  against the base (``base_tag``/``peft_meta``)
- ``InMemoryBase`` / ``StreamedBase``  base-weight providers
"""
from repro.serve.adapters import AdapterCache
from repro.serve.base import InMemoryBase, StreamedBase
from repro.serve.engine import Request, ServeEngine
from repro.serve.paged import PagePool
from repro.serve.program import ServeProgram, make_serve_program

__all__ = ["AdapterCache", "InMemoryBase", "StreamedBase", "PagePool",
           "Request", "ServeEngine", "ServeProgram", "make_serve_program"]
