"""Continuous-batching serve engine with per-request cache slots.

A fixed number of ``slots`` share one batched decode program.  Requests
join and leave mid-flight:

  submit() -> queue -> [admit: slot = prefill] -> chunked prefill, one
  (1, chunk) slab per engine step, interleaved with everyone else's decode
  -> [slot = active: joins the batched decode] -> max_new tokens reached
  -> emit + recycle the slot for the next queued request

Prefill runs at batch 1 through the *same* per-block program as decode
(exact numerics), against a private single-row cache; on completion the row
is scattered into the slot's rows of the shared cache (donated jit, so the
big cache updates in place) and the slot enters the decode batch.  Decode
runs all active slots in one dispatch — per-row adapters, per-row sequence
positions — while free/prefilling rows ride along as masked-out lanes
(their outputs are discarded; their cache rows are fully overwritten by the
next admit's scatter).

Greedy decoding only, and one merge geometry (rank/alpha/targets) per
engine — per-request sampling temperatures and mixed adapter ranks are out
of scope for this tier.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.lora import stack_adapters
from repro.models import mamba2
from repro.models import transformer as T
from repro.serve.adapters import AdapterCache
from repro.serve.base import InMemoryBase, StreamedBase
from repro.serve.program import make_serve_program


@dataclass
class Request:
    rid: Any
    tokens: Sequence[int]          # prompt token ids
    max_new: int = 16              # generated tokens (incl. first argmax)
    adapter: Optional[str] = None  # path to adapter.safetensors, or None


@dataclass
class _Slot:
    state: str = "free"            # free | prefill | active
    req: Optional[Request] = None
    prompt: Optional[np.ndarray] = None
    filled: int = 0                # tokens currently in this row's cache
    pcache: Optional[list] = None  # rows=1 per-layer cache during prefill
    lora: Any = None               # this request's (unstacked) adapter tree
    row_blocks: Optional[list] = None   # lora pre-split per block, rows=1
    row_head: Any = None
    last_tok: int = 0
    generated: List[int] = field(default_factory=list)


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_row(big, row, j):
    """Write a rows=1 prefill cache leaf into slot row ``j`` of the shared
    cache leaf (donated: updates in place)."""
    return jax.lax.dynamic_update_slice(
        big, row.astype(big.dtype), (j,) + (0,) * (row.ndim - 1))


def _layer_cache(cfg: ModelConfig, rows: int, max_len: int):
    """One layer's cache leaves with a leading slot-row axis."""
    c: Dict[str, Any] = {}
    if cfg.family != "ssm":
        kv = (rows, max_len, cfg.n_kv_heads, cfg.head_dim)
        c["k"] = jnp.zeros(kv, jnp.float32)
        c["v"] = jnp.zeros(kv, jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        conv_ch = mamba2.d_inner(cfg) + 2 * cfg.ssm_state
        c["conv"] = jnp.zeros((rows, cfg.ssm_conv_width - 1, conv_ch),
                              jnp.float32)
        c["ssm"] = jnp.zeros((rows, mamba2.n_ssm_heads(cfg),
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return c


def _split_adapter(tree, n_layers: int):
    """Stacked adapter tree -> (per-block trees, head tree).  Block leaves
    carry (rows, L, ...); the per-block slice is (rows, ...)."""
    if not isinstance(tree, dict):
        tree = {}
    blk = tree.get("blocks", {})
    head = {k: v for k, v in tree.items() if k != "blocks"}
    per_block = [jax.tree.map(lambda a, i=i: a[:, i], blk)
                 for i in range(n_layers)]
    return per_block, head


class ServeEngine:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, base, *,
                 slots: int = 4, max_len: int = 256, chunk: int = 16,
                 adapters: Optional[AdapterCache] = None):
        if cfg.family == "encdec":
            raise ValueError("ServeEngine drives decoder-only families")
        if isinstance(base, dict):
            base = InMemoryBase(base)
        elif not hasattr(base, "block"):
            base = StreamedBase(base)
        self.cfg, self.tcfg = cfg, tcfg
        self.base = base
        self.adapters = adapters
        if adapters is not None and \
                adapters.base_quant != (base.base_quant or ""):
            raise ValueError(
                f"AdapterCache expects base_quant "
                f"{adapters.base_quant or 'fp32'!r} but the serving base is "
                f"{base.base_quant or 'fp32'!r}")
        rank = adapters.rank if adapters else 0
        alpha = adapters.alpha if adapters else 0.0
        self.program = make_serve_program(cfg, tcfg, rank=rank, alpha=alpha,
                                          base_quant=base.base_quant)
        self.n_slots = int(slots)
        self.max_len = int(max_len)
        self.chunk = max(1, int(chunk))
        self.n_layers = base.n_layers
        self.slots = [_Slot() for _ in range(self.n_slots)]
        self.cache = [_layer_cache(cfg, self.n_slots, self.max_len)
                      for _ in range(self.n_layers)]
        self._windows = [jnp.asarray(w, jnp.int32)
                         for w in np.asarray(T.layer_windows(cfg))]
        self._queue: "deque[Request]" = deque()
        self._zero = adapters.zero() if adapters else {}
        self._stack_dirty = True
        self._stack_blocks: Optional[list] = None
        self._stack_head: Any = None
        # --- statistics ---
        self.admitted = 0
        self.completed = 0
        self.decode_steps = 0
        self.decoded_tokens = 0
        self.prefill_chunks = 0
        self.peak_active = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        plen = len(req.tokens)
        if plen < 1 or req.max_new < 1:
            raise ValueError("a request needs >=1 prompt and >=1 new token")
        if plen + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + max_new {req.max_new} "
                f"exceeds the engine's max_len {self.max_len}")
        if req.adapter is not None and self.adapters is None:
            raise ValueError(f"request {req.rid} carries an adapter but the "
                             "engine was built without an AdapterCache")
        self._queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        for j, slot in enumerate(self.slots):
            if not self._queue:
                break
            if slot.state != "free":
                continue
            req = self._queue.popleft()
            slot.state = "prefill"
            slot.req = req
            slot.prompt = np.asarray(req.tokens, np.int32)
            slot.filled = 0
            slot.generated = []
            slot.pcache = [_layer_cache(self.cfg, 1, self.max_len)
                           for _ in range(self.n_layers)]
            if self.adapters is not None:
                slot.lora = (self.adapters.get(req.adapter)
                             if req.adapter else self.adapters.zero())
            else:
                slot.lora = {}
            # pre-split the rows=1 adapter once; reused for every chunk
            row = jax.tree.map(lambda a: a[None], slot.lora)
            slot.row_blocks, slot.row_head = _split_adapter(
                row, self.n_layers)
            self.admitted += 1
            self._stack_dirty = True

    def _prefill_step(self, j: int, slot: _Slot, head_bp):
        p = slot.prompt
        cs = min(self.chunk, len(p) - slot.filled)
        slab = jnp.asarray(p[None, slot.filled:slot.filled + cs], jnp.int32)
        idx = jnp.full((1,), slot.filled, jnp.int32)
        self.base.prefetch(0)
        x = self.program.embed(head_bp, slot.row_head, slab, idx)
        for i in range(self.n_layers):
            self.base.prefetch(i + 1)
            x, slot.pcache[i] = self.program.block(
                self.base.block(i), slot.row_blocks[i], x, slot.pcache[i],
                idx, self._windows[i])
        slot.filled += cs
        self.prefill_chunks += 1
        if slot.filled < len(p):
            return
        # prefill complete: first generated token + scatter into the slot
        logits = self.program.head(head_bp, slot.row_head, x)   # (1, vocab)
        slot.last_tok = int(jnp.argmax(logits[0], -1))
        slot.generated = [slot.last_tok]
        jj = jnp.int32(j)
        for i in range(self.n_layers):
            self.cache[i] = jax.tree.map(
                lambda big, row: _scatter_row(big, row, jj),
                self.cache[i], slot.pcache[i])
        slot.pcache = None
        slot.state = "active"
        slot.row_blocks = slot.row_head = None
        self._stack_dirty = True

    def _restack(self):
        trees = [s.lora if s.state != "free" and s.lora is not None
                 else self._zero for s in self.slots]
        if self.adapters is None:
            self._stack_blocks = [{} for _ in range(self.n_layers)]
            self._stack_head = {}
        else:
            stacked = stack_adapters(trees)
            self._stack_blocks, self._stack_head = _split_adapter(
                stacked, self.n_layers)
        self._stack_dirty = False

    def _decode_step(self, active: List[int], head_bp):
        if self._stack_dirty:
            self._restack()
        toks = np.zeros((self.n_slots, 1), np.int32)
        idxs = np.zeros((self.n_slots,), np.int32)
        for j in active:
            toks[j, 0] = self.slots[j].last_tok
            idxs[j] = self.slots[j].filled
        toks = jnp.asarray(toks)
        idxs = jnp.asarray(idxs)
        self.base.prefetch(0)
        x = self.program.embed(head_bp, self._stack_head, toks, idxs)
        for i in range(self.n_layers):
            self.base.prefetch(i + 1)
            x, self.cache[i] = self.program.block(
                self.base.block(i), self._stack_blocks[i], x, self.cache[i],
                idxs, self._windows[i])
        logits = self.program.head(head_bp, self._stack_head, x)
        nxt = np.asarray(jnp.argmax(logits, -1))        # (slots,)
        self.decode_steps += 1
        self.decoded_tokens += len(active)
        for j in active:
            slot = self.slots[j]
            slot.filled += 1
            tok = int(nxt[j])
            slot.generated.append(tok)
            slot.last_tok = tok

    def _reap(self, finished: list):
        for j, slot in enumerate(self.slots):
            if slot.state == "active" and \
                    len(slot.generated) >= slot.req.max_new:
                finished.append({"rid": slot.req.rid,
                                 "tokens": np.asarray(slot.generated[
                                     :slot.req.max_new], np.int32)})
                self.completed += 1
                self.slots[j] = _Slot()
                self._stack_dirty = True

    # ------------------------------------------------------------------
    def step(self) -> list:
        """One engine iteration: admit from the queue, advance every
        prefilling slot by one chunk, run one batched decode step over the
        active slots, emit finished requests.  Returns the finished list."""
        finished: list = []
        self._admit()
        head_bp = self.base.head()
        for j, slot in enumerate(self.slots):
            if slot.state == "prefill":
                self._prefill_step(j, slot, head_bp)
        self._reap(finished)     # max_new == 1 finishes straight off prefill
        active = [j for j, s in enumerate(self.slots) if s.state == "active"]
        self.peak_active = max(self.peak_active, len(active))
        if active:
            self._decode_step(active, head_bp)
            self._reap(finished)
        return finished

    def run(self, max_steps: int = 100000) -> Dict[Any, np.ndarray]:
        """Drive ``step()`` until the queue and every slot drain; returns
        {rid: generated token ids}."""
        out: Dict[Any, np.ndarray] = {}
        for _ in range(max_steps):
            if not self._queue and \
                    all(s.state == "free" for s in self.slots):
                return out
            for r in self.step():
                out[r["rid"]] = r["tokens"]
        raise RuntimeError(f"ServeEngine.run did not drain in {max_steps} "
                           "steps")

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        s = {"admitted": self.admitted, "completed": self.completed,
             "decode_steps": self.decode_steps,
             "decoded_tokens": self.decoded_tokens,
             "prefill_chunks": self.prefill_chunks,
             "peak_active": self.peak_active}
        if self.adapters is not None:
            s.update(self.adapters.stats())
        s.update({"base_" + k: v for k, v in self.base.stats().items()})
        return s

    def close(self):
        self.base.close()
