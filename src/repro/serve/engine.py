"""Continuous-batching serve engine with paged KV cache slots.

A fixed number of ``slots`` share one batched decode program.  Requests
join and leave mid-flight:

  submit() -> queue -> [admit: reserve pages, slot = prefill] -> chunked
  prefill, one (1, chunk) slab per engine step, interleaved with everyone
  else's decode -> [slot = active: joins the batched decode] -> max_new
  tokens reached -> emit + release pages + recycle the slot

Prefill runs at batch 1 through the *same* per-block program as decode
(exact numerics) and writes its k/v **directly into the shared page pools**
through the slot's page table — no private prefill cache, no per-layer
scatter pass at completion (only the tiny recurrent ssm state keeps a
private rows=1 buffer, scattered once when prefill finishes).  Decode runs
all active slots in one dispatch — per-row adapters, per-row sequence
positions — while free/prefilling rows ride along as masked-out lanes:
their page-table rows are masked to the sentinel page, so their garbage
writes can never land in pages a live request owns.

**Paged KV** (repro/serve/paged.py): instead of a dense worst-case
``(slots, max_len, ...)`` cache per layer, each layer owns a pool of
fixed-size pages and each slot a page table.  Admission reserves a
request's full lifetime of pages up front (``ceil((plen + max_new - 1) /
page_size)``) — a request that doesn't fit *waits in the queue*
(backpressure) instead of being rejected, long and short requests share
one pool, and concurrency scales with pool memory rather than with
``slots x max_len``.

**Deferred host syncs**: the per-step ``argmax`` stays on device —
``_last_dev`` is a ``(slots,)`` device vector fed straight back into the
next decode dispatch, and each step appends the vector to a host-side
trace.  Tokens materialize in **one** ``np.asarray`` pull at ``_reap``
(when a request actually finishes), so the decode loop runs dispatch-only:
with a ``StreamedBase`` the flash read + h2d staging of block ``i+1``
genuinely overlap block ``i``'s compute instead of serializing on a
per-token host round trip.

Greedy decoding only, and one merge geometry (rank/alpha/targets) per
engine — per-request sampling temperatures and mixed adapter ranks are out
of scope for this tier.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.lora import stack_adapters
from repro.models import mamba2
from repro.models import transformer as T
from repro.serve.adapters import AdapterCache
from repro.serve.base import InMemoryBase, StreamedBase
from repro.serve.paged import PagePool
from repro.serve.program import make_serve_program


@dataclass
class Request:
    rid: Any
    tokens: Sequence[int]          # prompt token ids
    max_new: int = 16              # generated tokens (incl. first argmax)
    adapter: Optional[str] = None  # path to adapter.safetensors, or None


@dataclass
class _Slot:
    state: str = "free"            # free | prefill | active
    req: Optional[Request] = None
    prompt: Optional[np.ndarray] = None
    filled: int = 0                # tokens currently in this row's cache
    pcache: Optional[list] = None  # rows=1 recurrent (ssm) prefill cache
    lora: Any = None               # this request's (unstacked) adapter tree
    row_blocks: Optional[list] = None   # lora pre-split per block, rows=1
    row_head: Any = None
    n_gen: int = 0                 # tokens generated (incl. first argmax)
    generated: List[int] = field(default_factory=list)  # host-side, filled
    #                                                     at trace flushes


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_row(big, row, j):
    """Write a rows=1 prefill cache leaf into slot row ``j`` of the shared
    cache leaf (donated: updates in place)."""
    return jax.lax.dynamic_update_slice(
        big, row.astype(big.dtype), (j,) + (0,) * (row.ndim - 1))


@jax.jit
def _set_first(last, logits, j):
    """Record slot ``j``'s first generated token (prefill-completion argmax)
    in the device last-token vector — no host sync."""
    return last.at[j].set(jnp.argmax(logits[0], -1).astype(last.dtype))


@jax.jit
def _next_toks(last, logits, mask):
    """One decode step's next-token vector: argmax where the lane is a live
    request, the previous value elsewhere — stays on device."""
    return jnp.where(mask, jnp.argmax(logits, -1).astype(last.dtype), last)


def _recurrent_cache(cfg: ModelConfig, rows: int):
    """One layer's per-row recurrent leaves (ssm/hybrid families); the k/v
    of attention families live in the shared page pools instead."""
    c: Dict[str, Any] = {}
    if cfg.family in ("ssm", "hybrid"):
        conv_ch = mamba2.d_inner(cfg) + 2 * cfg.ssm_state
        c["conv"] = jnp.zeros((rows, cfg.ssm_conv_width - 1, conv_ch),
                              jnp.float32)
        c["ssm"] = jnp.zeros((rows, mamba2.n_ssm_heads(cfg),
                              cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    return c


def _split_adapter(tree, n_layers: int):
    """Stacked adapter tree -> (per-block trees, head tree).  Block leaves
    carry (rows, L, ...); the per-block slice is (rows, ...)."""
    if not isinstance(tree, dict):
        tree = {}
    blk = tree.get("blocks", {})
    head = {k: v for k, v in tree.items() if k != "blocks"}
    per_block = [jax.tree.map(lambda a, i=i: a[:, i], blk)
                 for i in range(n_layers)]
    return per_block, head


class ServeEngine:
    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, base, *,
                 slots: int = 4, max_len: int = 256, chunk: int = 16,
                 adapters: Optional[AdapterCache] = None,
                 page_size: int = 16, pool_pages: Optional[int] = None,
                 defer_tokens: bool = True):
        if cfg.family == "encdec":
            raise ValueError("ServeEngine drives decoder-only families")
        if isinstance(base, dict):
            base = InMemoryBase(base)
        elif not hasattr(base, "block"):
            base = StreamedBase(base)
        self.cfg, self.tcfg = cfg, tcfg
        self.base = base
        self.adapters = adapters
        if adapters is not None and \
                adapters.base_quant != (base.base_quant or ""):
            raise ValueError(
                f"AdapterCache expects base_quant "
                f"{adapters.base_quant or 'fp32'!r} but the serving base is "
                f"{base.base_quant or 'fp32'!r}")
        rank = adapters.rank if adapters else 0
        alpha = adapters.alpha if adapters else 0.0
        self.program = make_serve_program(cfg, tcfg, rank=rank, alpha=alpha,
                                          base_quant=base.base_quant)
        self.n_slots = int(slots)
        self.max_len = int(max_len)
        self.chunk = max(1, int(chunk))
        self.n_layers = base.n_layers
        self.slots = [_Slot() for _ in range(self.n_slots)]
        # recurrent (ssm) leaves keep the dense per-row layout — they are
        # O(1) in sequence length
        self.cache = [_recurrent_cache(cfg, self.n_slots)
                      for _ in range(self.n_layers)]
        # paged k/v pools for attention families; pool_pages defaults to
        # the dense-equivalent capacity slots * ceil(max_len / page_size)
        self.paged = cfg.family != "ssm"
        self.pool: Optional[PagePool] = None
        self.kv_pools: Optional[list] = None
        if self.paged:
            psz = max(1, int(page_size))
            width = -(-self.max_len // psz)
            usable = int(pool_pages) if pool_pages is not None \
                else self.n_slots * width
            self.pool = PagePool(n_pages=usable + 1, page_size=psz,
                                 slots=self.n_slots, table_width=width)
            shape = (usable + 1, psz, cfg.n_kv_heads, cfg.head_dim)
            self.kv_pools = [{"k": jnp.zeros(shape, jnp.float32),
                              "v": jnp.zeros(shape, jnp.float32)}
                             for _ in range(self.n_layers)]
        # per-layer device constants, uploaded once at construction
        self._windows = [jnp.asarray(w, jnp.int32)
                         for w in np.asarray(T.layer_windows(cfg))]
        self._queue: "deque[Request]" = deque()
        self._zero = adapters.zero() if adapters else {}
        self._stack_dirty = True
        self._stack_blocks: Optional[list] = None
        self._stack_head: Any = None
        # deferred decode syncs: device last-token vector + host trace of
        # (device token vector, active slot ids) per step, flushed in one
        # np.asarray pull when a request finishes.  defer_tokens=False
        # flushes every step instead — the pre-staging decode discipline
        # (bench_serving's unstaged row measures what deferral buys)
        self.defer_tokens = bool(defer_tokens)
        self._last_dev = jnp.zeros((self.n_slots,), jnp.int32)
        self._trace: List[tuple] = []
        # --- statistics ---
        self.admitted = 0
        self.completed = 0
        self.decode_steps = 0
        self.decoded_tokens = 0
        self.prefill_chunks = 0
        self.peak_active = 0
        self.t_decode_s = 0.0          # decode dispatch + trace-flush wall
        self.t_prefill_s = 0.0         # prefill dispatch wall

    # ------------------------------------------------------------------
    def _pages_for(self, req: Request) -> int:
        # cache positions written over the request's lifetime: the prompt
        # plus every generated token except the last (never fed back)
        return self.pool.pages_for(len(req.tokens) + req.max_new - 1)

    def submit(self, req: Request):
        plen = len(req.tokens)
        if plen < 1 or req.max_new < 1:
            raise ValueError("a request needs >=1 prompt and >=1 new token")
        if plen + req.max_new > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {plen} + max_new {req.max_new} "
                f"exceeds the per-request cap max_len {self.max_len} "
                f"(the page-table width)")
        if self.paged and self._pages_for(req) > self.pool.usable_pages:
            raise ValueError(
                f"request {req.rid} needs {self._pages_for(req)} pages but "
                f"the pool holds {self.pool.usable_pages} — it could never "
                f"be admitted")
        if req.adapter is not None and self.adapters is None:
            raise ValueError(f"request {req.rid} carries an adapter but the "
                             "engine was built without an AdapterCache")
        self._queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        for j, slot in enumerate(self.slots):
            if not self._queue:
                break
            if slot.state != "free":
                continue
            req = self._queue[0]
            if self.paged:
                need = self._pages_for(req)
                if not self.pool.can_admit(need):
                    # admission backpressure: the request waits for pages
                    # (FIFO — later, smaller requests do not starve it)
                    self.pool.admission_waits += 1
                    break
                self.pool.allocate(j, need)
            self._queue.popleft()
            slot.state = "prefill"
            slot.req = req
            slot.prompt = np.asarray(req.tokens, np.int32)
            slot.filled = 0
            slot.n_gen = 0
            slot.generated = []
            slot.pcache = [_recurrent_cache(self.cfg, 1)
                           for _ in range(self.n_layers)] \
                if self.cfg.family in ("ssm", "hybrid") else None
            if self.adapters is not None:
                slot.lora = (self.adapters.get(req.adapter)
                             if req.adapter else self.adapters.zero())
            else:
                slot.lora = {}
            # pre-split the rows=1 adapter once; reused for every chunk
            row = jax.tree.map(lambda a: a[None], slot.lora)
            slot.row_blocks, slot.row_head = _split_adapter(
                row, self.n_layers)
            self.admitted += 1
            self._stack_dirty = True

    def _block_call(self, i: int, blora, x, tab, idx, cache):  # hot-path
        """One per-layer block dispatch, routing the family's cache
        arguments; returns the new activations (pools/cache updated)."""
        bp = self.base.block(i)
        win = self._windows[i]
        fam = self.cfg.family
        if fam == "ssm":
            x, new = self.program.block(bp, blora, x, cache[i], idx, win)
            cache[i] = new
            return x
        pools = self.kv_pools[i]
        if fam == "hybrid":
            x, pk, pv, new = self.program.block(
                bp, blora, x, pools["k"], pools["v"], tab, idx, win,
                cache[i])
            cache[i] = new
        else:
            x, pk, pv = self.program.block(
                bp, blora, x, pools["k"], pools["v"], tab, idx, win)
        pools["k"], pools["v"] = pk, pv
        return x

    def _prefill_step(self, j: int, slot: _Slot, head_bp):  # hot-path
        p = slot.prompt
        cs = min(self.chunk, len(p) - slot.filled)
        slab = jnp.asarray(p[None, slot.filled:slot.filled + cs], jnp.int32)
        idx = jnp.full((1,), slot.filled, jnp.int32)
        tab = jnp.asarray(self.pool.tables[j:j + 1]) if self.paged else None
        self.base.prefetch(0)
        x = self.program.embed(head_bp, slot.row_head, slab, idx)
        cache = slot.pcache
        for i in range(self.n_layers):
            self.base.prefetch(i + 1)
            x = self._block_call(i, slot.row_blocks[i], x, tab, idx, cache)
            self.base.stage(i + 1)
        slot.filled += cs
        self.prefill_chunks += 1
        if slot.filled < len(p):
            self.base.prefetch(0)
            self.base.stage(0)
            return
        # prefill complete: first generated token (deferred — stays a device
        # value in _last_dev) + scatter the recurrent rows into the slot
        logits = self.program.head(head_bp, slot.row_head, x)   # (1, vocab)
        self._last_dev = _set_first(self._last_dev, logits, jnp.int32(j))
        self._trace.append((self._last_dev, (j,)))
        slot.n_gen = 1
        if slot.pcache is not None:
            jj = jnp.int32(j)
            for i in range(self.n_layers):
                self.cache[i] = jax.tree.map(
                    lambda big, row: _scatter_row(big, row, jj),
                    self.cache[i], slot.pcache[i])
            slot.pcache = None
        slot.state = "active"
        slot.row_blocks = slot.row_head = None
        self._stack_dirty = True
        self.base.prefetch(0)
        self.base.stage(0)

    def _restack(self):
        trees = [s.lora if s.state != "free" and s.lora is not None
                 else self._zero for s in self.slots]
        if self.adapters is None:
            self._stack_blocks = [{} for _ in range(self.n_layers)]
            self._stack_head = {}
        else:
            stacked = stack_adapters(trees)
            self._stack_blocks, self._stack_head = _split_adapter(
                stacked, self.n_layers)
        self._stack_dirty = False

    def _decode_step(self, active: List[int], head_bp):  # hot-path
        if self._stack_dirty:
            self._restack()
        idxs = np.zeros((self.n_slots,), np.int32)
        mask = np.zeros((self.n_slots,), bool)
        for j in active:
            idxs[j] = self.slots[j].filled
            mask[j] = True
        idx = jnp.asarray(idxs)
        tab = None
        if self.paged:
            # inactive lanes (free / still-prefilling slots riding the
            # dispatch) write through the sentinel page: zero their table
            # rows so lane garbage never lands in pages a request owns
            tab = jnp.asarray(
                np.where(mask[:, None], self.pool.tables, 0))
        # the previous step's tokens feed back as a device vector — no
        # host argmax sync anywhere in the decode loop
        toks = self._last_dev[:, None]
        self.base.prefetch(0)
        x = self.program.embed(head_bp, self._stack_head, toks, idx)
        for i in range(self.n_layers):
            self.base.prefetch(i + 1)
            x = self._block_call(i, self._stack_blocks[i], x, tab, idx,
                                 self.cache)
            self.base.stage(i + 1)
        logits = self.program.head(head_bp, self._stack_head, x)
        self._last_dev = _next_toks(self._last_dev, logits,
                                    jnp.asarray(mask))
        self._trace.append((self._last_dev, tuple(active)))
        self.base.prefetch(0)
        self.base.stage(0)
        self.decode_steps += 1
        self.decoded_tokens += len(active)
        for j in active:
            self.slots[j].filled += 1
            self.slots[j].n_gen += 1
        if not self.defer_tokens:
            self._materialize()      # per-step host round trip (unstaged)

    def _materialize(self):  # hot-path
        """Flush the deferred token trace: one host pull for every step
        since the last flush (satellite of the deferred-argmax tentpole —
        bookkeeping is batched per *flush*, not per step per slot)."""
        if not self._trace:
            return
        t0 = time.perf_counter()
        arr = np.asarray(jnp.stack([t for t, _ in self._trace]))  # sync-point:
        #   the deferred-argmax flush — one pull amortized over the trace
        for k, (_, act) in enumerate(self._trace):
            for j in act:
                self.slots[j].generated.append(int(arr[k, j]))  # sync-point:
                #   host numpy indexing (arr already pulled above)
        self._trace.clear()
        self.t_decode_s += time.perf_counter() - t0

    def _reap(self, finished: list):
        if not any(s.state == "active" and s.n_gen >= s.req.max_new
                   for s in self.slots):
            return
        self._materialize()
        for j, slot in enumerate(self.slots):
            if slot.state == "active" and slot.n_gen >= slot.req.max_new:
                finished.append({"rid": slot.req.rid,
                                 "tokens": np.asarray(slot.generated[
                                     :slot.req.max_new], np.int32)})
                self.completed += 1
                if self.paged:
                    self.pool.release(j)
                self.slots[j] = _Slot()
                self._stack_dirty = True

    # ------------------------------------------------------------------
    def step(self) -> list:  # hot-path
        """One engine iteration: admit from the queue, advance every
        prefilling slot by one chunk, run one batched decode step over the
        active slots, emit finished requests.  Returns the finished list."""
        finished: list = []
        self._admit()
        t0 = time.perf_counter()
        head_bp = self.base.head()
        t_head = time.perf_counter() - t0    # per-step head pull: billed to
        #   whichever phase this step runs — with staging it is ~free after
        #   the first step; the sync walk re-converts the segment every step
        t0 = time.perf_counter()
        for j, slot in enumerate(self.slots):
            if slot.state == "prefill":
                self._prefill_step(j, slot, head_bp)
        self.t_prefill_s += time.perf_counter() - t0
        self._reap(finished)     # max_new == 1 finishes straight off prefill
        active = [j for j, s in enumerate(self.slots) if s.state == "active"]
        self.peak_active = max(self.peak_active, len(active))
        if active:
            t0 = time.perf_counter()
            self._decode_step(active, head_bp)
            self.t_decode_s += time.perf_counter() - t0 + t_head
            self._reap(finished)
        else:
            self.t_prefill_s += t_head
        return finished

    def run(self, max_steps: int = 100000) -> Dict[Any, np.ndarray]:
        """Drive ``step()`` until the queue and every slot drain; returns
        {rid: generated token ids}."""
        out: Dict[Any, np.ndarray] = {}
        for _ in range(max_steps):
            if not self._queue and \
                    all(s.state == "free" for s in self.slots):
                return out
            for r in self.step():
                out[r["rid"]] = r["tokens"]
        raise RuntimeError(f"ServeEngine.run did not drain in {max_steps} "
                           "steps")

    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        s = {"admitted": self.admitted, "completed": self.completed,
             "decode_steps": self.decode_steps,
             "decoded_tokens": self.decoded_tokens,
             "prefill_chunks": self.prefill_chunks,
             "peak_active": self.peak_active,
             "decode_wall_s": self.t_decode_s,
             "prefill_wall_s": self.t_prefill_s}
        if self.pool is not None:
            s.update(self.pool.stats())
        if self.adapters is not None:
            s.update(self.adapters.stats())
        s.update({"base_" + k: v for k, v in self.base.stats().items()})
        return s

    def close(self):
        self.base.close()
