"""Bounded LRU cache of loaded LoRA adapters, validated against the base.

"Millions of users = one shared base + millions of tiny adapters": the
serving engine can only hold a handful of adapters hot at once.  This cache
loads ``adapter.safetensors`` files on demand, keeps at most ``capacity``
resident (LRU hot-swap — evicting an adapter only drops its few-hundred-KB
tree; the request re-loads it on the next touch), and refuses any adapter
that does not match the serving base:

- ``rank`` / ``alpha`` / ``targets``: the decode program is compiled for one
  merge geometry; a mismatched adapter would need a different program
- ``base_quant``: an adapter trained against an int8 base learned around the
  quantization error and is NOT valid against the fp32 base (and vice versa)
- ``base_tag``: pins the exact frozen base (arch + seed + dtype + quant) —
  an adapter trained against a different base would merge garbage silently
- tree structure + leaf shapes must match the ``lora_specs`` template
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.safetensors import load_adapter
from repro.config import ModelConfig
from repro.core.lora import lora_specs, zero_adapter
from repro.models import registry
from repro.param import flatten_names, is_spec


class AdapterCache:
    def __init__(self, cfg: ModelConfig, *, rank: int, alpha: float,
                 targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo"),
                 base_quant: str = "", base_tag: str = "",
                 capacity: int = 4):
        assert rank > 0, "AdapterCache needs a positive LoRA rank"
        self.rank = int(rank)
        self.alpha = float(alpha)
        self.targets = tuple(targets)
        self.base_quant = base_quant or ""
        self.base_tag = base_tag or ""
        self.capacity = max(1, int(capacity))
        self._specs = registry.param_specs(cfg)
        template = lora_specs(self._specs, self.targets, self.rank)
        self._shapes = {n: s.shape for n, s in
                        flatten_names(template, is_leaf=is_spec)}
        self._zero = None
        # serving contract: get() runs on the engine-step thread only, so
        # the LRU OrderedDict is deliberately unlocked — _owner detects
        # concurrent entry instead of letting the dict corrupt silently
        # (audit: the single caller is ServeEngine.step's admit path)
        self._cache: "OrderedDict[str, object]" = OrderedDict()
        self._owner: Optional[int] = None
        self.loads = 0
        self.hits = 0
        self.evictions = 0

    # ------------------------------------------------------------------
    def _validate(self, path: str, meta: dict, lora):
        def fail(what, want, got):
            raise ValueError(
                f"adapter {path} does not match the serving base: {what} "
                f"is {got!r}, engine expects {want!r}")
        if meta["rank"] != self.rank:
            fail("lora_rank", self.rank, meta["rank"])
        if meta["alpha"] != self.alpha:
            fail("lora_alpha", self.alpha, meta["alpha"])
        if meta["targets"] and tuple(meta["targets"]) != self.targets:
            fail("lora_targets", self.targets, meta["targets"])
        if meta["base_quant"] != self.base_quant:
            fail("base_quant", self.base_quant or "fp32",
                 meta["base_quant"] or "fp32")
        if self.base_tag and meta["base_tag"] and \
                meta["base_tag"] != self.base_tag:
            fail("base_tag", self.base_tag, meta["base_tag"])
        got = {n: tuple(v.shape) for n, v in flatten_names(lora)}
        want = {n: tuple(s) for n, s in self._shapes.items()}
        if got != want:
            raise ValueError(
                f"adapter {path} tree does not match the engine's "
                f"lora_specs template (rank {self.rank}, targets "
                f"{self.targets}); got leaves {sorted(got)} vs expected "
                f"{sorted(want)}")

    # ------------------------------------------------------------------
    def get(self, path: str):
        """The adapter tree for ``path`` (loaded + validated on first touch,
        then LRU-resident until ``capacity`` newer adapters displace it).
        Single-owner-at-a-time: raises on concurrent entry from a second
        thread (the LRU mutation is not locked by design)."""
        me = threading.get_ident()
        owner = self._owner
        if owner is not None and owner != me:
            raise RuntimeError(
                f"concurrent AdapterCache.get(): thread {me} entered while "
                f"thread {owner} is inside — adapter admission is "
                "single-threaded (see CONCURRENCY.md)")
        self._owner = me
        try:
            hit = self._cache.get(path)
            if hit is not None:
                self.hits += 1
                self._cache.move_to_end(path)
                return hit
            lora, meta = load_adapter(path)
            self._validate(path, meta, lora)
            tree = jax.tree.map(lambda a: jnp.asarray(a, jnp.float32), lora)
            self.loads += 1
            self._cache[path] = tree
            while len(self._cache) > self.capacity:
                self._cache.popitem(last=False)
                self.evictions += 1
            return tree
        finally:
            self._owner = None

    def zero(self):
        """The all-zero adapter (b = 0, so W' = W bitwise) — used for batch
        rows that carry no adapter, keeping one decode program for all."""
        if self._zero is None:
            self._zero = zero_adapter(self._specs, self.targets, self.rank)
        return self._zero

    def stats(self):
        return {"adapter_loads": self.loads, "adapter_hits": self.hits,
                "adapter_evictions": self.evictions,
                "adapters_resident": len(self._cache)}
