"""Per-block multi-adapter serving program.

The in-memory decode path (``models/lm.py::decode_step``) scans the stacked
block tree — fine for one model, but multi-LoRA serving needs *per-row*
weights: every batch row may carry a different adapter.  Materializing a
merged tree per row would cost rows x model bytes, so this module re-expresses
decode as a per-block program (the serving analogue of
``lm.make_layer_program``):

  embed(head, head_lora, tokens (R, S), index (R,)) -> x (R, S, d)
  block(bp, block_lora, x, <cache args>, index (R,), window) -> (x, ...)
  head(head, head_lora, x) -> logits (R, vocab)   [last slab position]

Each entry point is ``jax.vmap``-ed over the row axis with the base tree
shared (``in_axes=None``) and the adapter/cache/index mapped per row, then
jitted.  ``merge_lora`` runs *inside* the jit, so per-row merged weights
exist only as XLA transients one block at a time — the same honesty rule the
training stack applies to int8 dequantization, which also composes here: with
``base_quant="int8"`` the base arguments arrive as (codes, scales) pairs
straight from the encoded offload window and are dequantized as the first op
of each entry point.

Per-row ``index`` (vs ``decode_step``'s shared scalar) is what lets rows at
*different* sequence positions decode in one dispatch — the continuous
batching engine (repro/serve/engine.py) relies on it.

Attention families run against a **paged KV cache**: the block entry point
takes the shared per-layer page pools (``pool_k``/``pool_v``, donated) plus
per-row page tables.  Inside the jit each row gathers its pages into a
contiguous strip (``lm.paged_gather``) and attends in ``cache_mode="append"``
(stale strip positions masked, fresh k/v appended with true positions); the
fresh k/v of *all* rows then scatter into the pools through the tables in
one batched indexed update (``lm.paged_scatter``) — writes stay outside the
vmap because vmapped writes to a shared pool have no batched meaning.  SSM
state is tiny and per-row, so the ssm/hybrid recurrent leaves keep the dense
per-row cache layout.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.core.lora import merge_lora
from repro.models import layers as L
from repro.models import lm
from repro.models import mamba2, moe as moe_mod
from repro.models import transformer as T
from repro.models.hymba import apply_hymba_block
from repro.offload.codecs import dequant_tree


class ServeProgram(NamedTuple):
    embed: Any
    block: Any
    head: Any


def make_serve_program(cfg: ModelConfig, tcfg: TrainConfig, *,
                       rank: int = 0, alpha: float = 0.0,
                       base_quant: str = "") -> ServeProgram:
    """Build the jitted per-block serving entry points.

    ``rank <= 0`` builds the adapterless program (the lora arguments are
    empty pytrees).  All blocks share one compilation per activation shape:
    the block entry point is jitted once and reused for every layer.
    """
    if cfg.family == "encdec":
        raise ValueError("the serving engine drives decoder-only families; "
                         "encdec (whisper) keeps the step-wise path")
    cd = dtype_of(tcfg.compute_dtype)
    fam = cfg.family
    base_of = dequant_tree if base_quant else (lambda t: t)

    def merged(bp, lora):
        bp = base_of(bp)
        if rank <= 0:
            return bp
        return merge_lora(bp, lora, rank=rank, alpha=alpha, train=False)

    def row_positions(idx, s):
        pos = idx + jnp.arange(s, dtype=jnp.int32)
        if cfg.pos_variant == "mrope":
            return jnp.broadcast_to(pos[None, None], (1, 3, s))
        return pos[None]

    # ------------------------------------------------------------------
    # per-row entry points (vmapped below; every array here is one row)
    # ------------------------------------------------------------------
    def embed_row(head, hlora, tok, idx):
        hp = merged(head, hlora)
        s = tok.shape[0]
        x = L.embed_tokens(hp["embed"], tok[None], cd)[0]       # (S, d)
        if cfg.pos_variant == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(
                hp["wpe"].astype(cd),
                jnp.minimum(idx, cfg.max_seq_len - s), s, axis=0)
        return x

    def ssm_block_row(bp, blora, x, cache, idx, window):
        lp = merged(bp, blora)
        x1 = x[None]                                            # (1, S, d)
        h, st = mamba2.apply_mamba(
            lp["mamba"], L.apply_norm(lp["ln1"], x1, cfg.norm_variant),
            cfg, tcfg,
            state={"conv": cache["conv"][None], "ssm": cache["ssm"][None]})
        return (x1 + h)[0], {"conv": st["conv"][0], "ssm": st["ssm"][0]}

    def head_row(head, hlora, x):
        hp = merged(head, hlora)
        xl = L.apply_norm(hp["ln_f"], x[-1:][None], cfg.norm_variant)
        logits = L.unembed(hp["embed"], xl.astype(jnp.float32),
                           cfg.tie_embeddings, cfg.logit_softcap,
                           cfg.vocab_size)
        return logits[0, 0]                                     # (vocab,)

    # ------------------------------------------------------------------
    # paged block (attention families): per-row gather + append-mode
    # attention inside the vmap, one batched pool scatter outside it
    # ------------------------------------------------------------------
    def paged_block(bp, blora, x, pool_k, pool_v, tables, idx, window,
                    cache):
        def attn_row(bl, x_r, tab_r, idx_r, cache_r):
            lp = merged(bp, bl)
            view = (lm.paged_gather(pool_k, tab_r)[None],
                    lm.paged_gather(pool_v, tab_r)[None])
            positions = row_positions(idx_r, x_r.shape[0])
            if fam == "moe":
                y, (kf, vf), _ = moe_mod.apply_moe_block(
                    lp, x_r[None], cfg, tcfg, positions=positions,
                    window=window, kv_cache=view, cache_index=idx_r,
                    cache_mode="append")
                return y[0], kf[0], vf[0], cache_r
            if fam == "hybrid":
                y, (kf, vf), st = apply_hymba_block(
                    lp, x_r[None], cfg, tcfg, positions=positions,
                    window=window, kv_cache=view, cache_index=idx_r,
                    cache_mode="append",
                    ssm_state={"conv": cache_r["conv"][None],
                               "ssm": cache_r["ssm"][None]})
                return y[0], kf[0], vf[0], {"conv": st["conv"][0],
                                            "ssm": st["ssm"][0]}
            y, (kf, vf) = T.apply_block(
                lp, x_r[None], cfg, tcfg, positions=positions,
                window=window, kv_cache=view, cache_index=idx_r,
                cache_mode="append")
            return y[0], kf[0], vf[0], cache_r

        y, kf, vf, new_cache = jax.vmap(
            attn_row, in_axes=(0, 0, 0, 0, 0))(blora, x, tables, idx, cache)
        pool_k = lm.paged_scatter(pool_k, tables, idx, kf)
        pool_v = lm.paged_scatter(pool_v, tables, idx, vf)
        if fam == "hybrid":
            return y, pool_k, pool_v, new_cache
        return y, pool_k, pool_v

    # the per-row recurrent cache (ssm) and the page pools are each
    # consumed exactly once per block call — donate them so the decode
    # loop updates the big buffers in place instead of doubling them
    if fam == "ssm":
        block = functools.partial(jax.jit, donate_argnums=(3,))(
            jax.vmap(ssm_block_row, in_axes=(None, 0, 0, 0, 0, None)))
    elif fam == "hybrid":
        block = functools.partial(jax.jit, donate_argnums=(3, 4, 8))(
            paged_block)
    else:
        def dense_block(bp, blora, x, pool_k, pool_v, tables, idx, window):
            y, pk, pv = paged_block(bp, blora, x, pool_k, pool_v, tables,
                                    idx, window, {})
            return y, pk, pv
        block = functools.partial(jax.jit, donate_argnums=(3, 4))(dense_block)
    return ServeProgram(
        embed=jax.jit(jax.vmap(embed_row, in_axes=(None, 0, 0, 0))),
        block=block,
        head=jax.jit(jax.vmap(head_row, in_axes=(None, 0, 0))),
    )
