"""Per-block multi-adapter serving program.

The in-memory decode path (``models/lm.py::decode_step``) scans the stacked
block tree — fine for one model, but multi-LoRA serving needs *per-row*
weights: every batch row may carry a different adapter.  Materializing a
merged tree per row would cost rows x model bytes, so this module re-expresses
decode as a per-block program (the serving analogue of
``lm.make_layer_program``):

  embed(head, head_lora, tokens (R, S), index (R,)) -> x (R, S, d)
  block(bp, block_lora, x, cache, index (R,), window) -> (x, new_cache)
  head(head, head_lora, x) -> logits (R, vocab)   [last slab position]

Each entry point is ``jax.vmap``-ed over the row axis with the base tree
shared (``in_axes=None``) and the adapter/cache/index mapped per row, then
jitted.  ``merge_lora`` runs *inside* the jit, so per-row merged weights
exist only as XLA transients one block at a time — the same honesty rule the
training stack applies to int8 dequantization, which also composes here: with
``base_quant="int8"`` the base arguments arrive as (codes, scales) pairs
straight from the encoded offload window and are dequantized as the first op
of each entry point.

Per-row ``index`` (vs ``decode_step``'s shared scalar) is what lets rows at
*different* sequence positions decode in one dispatch — the continuous
batching engine (repro/serve/engine.py) relies on it.  Numerics match
``decode_step`` exactly: same per-layer ops, same cache masking.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.core.lora import merge_lora
from repro.models import layers as L
from repro.models import mamba2, moe as moe_mod
from repro.models import transformer as T
from repro.models.hymba import apply_hymba_block
from repro.offload.codecs import dequant_tree


class ServeProgram(NamedTuple):
    embed: Any
    block: Any
    head: Any


def make_serve_program(cfg: ModelConfig, tcfg: TrainConfig, *,
                       rank: int = 0, alpha: float = 0.0,
                       base_quant: str = "") -> ServeProgram:
    """Build the jitted per-block serving entry points.

    ``rank <= 0`` builds the adapterless program (the lora arguments are
    empty pytrees).  All blocks share one compilation per activation shape:
    the block entry point is jitted once and reused for every layer.
    """
    if cfg.family == "encdec":
        raise ValueError("the serving engine drives decoder-only families; "
                         "encdec (whisper) keeps the step-wise path")
    cd = dtype_of(tcfg.compute_dtype)
    fam = cfg.family
    base_of = dequant_tree if base_quant else (lambda t: t)

    def merged(bp, lora):
        bp = base_of(bp)
        if rank <= 0:
            return bp
        return merge_lora(bp, lora, rank=rank, alpha=alpha, train=False)

    def row_positions(idx, s):
        pos = idx + jnp.arange(s, dtype=jnp.int32)
        if cfg.pos_variant == "mrope":
            return jnp.broadcast_to(pos[None, None], (1, 3, s))
        return pos[None]

    # ------------------------------------------------------------------
    # per-row entry points (vmapped below; every array here is one row)
    # ------------------------------------------------------------------
    def embed_row(head, hlora, tok, idx):
        hp = merged(head, hlora)
        s = tok.shape[0]
        x = L.embed_tokens(hp["embed"], tok[None], cd)[0]       # (S, d)
        if cfg.pos_variant == "learned":
            x = x + jax.lax.dynamic_slice_in_dim(
                hp["wpe"].astype(cd),
                jnp.minimum(idx, cfg.max_seq_len - s), s, axis=0)
        return x

    def block_row(bp, blora, x, cache, idx, window):
        lp = merged(bp, blora)
        x1 = x[None]                                            # (1, S, d)
        positions = row_positions(idx, x.shape[0])
        if fam in ("dense", "vlm", "moe"):
            kv = (cache["k"][None], cache["v"][None])
            if fam == "moe":
                y, (ck, cv), _ = moe_mod.apply_moe_block(
                    lp, x1, cfg, tcfg, positions=positions, window=window,
                    kv_cache=kv, cache_index=idx)
            else:
                y, (ck, cv) = T.apply_block(
                    lp, x1, cfg, tcfg, positions=positions, window=window,
                    kv_cache=kv, cache_index=idx)
            return y[0], {"k": ck[0], "v": cv[0]}
        if fam == "ssm":
            h, st = mamba2.apply_mamba(
                lp["mamba"], L.apply_norm(lp["ln1"], x1, cfg.norm_variant),
                cfg, tcfg,
                state={"conv": cache["conv"][None], "ssm": cache["ssm"][None]})
            return (x1 + h)[0], {"conv": st["conv"][0], "ssm": st["ssm"][0]}
        # hybrid
        y, (ck, cv), st = apply_hymba_block(
            lp, x1, cfg, tcfg, positions=positions, window=window,
            kv_cache=(cache["k"][None], cache["v"][None]), cache_index=idx,
            ssm_state={"conv": cache["conv"][None],
                       "ssm": cache["ssm"][None]})
        return y[0], {"k": ck[0], "v": cv[0],
                      "conv": st["conv"][0], "ssm": st["ssm"][0]}

    def head_row(head, hlora, x):
        hp = merged(head, hlora)
        xl = L.apply_norm(hp["ln_f"], x[-1:][None], cfg.norm_variant)
        logits = L.unembed(hp["embed"], xl.astype(jnp.float32),
                           cfg.tie_embeddings, cfg.logit_softcap,
                           cfg.vocab_size)
        return logits[0, 0]                                     # (vocab,)

    # the cache is consumed exactly once per block call — donate it so the
    # decode loop updates slot caches in place instead of doubling them
    return ServeProgram(
        embed=jax.jit(jax.vmap(embed_row, in_axes=(None, 0, 0, 0))),
        block=functools.partial(jax.jit, donate_argnums=(3,))(
            jax.vmap(block_row, in_axes=(None, 0, 0, 0, 0, None))),
        head=jax.jit(jax.vmap(head_row, in_axes=(None, 0, 0))),
    )
