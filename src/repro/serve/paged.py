"""Paged KV cache: fixed-size pages + per-slot page tables (vLLM-style).

The PR 6 engine gave every slot a dense ``(max_len, kv_heads, head_dim)``
cache row per layer — worst-case-sized, so slot count scaled with
``slots x max_len`` whether requests used the length or not.  Here the
per-layer cache is a shared *pool* of fixed-size pages plus one page table
per slot: a request owns exactly ``ceil(positions / page_size)`` pages for
its lifetime, long and short requests share the same pool, and the number
of concurrently admitted requests scales with *pool memory*, not with the
per-request cap.

Device side (repro/models/lm.py::paged_gather / paged_scatter, called
inside the jitted serving block): each row gathers its pages into a
contiguous (table_width * page_size) view for attention and fresh k/v
scatter back through the table.  Host side (this module): ``PagePool``
does the allocation accounting — admission reserves a request's full
lifetime of pages up front (deadlock-free: every admitted request can
always finish and release), ``release`` returns them at reap, and a
request whose pages are not free yet simply waits in the queue
(*admission backpressure* instead of PR 6's hard ``max_len`` rejection).

Page 0 is a sentinel: unallocated table entries point at it, and the
batched decode scatter routes masked-out lanes (free / still-prefilling
slots riding the dispatch) there, so a garbage lane can never write into
a page another request owns.  Sentinel reads are harmless — attention
masks positions past each row's write head to exactly zero weight.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PagePool:
    """Host-side page accounting shared by every layer's pool arrays.

    All layers use the same geometry and the same per-slot table (one
    allocation covers the whole depth), so the pool tracks pages in units
    of "one page across all layers".
    """

    def __init__(self, n_pages: int, page_size: int, slots: int,
                 table_width: int):
        if n_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is the "
                             "sentinel page and is never allocated)")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.table_width = int(table_width)
        # page 0 reserved as the sentinel; allocate low ids first so tests
        # and traces read naturally
        self._free: List[int] = list(range(self.n_pages - 1, 0, -1))
        self.tables = np.zeros((slots, table_width), np.int32)
        self._owned: List[List[int]] = [[] for _ in range(slots)]
        self.peak_pages_used = 0
        self.admission_waits = 0       # admissions deferred on a full pool

    # ------------------------------------------------------------------
    @property
    def usable_pages(self) -> int:
        """Allocatable pages (total minus the sentinel)."""
        return self.n_pages - 1

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.usable_pages - len(self._free)

    def pages_for(self, positions: int) -> int:
        """Pages needed to hold ``positions`` cache positions."""
        return max(1, -(-int(positions) // self.page_size))

    def can_admit(self, n_pages: int) -> bool:
        return len(self._free) >= n_pages

    def allocate(self, slot: int, n_pages: int):
        """Reserve ``n_pages`` for ``slot`` and point the head of its table
        row at them (the tail keeps the sentinel)."""
        if self._owned[slot]:
            raise RuntimeError(f"slot {slot} already owns pages")
        if n_pages > self.table_width:
            raise ValueError(
                f"request needs {n_pages} pages but the table holds "
                f"{self.table_width} (per-request cap)")
        if len(self._free) < n_pages:
            raise RuntimeError(
                f"page pool exhausted: need {n_pages}, free "
                f"{len(self._free)} — admission must check can_admit first")
        ids = [self._free.pop() for _ in range(n_pages)]
        self.tables[slot, :] = 0
        self.tables[slot, :n_pages] = ids
        self._owned[slot] = ids
        self.peak_pages_used = max(self.peak_pages_used, self.used_pages)

    def release(self, slot: int):
        """Return a reaped slot's pages to the pool (table row back to the
        sentinel).  Safe to call on a slot that owns nothing."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self.tables[slot, :] = 0

    def stats(self) -> Dict[str, int]:
        return {"pool_pages": self.usable_pages,
                "page_size": self.page_size,
                "free_pages": self.free_pages,
                "peak_pages_used": self.peak_pages_used,
                "admission_waits": self.admission_waits}
