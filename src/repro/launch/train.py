"""End-to-end training driver (paper Application layer).

Composes the full resource-aware runtime: data pipeline -> sharded train step
(C1–C4) -> energy governor (C5) -> metrics observer + visualizer (C7) ->
fault-tolerant checkpointing.  Runs on 1 CPU device (paper-scale models) or
any mesh.

Three loop variants compose the shared ``TrainerRuntime`` scaffold
(repro/runtime/trainer.py):

  train_loop           fully in-memory jitted step
  offload_train_loop   in-memory fwd/bwd, segment-streamed optimizer (C1)
  stream_train_loop    layer-streamed fwd/bwd AND optimizer (C1, full depth):
                       peak resident params bounded by a few layer segments

    PYTHONPATH=src python -m repro.launch.train --arch gpt2_124m \
        --steps 200 --batch 8 --seq 128 --lora-rank 8 --out runs/gpt2
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.checkpoint.store import (is_offload_checkpoint,
                                    offload_checkpoint_layout, restore,
                                    restore_offload)
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.core.step import (init_state, make_grad_step, make_stream_step,
                             make_train_step)
from repro.models import registry
from repro.offload.state import (LAYER_LAYOUT, LayerStreamedState,
                                 OffloadedTrainState, offload_dir_for)
from repro.optim.schedule import lr_schedule
from repro.param import abstract_params
from repro.runtime.trainer import TrainerRuntime, build_data  # noqa: F401


def _resume_layout_guard(rt: TrainerRuntime, last: int, expected: str):
    """Refuse to resume a checkpoint written by a different loop variant.

    ``expected`` is the layout this loop can consume: "memory" (in-memory
    jit), "byte" (byte-balanced optimizer offload) or "layer" (layer-aligned
    param streaming).  The error names the flag that matches the checkpoint.
    """
    actual = "memory"
    if is_offload_checkpoint(rt.ckdir, last):
        actual = ("layer" if offload_checkpoint_layout(rt.ckdir, last) ==
                  LAYER_LAYOUT else "byte")
    if actual == expected:
        return
    kind = {"memory": "in-memory",
            "byte": "byte-balanced segment-offload",
            "layer": "layer-aligned (param-streaming) segment-offload"}
    flag = {"memory": "without offload flags",
            "byte": "with --offload-segments N",
            "layer": "with --offload-stream-params"}
    raise ValueError(
        f"{rt.ckdir} holds {kind[actual]} checkpoints; resume {flag[actual]} "
        f"(or point --out elsewhere)")


def _warn_moment_dtype(rt: TrainerRuntime, ostate, tcfg: TrainConfig):
    if ostate.moment_dtype != tcfg.offload_moment_dtype:
        rt.log(f"[warn] --offload-moment-dtype {tcfg.offload_moment_dtype} "
               f"ignored: the resumed segment files store "
               f"{ostate.moment_dtype} moments (fixed at create time)")


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, *, out_dir: Optional[str],
               seed: int = 0, resume: bool = True,
               governor: Optional[EnergyGovernor] = None,
               dataset=None, print_fn=print):
    if tcfg.offload_stream_params:
        return stream_train_loop(cfg, tcfg, out_dir=out_dir, seed=seed,
                                 resume=resume, governor=governor,
                                 dataset=dataset, print_fn=print_fn)
    if tcfg.offload_segments > 0:
        return offload_train_loop(cfg, tcfg, out_dir=out_dir, seed=seed,
                                  resume=resume, governor=governor,
                                  dataset=dataset, print_fn=print_fn)
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)

    start = 0
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "memory")
        state, start = restore(rt.ckdir, state)
        start = int(start)
        rt.log(f"[resume] from step {start}")
    # defer: mid-step the donated `state` buffers belong to the jit call
    rt.install_sigterm(lambda: rt.store.save_sync(state, int(state["step"])),
                       defer=True)

    for step, batch in rt.steps(start):
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_async(state, step + 1)
    if rt.store:
        rt.store.wait()
        rt.store.save_sync(state, int(state["step"]))
    obs = rt.finish(f"{cfg.name} | {'LoRA' if tcfg.lora_rank else 'Full-FT'}")
    return state, obs


def offload_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                       out_dir: Optional[str], seed: int = 0,
                       resume: bool = True,
                       governor: Optional[EnergyGovernor] = None,
                       dataset=None, print_fn=print):
    """Training with segment-wise *optimizer-state* offload (paper §4.1.1
    C1, phone realization — repro/offload/).

    fwd/bwd runs jitted on the full in-memory params; the AdamW update then
    streams the (p, m, v) segments through a small LRU window with
    double-buffered prefetch, so peak resident optimizer state is
    ``offload_resident / offload_segments`` of the whole — decoupled from
    model size.  Checkpoints hardlink the segment files (zero-copy)."""
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    grad_fn = jax.jit(make_grad_step(cfg, tcfg))
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    like_params = abstract_params(registry.param_specs(cfg),
                                  dtype=dtype_of(tcfg.param_dtype))

    ostate = None
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "byte")
        ostate, start = restore_offload(
            rt.ckdir, work_dir, like_params, last,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch)
        _warn_moment_dtype(rt, ostate, tcfg)
        rt.log(f"[resume] offload checkpoint step {start}")
    if ostate is None:
        state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        ostate = OffloadedTrainState.create(
            state, work_dir, tcfg.offload_segments,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            moment_dtype=tcfg.offload_moment_dtype)
        del state  # from here on the segment files own the optimizer state

    rt.install_sigterm(lambda: rt.store.save_offload(ostate, ostate.step),
                       defer=True)  # segments mutate in place mid-step
    params = ostate.materialize_params()
    for step, batch in rt.steps(ostate.step):
        loss, metrics, grads = grad_fn(params, batch)
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        params = ostate.apply_update(grads, lr=lr, beta1=tcfg.beta1,
                                     beta2=tcfg.beta2, eps=tcfg.eps,
                                     weight_decay=tcfg.weight_decay)
        del grads
        jax.block_until_ready(loss)
        metrics = dict(metrics)
        metrics["lr"] = lr
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_offload(ostate, step + 1)
    if rt.store:
        rt.store.save_offload(ostate, ostate.step)
    s = ostate.stats()
    rt.log(f"[offload] segments {ostate.store.num_segments} | state "
           f"{s['store_bytes']/1e6:.1f} MB | peak window "
           f"{s['peak_resident_bytes']/1e6:.1f} MB | prefetch hit "
           f"{s['prefetch_hits']}/{s['prefetch_hits']+s['sync_loads']}")
    ostate.close()
    obs = rt.finish(f"{cfg.name} | offload x{ostate.store.num_segments}")
    state = {"params": params, "step": jnp.asarray(ostate.step, jnp.int32),
             "offload": ostate}
    return state, obs


def stream_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                      out_dir: Optional[str], seed: int = 0,
                      resume: bool = True,
                      governor: Optional[EnergyGovernor] = None,
                      dataset=None, print_fn=print):
    """Layer-streamed training (paper §4.1.1 C1, full depth): fwd/bwd pulls
    each block's layer-aligned (p, m, v) segment through the offload window
    (prefetching block i+1 while block i computes), saves only the
    layer-boundary activations, back-propagates block-by-block into a
    gradient scratch store, and streams the AdamW update segment-wise.  Peak
    resident params during compute stay bounded by a few layer segments +
    the head segment — independent of model depth."""
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    like_params = abstract_params(registry.param_specs(cfg),
                                  dtype=dtype_of(tcfg.param_dtype))

    lstate = None
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "layer")
        lstate, start = restore_offload(
            rt.ckdir, work_dir, like_params, last,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch)
        _warn_moment_dtype(rt, lstate, tcfg)
        rt.log(f"[resume] layer-streamed checkpoint step {start}")
    if lstate is None:
        state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        lstate = LayerStreamedState.create(
            state, work_dir, max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            moment_dtype=tcfg.offload_moment_dtype)
        del state  # the segment files own params AND optimizer state now

    rt.install_sigterm(lambda: rt.store.save_offload(lstate, lstate.step),
                       defer=True)  # segments mutate in place mid-step
    step_fn = make_stream_step(cfg, tcfg, lstate,
                               grad_dir=os.path.join(work_dir, "grads"))
    for step, batch in rt.steps(lstate.step):
        loss, metrics = step_fn(batch, step)
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_offload(lstate, step + 1)
    if rt.store:
        rt.store.save_offload(lstate, lstate.step)
    s = step_fn.stats()
    rt.log(f"[stream] {lstate.n_layers} layer segments + head | state "
           f"{s['param_store_bytes']/1e6:.1f} MB | peak param window "
           f"{s['param_peak_resident_bytes']/1e6:.1f} MB | prefetch hit "
           f"{s['param_prefetch_hits']}"
           f"/{s['param_prefetch_hits']+s['param_sync_loads']}")
    params = lstate.materialize_params()
    step_fn.close()
    lstate.close()
    obs = rt.finish(f"{cfg.name} | layer-streamed x{lstate.n_layers}")
    state = {"params": params, "step": jnp.asarray(lstate.step, jnp.int32),
             "offload": lstate}
    return state, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_124m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-5)
    ap.add_argument("--lora-rank", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--attention", default="streaming")
    ap.add_argument("--scan-layers", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="lax.scan over the stacked layers (in-memory path); "
                         "--no-scan-layers unrolls them")
    ap.add_argument("--offload-segments", type=int, default=0,
                    help="page (param, m, v) state to N mmap segment files; "
                         "optimizer updates stream segment-by-segment (C1)")
    ap.add_argument("--offload-stream-params", action="store_true",
                    help="layer-streamed fwd/bwd: segments become "
                         "layer-aligned (one per block + head) and params "
                         "page through the window during compute too")
    ap.add_argument("--offload-dir", default="",
                    help="segment-file directory (default <out>/offload)")
    ap.add_argument("--offload-resident", type=int, default=2,
                    help="LRU window size in segments")
    ap.add_argument("--offload-prefetch",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="background double-buffered segment prefetch")
    ap.add_argument("--offload-moment-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="storage dtype of the AdamW m/v segments "
                         "(bfloat16 halves their bytes; update math stays "
                         "fp32 via round-trip cast)")
    ap.add_argument("--out", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--energy", action="store_true",
                    help="enable the K/mu/rho governor with a simulated battery")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq,
        microbatches=args.microbatches, learning_rate=args.lr,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        lora_rank=args.lora_rank,
        lora_alpha=32.0 if args.lora_rank else 0.0,
        remat_policy=args.remat, attention_impl=args.attention,
        scan_layers=args.scan_layers,
        compute_dtype="float32", checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.out or "",
        offload_segments=args.offload_segments,
        offload_stream_params=args.offload_stream_params,
        offload_dir=args.offload_dir,
        offload_resident=args.offload_resident,
        offload_prefetch=args.offload_prefetch,
        offload_moment_dtype=args.offload_moment_dtype)
    governor = None
    if args.energy:
        governor = EnergyGovernor(monitor=SimulatedBattery(
            level=70.0, drain_per_unit=0.5))
    t0 = time.time()
    state, obs = train_loop(cfg, tcfg, out_dir=args.out, seed=args.seed,
                            governor=governor)
    print(f"done in {time.time()-t0:.1f}s | final loss "
          f"{obs.rows[-1]['loss']:.4f} | peak RSS {obs.peak_rss_mb:.0f} MB")


if __name__ == "__main__":
    main()
