"""End-to-end training driver (paper Application layer).

Composes the full resource-aware runtime: data pipeline -> sharded train step
(C1–C4) -> energy governor (C5) -> metrics observer + visualizer (C7) ->
fault-tolerant checkpointing.  Runs on 1 CPU device (paper-scale models) or
any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2_124m \
        --steps 200 --batch 8 --seq 128 --lora-rank 8 --out runs/gpt2
"""
from __future__ import annotations

import argparse
import os
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.checkpoint.store import (CheckpointStore, is_offload_checkpoint,
                                    latest_step, restore, restore_offload)
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.core.step import (init_state, make_eval_step, make_grad_step,
                             make_train_step)
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset, packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import registry
from repro.offload.state import OffloadedTrainState, offload_dir_for
from repro.optim.schedule import lr_schedule
from repro.param import abstract_params
from repro.runtime.metrics import MetricsObserver
from repro.runtime.visualizer import write_dashboard


def build_data(cfg: ModelConfig, tcfg: TrainConfig, n_sentences: int = 4000,
               seed: int = 0):
    tok = ByteTokenizer()
    text = synthetic_wikitext(n_sentences, seed=seed)
    ds = LMDataset(text, tok, tcfg.seq_len)
    # token ids must stay inside the model vocab
    assert tok.vocab_size <= cfg.vocab_size, (tok.vocab_size, cfg.vocab_size)
    return ds


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, *, out_dir: Optional[str],
               seed: int = 0, resume: bool = True, eval_every: int = 0,
               governor: Optional[EnergyGovernor] = None,
               dataset=None, print_fn=print):
    if tcfg.offload_segments > 0:
        return offload_train_loop(cfg, tcfg, out_dir=out_dir, seed=seed,
                                  resume=resume, governor=governor,
                                  dataset=dataset, print_fn=print_fn)
    ds = dataset or build_data(cfg, tcfg, seed=seed)
    obs = MetricsObserver(out_dir=out_dir, print_fn=print_fn)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)

    store = None
    start = 0
    if tcfg.checkpoint_every > 0 and out_dir:
        ckdir = os.path.join(out_dir, "ckpt")
        store = CheckpointStore(ckdir, keep=tcfg.keep_checkpoints)
        if resume and latest_step(ckdir) is not None:
            if is_offload_checkpoint(ckdir, latest_step(ckdir)):
                raise ValueError(
                    f"{ckdir} holds segment-offload checkpoints; resume with "
                    f"--offload-segments N (or point --out elsewhere)")
            state, start = restore(ckdir, state)
            start = int(start)
            if print_fn:
                print_fn(f"[resume] from step {start}")

        def _flush(signum, frame):  # preemption tolerance
            store.save_sync(state, int(state["step"]))
            raise SystemExit(128 + signum)
        try:
            signal.signal(signal.SIGTERM, _flush)
        except ValueError:
            pass  # not the main thread

    batches = packed_batches(ds, tcfg.global_batch, seed=seed, epochs=10_000)
    for _ in range(start):
        next(batches)  # deterministic data order on resume

    tokens_per_step = tcfg.global_batch * tcfg.seq_len
    for step in range(start, tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        obs.start_step()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        row = obs.end_step(step, metrics, tokens=tokens_per_step,
                           battery=(governor.monitor.fraction()
                                    if governor else 1.0))
        if governor is not None:
            governor.after_step(step, row["step_time_s"])
        if store and (step + 1) % tcfg.checkpoint_every == 0:
            store.save_async(state, step + 1)
    if store:
        store.wait()
        store.save_sync(state, int(state["step"]))
    obs.flush_csv()
    if out_dir:
        write_dashboard(obs.rows, os.path.join(out_dir, "dashboard.html"),
                        title=f"{cfg.name} | {'LoRA' if tcfg.lora_rank else 'Full-FT'}")
    return state, obs


def offload_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                       out_dir: Optional[str], seed: int = 0,
                       resume: bool = True,
                       governor: Optional[EnergyGovernor] = None,
                       dataset=None, print_fn=print):
    """Training with segment-wise state offload (paper §4.1.1 C1, phone
    realization — repro/offload/).

    fwd/bwd runs jitted on the full in-memory params; the AdamW update then
    streams the (p, m, v) segments through a small LRU window with
    double-buffered prefetch, so peak resident optimizer state is
    ``offload_resident / offload_segments`` of the whole — decoupled from
    model size.  Checkpoints hardlink the segment files (zero-copy)."""
    ds = dataset or build_data(cfg, tcfg, seed=seed)
    obs = MetricsObserver(out_dir=out_dir, print_fn=print_fn)
    grad_fn = jax.jit(make_grad_step(cfg, tcfg))
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    like_params = abstract_params(registry.param_specs(cfg),
                                  dtype=dtype_of(tcfg.param_dtype))

    store = None
    ckdir = os.path.join(out_dir, "ckpt") if (
        tcfg.checkpoint_every > 0 and out_dir) else None
    ostate = None
    if ckdir:
        store = CheckpointStore(ckdir, keep=tcfg.keep_checkpoints)
        last = latest_step(ckdir)
        if resume and last is not None:
            if not is_offload_checkpoint(ckdir, last):
                raise ValueError(
                    f"{ckdir} holds in-memory checkpoints; resume without "
                    f"--offload-segments (or point --out elsewhere)")
            ostate, start = restore_offload(
                ckdir, work_dir, like_params, last,
                max_resident=tcfg.offload_resident,
                prefetch=tcfg.offload_prefetch)
            if print_fn:
                print_fn(f"[resume] offload checkpoint step {start}")
    if ostate is None:
        state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        ostate = OffloadedTrainState.create(
            state, work_dir, tcfg.offload_segments,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch)
        del state  # from here on the segment files own the optimizer state

    if store is not None:
        def _flush(signum, frame):  # preemption tolerance
            store.save_offload(ostate, ostate.step)
            raise SystemExit(128 + signum)
        try:
            signal.signal(signal.SIGTERM, _flush)
        except ValueError:
            pass  # not the main thread

    params = ostate.materialize_params()
    start = ostate.step
    batches = packed_batches(ds, tcfg.global_batch, seed=seed, epochs=10_000)
    for _ in range(start):
        next(batches)  # deterministic data order on resume

    tokens_per_step = tcfg.global_batch * tcfg.seq_len
    for step in range(start, tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        obs.start_step()
        loss, metrics, grads = grad_fn(params, batch)
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        params = ostate.apply_update(grads, lr=lr, beta1=tcfg.beta1,
                                     beta2=tcfg.beta2, eps=tcfg.eps,
                                     weight_decay=tcfg.weight_decay)
        del grads
        jax.block_until_ready(loss)
        metrics = dict(metrics)
        metrics["lr"] = lr
        row = obs.end_step(step, metrics, tokens=tokens_per_step,
                           battery=(governor.monitor.fraction()
                                    if governor else 1.0))
        if governor is not None:
            governor.after_step(step, row["step_time_s"])
        if store and (step + 1) % tcfg.checkpoint_every == 0:
            store.save_offload(ostate, step + 1)
    if store:
        store.save_offload(ostate, ostate.step)
    if print_fn:
        s = ostate.stats()
        print_fn(f"[offload] segments {ostate.store.num_segments} | state "
                 f"{s['store_bytes']/1e6:.1f} MB | peak window "
                 f"{s['peak_resident_bytes']/1e6:.1f} MB | prefetch hit "
                 f"{s['prefetch_hits']}/{s['prefetch_hits']+s['sync_loads']}")
    ostate.close()
    obs.flush_csv()
    if out_dir:
        write_dashboard(obs.rows, os.path.join(out_dir, "dashboard.html"),
                        title=f"{cfg.name} | offload x{ostate.store.num_segments}")
    state = {"params": params, "step": jnp.asarray(ostate.step, jnp.int32),
             "offload": ostate}
    return state, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_124m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-5)
    ap.add_argument("--lora-rank", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--attention", default="streaming")
    ap.add_argument("--offload-segments", type=int, default=0,
                    help="page (param, m, v) state to N mmap segment files; "
                         "optimizer updates stream segment-by-segment (C1)")
    ap.add_argument("--offload-dir", default="",
                    help="segment-file directory (default <out>/offload)")
    ap.add_argument("--offload-resident", type=int, default=2,
                    help="LRU window size in segments")
    ap.add_argument("--out", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--energy", action="store_true",
                    help="enable the K/mu/rho governor with a simulated battery")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq,
        microbatches=args.microbatches, learning_rate=args.lr,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        lora_rank=args.lora_rank,
        lora_alpha=32.0 if args.lora_rank else 0.0,
        remat_policy=args.remat, attention_impl=args.attention,
        compute_dtype="float32", checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.out or "",
        offload_segments=args.offload_segments,
        offload_dir=args.offload_dir,
        offload_resident=args.offload_resident)
    governor = None
    if args.energy:
        governor = EnergyGovernor(monitor=SimulatedBattery(
            level=70.0, drain_per_unit=0.5))
    t0 = time.time()
    state, obs = train_loop(cfg, tcfg, out_dir=args.out, seed=args.seed,
                            governor=governor)
    print(f"done in {time.time()-t0:.1f}s | final loss "
          f"{obs.rows[-1]['loss']:.4f} | peak RSS {obs.peak_rss_mb:.0f} MB")


if __name__ == "__main__":
    main()
