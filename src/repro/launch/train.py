"""End-to-end training driver (paper Application layer).

Composes the full resource-aware runtime: data pipeline -> sharded train step
(C1–C4) -> energy governor (C5) -> metrics observer + visualizer (C7) ->
fault-tolerant checkpointing.  Runs on 1 CPU device (paper-scale models) or
any mesh.

Three loop variants compose the shared ``TrainerRuntime`` scaffold
(repro/runtime/trainer.py):

  train_loop           fully in-memory jitted step
  offload_train_loop   in-memory fwd/bwd, segment-streamed optimizer (C1)
  stream_train_loop    layer-streamed fwd/bwd AND optimizer (C1, full depth):
                       peak resident params bounded by a few layer segments

    PYTHONPATH=src python -m repro.launch.train --arch gpt2_124m \
        --steps 200 --batch 8 --seq 128 --lora-rank 8 --out runs/gpt2
"""
from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro import configs
from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.checkpoint.safetensors import save_adapter
from repro.checkpoint.store import (checkpoint_meta, is_adapter_checkpoint,
                                    is_offload_checkpoint,
                                    offload_checkpoint_layout, restore,
                                    restore_offload)
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.core.step import (init_adapter_state, init_state, make_grad_step,
                             make_stream_step, make_train_step)
from repro.models import registry
from repro.offload.state import (LAYER_LAYOUT, LayerStreamedState,
                                 OffloadedTrainState, offload_dir_for)
from repro.optim.schedule import lr_schedule
from repro.param import abstract_params, init_params, tree_bytes
from repro.runtime.trainer import TrainerRuntime, build_data  # noqa: F401


def _resume_layout_guard(rt: TrainerRuntime, last: int, expected: str):
    """Refuse to resume a checkpoint written by a different loop variant.

    ``expected`` is the layout this loop can consume: "memory" (in-memory
    jit), "byte" (byte-balanced optimizer offload), "layer" (layer-aligned
    param streaming) or "adapter" (adapter-only, frozen-base streamed LoRA).
    The error names the flag that matches the checkpoint.
    """
    actual = "memory"
    if is_offload_checkpoint(rt.ckdir, last):
        actual = ("layer" if offload_checkpoint_layout(rt.ckdir, last) ==
                  LAYER_LAYOUT else "byte")
    elif is_adapter_checkpoint(rt.ckdir, last):
        actual = "adapter"
    if actual == expected:
        return
    kind = {"memory": "in-memory",
            "byte": "byte-balanced segment-offload",
            "layer": "layer-aligned (param-streaming) segment-offload",
            "adapter": "adapter-only (frozen-base streamed LoRA)"}
    flag = {"memory": "without offload flags",
            "byte": "with --offload-segments N",
            "layer": "with --offload-stream-params",
            "adapter": "with --offload-stream-params --lora-rank N"}
    raise ValueError(
        f"{rt.ckdir} holds {kind[actual]} checkpoints; resume {flag[actual]} "
        f"(or point --out elsewhere)")


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, *, out_dir: Optional[str],
               seed: int = 0, resume: bool = True,
               governor: Optional[EnergyGovernor] = None,
               dataset=None, print_fn=print):
    if tcfg.offload_stream_params:
        loop = (stream_lora_train_loop if tcfg.lora_rank > 0
                else stream_train_loop)
        return loop(cfg, tcfg, out_dir=out_dir, seed=seed,
                    resume=resume, governor=governor,
                    dataset=dataset, print_fn=print_fn)
    if tcfg.offload_segments > 0:
        return offload_train_loop(cfg, tcfg, out_dir=out_dir, seed=seed,
                                  resume=resume, governor=governor,
                                  dataset=dataset, print_fn=print_fn)
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)

    start = 0
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "memory")
        state, start = restore(rt.ckdir, state)
        start = int(start)
        rt.log(f"[resume] from step {start}")
    # defer: mid-step the donated `state` buffers belong to the jit call
    rt.install_sigterm(lambda: rt.store.save_sync(state, int(state["step"])),
                       defer=True)

    for step, batch in rt.steps(start):
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_async(state, step + 1)
    if rt.store:
        rt.store.wait()
        rt.store.save_sync(state, int(state["step"]))
    obs = rt.finish(f"{cfg.name} | {'LoRA' if tcfg.lora_rank else 'Full-FT'}")
    return state, obs


def offload_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                       out_dir: Optional[str], seed: int = 0,
                       resume: bool = True,
                       governor: Optional[EnergyGovernor] = None,
                       dataset=None, print_fn=print):
    """Training with segment-wise *optimizer-state* offload (paper §4.1.1
    C1, phone realization — repro/offload/).

    fwd/bwd runs jitted on the full in-memory params; the AdamW update then
    streams the (p, m, v) segments through a small LRU window with
    double-buffered prefetch, so peak resident optimizer state is
    ``offload_resident / offload_segments`` of the whole — decoupled from
    model size.  Checkpoints hardlink the segment files (zero-copy)."""
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    grad_fn = jax.jit(make_grad_step(cfg, tcfg))
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    like_params = abstract_params(registry.param_specs(cfg),
                                  dtype=dtype_of(tcfg.param_dtype))

    ostate = None
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "byte")
        ostate, start = restore_offload(
            rt.ckdir, work_dir, like_params, last,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            async_writeback=tcfg.offload_async_writeback,
            io_backend=tcfg.offload_io)
        rt.guard_segment_layout(ostate)
        rt.log(f"[resume] offload checkpoint step {start}")
    if ostate is None:
        state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        ostate = OffloadedTrainState.create(
            state, work_dir, tcfg.offload_segments,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            moment_dtype=tcfg.offload_moment_dtype,
            async_writeback=tcfg.offload_async_writeback,
            io_backend=tcfg.offload_io)
        del state  # from here on the segment files own the optimizer state

    rt.install_sigterm(lambda: rt.store.save_offload(ostate, ostate.step),
                       defer=True)  # segments mutate in place mid-step
    params = ostate.materialize_params()
    for step, batch in rt.steps(ostate.step):
        loss, metrics, grads = grad_fn(params, batch)
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        params = ostate.apply_update(grads, lr=lr, beta1=tcfg.beta1,
                                     beta2=tcfg.beta2, eps=tcfg.eps,
                                     weight_decay=tcfg.weight_decay)
        del grads
        jax.block_until_ready(loss)
        metrics = dict(metrics)
        metrics["lr"] = lr
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_offload(ostate, step + 1)
    if rt.store:
        rt.store.save_offload(ostate, ostate.step)
    s = ostate.stats()
    rt.log(f"[offload] segments {ostate.store.num_segments} | state "
           f"{s['store_bytes']/1e6:.1f} MB | peak window "
           f"{s['peak_resident_bytes']/1e6:.1f} MB | prefetch hit "
           f"{s['prefetch_hits']}/{s['prefetch_hits']+s['sync_loads']}")
    ostate.close()
    obs = rt.finish(f"{cfg.name} | offload x{ostate.store.num_segments}")
    state = {"params": params, "step": jnp.asarray(ostate.step, jnp.int32),
             "offload": ostate}
    return state, obs


def stream_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                      out_dir: Optional[str], seed: int = 0,
                      resume: bool = True,
                      governor: Optional[EnergyGovernor] = None,
                      dataset=None, print_fn=print):
    """Layer-streamed training (paper §4.1.1 C1, full depth): fwd/bwd pulls
    each block's layer-aligned (p, m, v) segment through the offload window
    (prefetching block i+1 while block i computes), saves only the
    layer-boundary activations, back-propagates block-by-block into a
    gradient scratch store, and streams the AdamW update segment-wise.  Peak
    resident params during compute stay bounded by a few layer segments +
    the head segment — independent of model depth."""
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    like_params = abstract_params(registry.param_specs(cfg),
                                  dtype=dtype_of(tcfg.param_dtype))

    lstate = None
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "layer")
        lstate, start = restore_offload(
            rt.ckdir, work_dir, like_params, last,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            async_writeback=tcfg.offload_async_writeback,
            io_backend=tcfg.offload_io)
        rt.guard_segment_layout(lstate)
        rt.log(f"[resume] layer-streamed checkpoint step {start}")
    if lstate is None:
        state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)
        lstate = LayerStreamedState.create(
            state, work_dir, max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            moment_dtype=tcfg.offload_moment_dtype,
            async_writeback=tcfg.offload_async_writeback,
            io_backend=tcfg.offload_io)
        del state  # the segment files own params AND optimizer state now

    rt.install_sigterm(lambda: rt.store.save_offload(lstate, lstate.step),
                       defer=True)  # segments mutate in place mid-step
    step_fn = make_stream_step(cfg, tcfg, lstate,
                               grad_dir=os.path.join(work_dir, "grads"))
    for step, batch in rt.steps(lstate.step):
        loss, metrics = step_fn(batch, step)
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_offload(lstate, step + 1)
    if rt.store:
        rt.store.save_offload(lstate, lstate.step)
    s = step_fn.stats()
    ps = step_fn.pipeline_stats()
    rt.log(f"[stream] {lstate.n_layers} layer segments + head | state "
           f"{s['param_store_bytes']/1e6:.1f} MB | peak param window "
           f"{s['param_peak_resident_bytes']/1e6:.1f} MB | prefetch hit "
           f"{s['param_prefetch_hits']}"
           f"/{s['param_prefetch_hits']+s['param_sync_loads']}")
    rt.log(f"[stream] pipeline: read-blocked {ps['read_block_s']:.2f}s | "
           f"write-blocked {ps['write_block_s']:.2f}s | h2d staging "
           f"{ps['stage_h2d_s']:.2f}s | background write "
           f"{ps['writeback_busy_s']:.2f}s")
    params = lstate.materialize_params()
    step_fn.close()
    lstate.close()
    obs = rt.finish(f"{cfg.name} | layer-streamed x{lstate.n_layers}")
    state = {"params": params, "step": jnp.asarray(lstate.step, jnp.int32),
             "offload": lstate}
    return state, obs


def stream_lora_train_loop(cfg: ModelConfig, tcfg: TrainConfig, *,
                           out_dir: Optional[str], seed: int = 0,
                           resume: bool = True,
                           governor: Optional[EnergyGovernor] = None,
                           dataset=None, print_fn=print):
    """PEFT on the streamed offload engine (paper C6 over C1, full depth):
    the frozen base pages through *read-only* param-only layer segments —
    no m/v segments, no dirty write-back, no gradient scratch — while the
    (tiny) LoRA adapter and its AdamW state stay memory-resident.
    ``merge_lora`` runs per block inside the jitted apply/VJP entry points,
    so merged weights exist one block at a time.  With ``--base-quant int8``
    the frozen segments are additionally per-channel quantized (QLoRA-style)
    and stay int8 in the window — the program dequantizes per block inside
    the jit.  Checkpoints are **adapter-only**: base and adapter init both
    derive deterministically from the seed (crc32 path fold, repro/param.py),
    so resume re-derives (and re-quantizes) the frozen base and restores
    just the adapter tree."""
    rt = TrainerRuntime(cfg, tcfg, out_dir=out_dir, seed=seed,
                        governor=governor, dataset=dataset, print_fn=print_fn)
    work_dir = offload_dir_for(out_dir, tcfg.offload_dir)
    # the frozen base is fully determined by (arch, seed, param dtype) plus
    # its segment quantization; the quant suffix only appears when set so
    # pre-codec fp32 tags (and their checkpoints) stay valid
    base_tag = (f"{cfg.name}|seed{seed}|{tcfg.param_dtype}"
                + (f"|{tcfg.base_quant}" if tcfg.base_quant else ""))
    # adapter init is tiny; the full base only materializes if the frozen
    # segments still need laying out (see below)
    adapter = init_adapter_state(jax.random.PRNGKey(seed), cfg, tcfg)
    # everything the restored adapter is only valid against: base identity
    # (base_tag covers arch/seed/dtype/quant) and the merge hyperparameters
    # — stamped into the checkpoint manifest, validated on resume.  An
    # adapter trained against an int8 base is NOT valid against the fp32
    # base (and vice versa): the adapter learned around the quantization
    # error, so a codec mismatch hard-errors via base_quant/base_tag.
    peft_meta = {"seed": int(seed), "base_tag": base_tag,
                 "base_quant": tcfg.base_quant,
                 "lora_rank": int(tcfg.lora_rank),
                 "lora_alpha": float(tcfg.lora_alpha),
                 "lora_targets": list(tcfg.lora_targets)}

    start = 0
    last = rt.latest_checkpoint()
    if resume and last is not None:
        _resume_layout_guard(rt, last, "adapter")
        stored = checkpoint_meta(rt.ckdir, last)
        bad = {k: (stored[k], v) for k, v in peft_meta.items()
               if k in stored and stored[k] != v}
        if bad:
            raise ValueError(
                f"{rt.ckdir} was written with different PEFT settings: " +
                "; ".join(f"{k} was {was!r}, now {now!r}"
                          for k, (was, now) in sorted(bad.items())) +
                " — the adapter only matches the base/merge it was trained "
                "against (rerun with the original flags, or point --out "
                "elsewhere)")
        adapter, start = restore(rt.ckdir, adapter)
        start = int(start)
        rt.log(f"[resume] adapter-only checkpoint step {start} "
               f"(frozen base re-derived from seed {seed})")
    # the frozen segments are read-only and seed-derived: a matching store
    # left in work_dir by a previous run is reused as-is — no full-base RAM
    # materialization and no parameter-sized rewrite to flash on restart
    like_base = abstract_params(registry.param_specs(cfg),
                                dtype=dtype_of(tcfg.param_dtype))
    lstate = LayerStreamedState.open_frozen_if_matching(
        work_dir, like_base, base_tag=base_tag,
        max_resident=tcfg.offload_resident, prefetch=tcfg.offload_prefetch,
        io_backend=tcfg.offload_io)
    if lstate is not None:
        rt.log("[stream+lora] reusing frozen base segments in "
               f"{work_dir} (tag {base_tag})")
    else:
        # base only — the adapter above is the same tree init_state builds
        base = init_params(jax.random.PRNGKey(seed),
                           registry.param_specs(cfg),
                           dtype=dtype_of(tcfg.param_dtype))
        lstate = LayerStreamedState.create_frozen(
            base, work_dir, base_tag=base_tag,
            max_resident=tcfg.offload_resident,
            prefetch=tcfg.offload_prefetch,
            quant=tcfg.base_quant,
            io_backend=tcfg.offload_io)
        del base  # the read-only segment files own the base from here on
    rt.guard_segment_layout(lstate)

    step_fn = make_stream_step(cfg, tcfg, lstate, grad_dir="",
                               adapter=adapter)
    # defer: the adapter/opt swap inside the update is not atomic mid-step
    rt.install_sigterm(
        lambda: rt.store.save_sync(step_fn.adapter_state(),
                                   int(step_fn.adapter_state()["step"]),
                                   extra_meta=peft_meta),
        defer=True)
    for step, batch in rt.steps(start):
        loss, metrics = step_fn(batch, step)
        rt.end_step(step, metrics)
        if rt.checkpoint_due(step):
            rt.store.save_async(step_fn.adapter_state(), step + 1,
                                extra_meta=peft_meta)
    if rt.store:
        rt.store.wait()
        rt.store.save_sync(step_fn.adapter_state(),
                           int(step_fn.adapter_state()["step"]),
                           extra_meta=peft_meta)
    adapter = step_fn.adapter_state()
    s = step_fn.stats()
    adapter_mb = tree_bytes({"lora": adapter["lora"],
                             "opt": adapter["opt"]}) / 1e6
    quant_note = f" ({tcfg.base_quant})" if tcfg.base_quant else ""
    rt.log(f"[stream+lora] {lstate.n_layers} frozen layer segments + head | "
           f"base {s['param_store_bytes']/1e6:.1f} MB read-only{quant_note} |"
           f" peak param window {s['param_peak_resident_bytes']/1e6:.1f} MB |"
           f" adapter state {adapter_mb:.2f} MB resident | prefetch hit "
           f"{s['param_prefetch_hits']}"
           f"/{s['param_prefetch_hits']+s['param_sync_loads']}")
    ps = step_fn.pipeline_stats()
    rt.log(f"[stream+lora] pipeline: read-blocked {ps['read_block_s']:.2f}s"
           f" | h2d staging {ps['stage_h2d_s']:.2f}s | prefetch hit rate "
           f"{ps['prefetch_hit_rate']:.2f}")
    if out_dir:
        save_adapter(os.path.join(out_dir, "adapter.safetensors"),
                     adapter["lora"], rank=tcfg.lora_rank,
                     alpha=tcfg.lora_alpha, targets=tcfg.lora_targets,
                     base_quant=tcfg.base_quant, base_tag=base_tag)
    # a quantized base materializes dequantized, so the merged export folds
    # the adapter into the same weights the adapter actually trained against
    base = lstate.materialize_params()
    step_fn.close()
    lstate.close()
    obs = rt.finish(f"{cfg.name} | streamed-LoRA r{tcfg.lora_rank} "
                    f"x{lstate.n_layers}{quant_note}")
    state = {"base": base, "lora": adapter["lora"], "opt": adapter["opt"],
             "step": adapter["step"], "offload": lstate}
    return state, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_124m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-5)
    ap.add_argument("--lora-rank", type=int, default=0)
    ap.add_argument("--lora-alpha", type=float, default=None,
                    help="LoRA scaling numerator (effective scale "
                         "alpha/rank; default 32); requires --lora-rank")
    ap.add_argument("--lora-targets", default=None,
                    help="comma-separated leaf names to adapt (default "
                         "wq,wk,wv,wo; use e.g. w_x,w_out for the ssm "
                         "family); requires --lora-rank")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--attention", default="streaming")
    ap.add_argument("--scan-layers", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="lax.scan over the stacked layers (in-memory path); "
                         "--no-scan-layers unrolls them")
    ap.add_argument("--offload-segments", type=int, default=0,
                    help="page (param, m, v) state to N mmap segment files; "
                         "optimizer updates stream segment-by-segment (C1)")
    ap.add_argument("--offload-stream-params", action="store_true",
                    help="layer-streamed fwd/bwd: segments become "
                         "layer-aligned (one per block + head) and params "
                         "page through the window during compute too")
    ap.add_argument("--offload-dir", default="",
                    help="segment-file directory (default <out>/offload)")
    ap.add_argument("--offload-resident", type=int, default=2,
                    help="LRU window size in segments")
    ap.add_argument("--offload-prefetch",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="background double-buffered segment prefetch")
    ap.add_argument("--offload-moment-dtype", default="float32",
                    choices=("float32", "bfloat16"),
                    help="storage dtype of the AdamW m/v segments "
                         "(bfloat16 halves their bytes; update math stays "
                         "fp32 via the bf16 segment codec)")
    ap.add_argument("--offload-async-writeback",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="bounded background dirty-segment writer: eviction "
                         "no longer blocks on encode+msync (flush and "
                         "snapshots stay barriers)")
    ap.add_argument("--offload-staging",
                    action=argparse.BooleanOptionalAction, default=True,
                    help="double-buffered host->device staging of block "
                         "i+1 while block i computes (deferred loss/"
                         "grad-norm syncs are unconditional)")
    ap.add_argument("--base-quant", default="", choices=("", "int8"),
                    help="quantize the frozen base segments of streamed "
                         "LoRA (requires --lora-rank and "
                         "--offload-stream-params): int8 per-channel "
                         "absmax, ~4x less flash and resident window; the "
                         "jitted per-block program dequantizes on the fly")
    ap.add_argument("--offload-activations", action="store_true",
                    help="spill layer-boundary activations to a per-step "
                         "scratch store during the streamed forward sweep "
                         "and re-pull them in reverse order for backward "
                         "(requires --offload-stream-params): resident "
                         "activations stop scaling with depth at long seq")
    ap.add_argument("--activation-codec", default="fp32",
                    choices=("fp32", "bf16", "int8"),
                    help="storage precision of spilled activations: fp32 is "
                         "a bit-exact spill, bf16 halves the bytes, int8 "
                         "quarters them (per-token absmax)")
    ap.add_argument("--offload-io", default="",
                    choices=("", "mmap", "pread", "direct", "uring", "auto"),
                    help="segment read backend: mmap (default, page-cache "
                         "oracle), pread (batched positional reads straight "
                         "into window buffers), direct (O_DIRECT, bypasses "
                         "the page cache), uring (one io_uring SQE batch "
                         "per segment pull), auto (probe uring -> direct -> "
                         "pread).  Unsupported backends fall back to pread "
                         "with a logged note; bytes are bit-identical "
                         "across all of them.  Default '' defers to "
                         "$REPRO_OFFLOAD_IO, else mmap")
    ap.add_argument("--out", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--energy", action="store_true",
                    help="enable the K/mu/rho governor with a simulated battery")
    args = ap.parse_args()
    # fail at parse time, not deep inside the first step's split_batch
    if args.microbatches < 1:
        ap.error(f"--microbatches must be >= 1, got {args.microbatches}")
    if args.batch % args.microbatches != 0:
        ap.error(f"--batch {args.batch} is not divisible by --microbatches "
                 f"{args.microbatches}; each micro-batch must be equal-sized")
    if args.lora_rank == 0 and (args.lora_alpha is not None
                                or args.lora_targets is not None):
        ap.error("--lora-alpha/--lora-targets have no effect without "
                 "--lora-rank N")
    lora_targets = tuple(
        t.strip() for t in (args.lora_targets or "wq,wk,wv,wo").split(",")
        if t.strip())
    if args.lora_rank > 0 and not lora_targets:
        ap.error("--lora-rank set but --lora-targets is empty")
    if args.base_quant and not (args.lora_rank > 0
                                and args.offload_stream_params):
        ap.error("--base-quant applies to the frozen base of streamed LoRA; "
                 "pass --lora-rank N and --offload-stream-params with it")
    if args.offload_activations and not args.offload_stream_params:
        ap.error("--offload-activations spills the streamed driver's "
                 "boundary activations; pass --offload-stream-params with it")
    from repro.core.remat import POLICIES
    if args.remat not in POLICIES:
        ap.error(f"--remat {args.remat!r} is not a remat policy "
                 f"(choose from {', '.join(POLICIES)})")
    if args.attention not in ("naive", "streaming", "ref", "flash"):
        ap.error(f"--attention {args.attention!r} is not an attention impl "
                 "(choose from naive, streaming, ref, flash)")

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq,
        microbatches=args.microbatches, learning_rate=args.lr,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        lora_rank=args.lora_rank,
        lora_alpha=((32.0 if args.lora_alpha is None else args.lora_alpha)
                    if args.lora_rank else 0.0),
        lora_targets=lora_targets,
        remat_policy=args.remat, attention_impl=args.attention,
        scan_layers=args.scan_layers,
        compute_dtype="float32", checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.out or "",
        offload_segments=args.offload_segments,
        offload_stream_params=args.offload_stream_params,
        offload_dir=args.offload_dir,
        offload_resident=args.offload_resident,
        offload_prefetch=args.offload_prefetch,
        offload_moment_dtype=args.offload_moment_dtype,
        offload_async_writeback=args.offload_async_writeback,
        offload_staging=args.offload_staging,
        base_quant=args.base_quant,
        offload_activations=args.offload_activations,
        activation_codec=args.activation_codec,
        offload_io=args.offload_io)
    governor = None
    if args.energy:
        governor = EnergyGovernor(monitor=SimulatedBattery(
            level=70.0, drain_per_unit=0.5))
    t0 = time.time()
    state, obs = train_loop(cfg, tcfg, out_dir=args.out, seed=args.seed,
                            governor=governor)
    print(f"done in {time.time()-t0:.1f}s | final loss "
          f"{obs.rows[-1]['loss']:.4f} | peak RSS {obs.peak_rss_mb:.0f} MB")


if __name__ == "__main__":
    main()
