"""End-to-end training driver (paper Application layer).

Composes the full resource-aware runtime: data pipeline -> sharded train step
(C1–C4) -> energy governor (C5) -> metrics observer + visualizer (C7) ->
fault-tolerant checkpointing.  Runs on 1 CPU device (paper-scale models) or
any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gpt2_124m \
        --steps 200 --batch 8 --seq 128 --lora-rank 8 --out runs/gpt2
"""
from __future__ import annotations

import argparse
import os
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import ModelConfig, TrainConfig
from repro.checkpoint.store import CheckpointStore, latest_step, restore
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.core.step import init_state, make_eval_step, make_train_step
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset, packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.models import registry
from repro.runtime.metrics import MetricsObserver
from repro.runtime.visualizer import write_dashboard


def build_data(cfg: ModelConfig, tcfg: TrainConfig, n_sentences: int = 4000,
               seed: int = 0):
    tok = ByteTokenizer()
    text = synthetic_wikitext(n_sentences, seed=seed)
    ds = LMDataset(text, tok, tcfg.seq_len)
    # token ids must stay inside the model vocab
    assert tok.vocab_size <= cfg.vocab_size, (tok.vocab_size, cfg.vocab_size)
    return ds


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, *, out_dir: Optional[str],
               seed: int = 0, resume: bool = True, eval_every: int = 0,
               governor: Optional[EnergyGovernor] = None,
               dataset=None, print_fn=print):
    ds = dataset or build_data(cfg, tcfg, seed=seed)
    obs = MetricsObserver(out_dir=out_dir, print_fn=print_fn)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0,))
    state = init_state(jax.random.PRNGKey(seed), cfg, tcfg)

    store = None
    start = 0
    if tcfg.checkpoint_every > 0 and out_dir:
        ckdir = os.path.join(out_dir, "ckpt")
        store = CheckpointStore(ckdir, keep=tcfg.keep_checkpoints)
        if resume and latest_step(ckdir) is not None:
            state, start = restore(ckdir, state)
            start = int(start)
            if print_fn:
                print_fn(f"[resume] from step {start}")

        def _flush(signum, frame):  # preemption tolerance
            store.save_sync(state, int(state["step"]))
            raise SystemExit(128 + signum)
        try:
            signal.signal(signal.SIGTERM, _flush)
        except ValueError:
            pass  # not the main thread

    batches = packed_batches(ds, tcfg.global_batch, seed=seed, epochs=10_000)
    for _ in range(start):
        next(batches)  # deterministic data order on resume

    tokens_per_step = tcfg.global_batch * tcfg.seq_len
    for step in range(start, tcfg.total_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        obs.start_step()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        row = obs.end_step(step, metrics, tokens=tokens_per_step,
                           battery=(governor.monitor.fraction()
                                    if governor else 1.0))
        if governor is not None:
            governor.after_step(step, row["step_time_s"])
        if store and (step + 1) % tcfg.checkpoint_every == 0:
            store.save_async(state, step + 1)
    if store:
        store.wait()
        store.save_sync(state, int(state["step"]))
    obs.flush_csv()
    if out_dir:
        write_dashboard(obs.rows, os.path.join(out_dir, "dashboard.html"),
                        title=f"{cfg.name} | {'LoRA' if tcfg.lora_rank else 'Full-FT'}")
    return state, obs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2_124m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-5)
    ap.add_argument("--lora-rank", type=int, default=0)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--attention", default="streaming")
    ap.add_argument("--out", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--energy", action="store_true",
                    help="enable the K/mu/rho governor with a simulated battery")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq,
        microbatches=args.microbatches, learning_rate=args.lr,
        total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        lora_rank=args.lora_rank,
        lora_alpha=32.0 if args.lora_rank else 0.0,
        remat_policy=args.remat, attention_impl=args.attention,
        compute_dtype="float32", checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.out or "")
    governor = None
    if args.energy:
        governor = EnergyGovernor(monitor=SimulatedBattery(
            level=70.0, drain_per_unit=0.5))
    t0 = time.time()
    state, obs = train_loop(cfg, tcfg, out_dir=args.out, seed=args.seed,
                            governor=governor)
    print(f"done in {time.time()-t0:.1f}s | final loss "
          f"{obs.rows[-1]['loss']:.4f} | peak RSS {obs.peak_rss_mb:.0f} MB")


if __name__ == "__main__":
    main()
