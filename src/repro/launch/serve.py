"""Batched serving driver: chunked prefill + decode loop.

Greedy/sampled batched generation against the family-appropriate cache
(KV / SSM state / enc-dec cross cache).  Used by examples/serve_batch.py and
the serving smoke tests.

Prefill is *chunked*: ``models/lm.py::decode_step`` accepts (B, S) token
slabs, so the cache fills in ceil(P/chunk) jitted calls instead of P
token-at-a-time steps, with exactly matching decode numerics (the attention
mask hides kv positions past the write head; the SSM state path scans the
slab inside the jit).  ``prefill_stepwise`` keeps the token-at-a-time fill
as the reference oracle — tests/test_serving.py pins chunked == step-wise.

The serve step is compiled once per ``generate`` call and shared between
prefill and decode (the previous driver jitted it twice).  For multi-user
multi-adapter serving see ``repro.serve.ServeEngine``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import ModelConfig, TrainConfig
from repro.core.step import make_serve_step
from repro.models import registry
from repro.param import init_params


def make_serve_fn(cfg: ModelConfig, tcfg: TrainConfig):
    """The one shared jitted serve step: (params, cache, tokens, index) ->
    (logits at last position, new cache), cache donated."""
    return jax.jit(make_serve_step(cfg, tcfg), donate_argnums=(1,))


def _init_cache(cfg: ModelConfig, b: int, max_len: int):
    return init_params(jax.random.PRNGKey(0),
                       registry.cache_specs(cfg, b, max_len, jnp.float32))


def prefill(params, prompts, cfg: ModelConfig, tcfg: TrainConfig,
            max_len: int, serve=None, chunk: int = 32):
    """Fill the cache with (B, chunk) slabs of prompt tokens per jitted call.

    encdec (whisper) decodes strictly token-at-a-time, so it falls back to
    the step-wise oracle below.  ``serve`` shares an already-compiled serve
    step; the final slab is the remainder (never padded — padding would
    corrupt the SSM state carried across slabs).
    """
    if cfg.family == "encdec":
        return prefill_stepwise(params, prompts, cfg, tcfg, max_len,
                                serve=serve)
    b, plen = prompts.shape
    cache = _init_cache(cfg, b, max_len)
    if serve is None:
        serve = make_serve_fn(cfg, tcfg)
    logits = None
    for start in range(0, plen, chunk):
        slab = prompts[:, start:start + chunk]
        logits, cache = serve(params, cache, slab, jnp.int32(start))
    return logits, cache


def prefill_stepwise(params, prompts, cfg: ModelConfig, tcfg: TrainConfig,
                     max_len: int, serve=None):
    """Reference oracle: fill the cache one decode step per prompt token.
    Chunked prefill must reproduce this bit-for-bit on the same backend."""
    b, plen = prompts.shape
    cache = _init_cache(cfg, b, max_len)
    if serve is None:
        serve = make_serve_fn(cfg, tcfg)
    logits = None
    for i in range(plen):
        logits, cache = serve(params, cache, prompts[:, i:i + 1],
                              jnp.int32(i))
    return logits, cache


def generate(params, prompts, cfg: ModelConfig, tcfg: TrainConfig,
             n_new: int = 16, greedy: bool = True, rng=None,
             chunk: int = 32, stepwise_prefill: bool = False):
    b, plen = prompts.shape
    max_len = plen + n_new + 1
    if not greedy and rng is None:
        rng = jax.random.PRNGKey(0)
    # one compile, shared by prefill and the decode loop
    serve = make_serve_fn(cfg, tcfg)
    fill = prefill_stepwise if stepwise_prefill else prefill
    kw = {} if stepwise_prefill else {"chunk": chunk}
    logits, cache = fill(params, prompts, cfg, tcfg, max_len, serve=serve,
                         **kw)
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(n_new):
        out.append(tok)
        logits, cache = serve(params, cache, tok, jnp.int32(plen + i))
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits)[:, None].astype(jnp.int32)
    out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 3,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = generate(params, prompts, cfg, tcfg, n_new=args.new_tokens,
                    chunk=args.prefill_chunk)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
