"""Batched serving driver: prefill (teacher-forced cache fill) + decode loop.

Greedy batched generation against the family-appropriate cache (KV / SSM
state / enc-dec cross cache).  Used by examples/serve_batch.py and the
serving smoke tests.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.config import ModelConfig, TrainConfig
from repro.core.step import make_serve_step
from repro.models import registry
from repro.param import init_params


def prefill(params, prompts, cfg: ModelConfig, tcfg: TrainConfig,
            max_len: int):
    """Fill the cache by running decode steps over the prompt tokens.

    (A fused prefill kernel is the production path; the step-wise fill keeps
    this driver family-agnostic and exactly matches decode numerics.)
    """
    b, plen = prompts.shape
    cache = init_params(jax.random.PRNGKey(0),
                        registry.cache_specs(cfg, b, max_len, jnp.float32))
    serve = jax.jit(make_serve_step(cfg, tcfg), donate_argnums=(1,))
    logits = None
    for i in range(plen):
        logits, cache = serve(params, cache, prompts[:, i:i + 1],
                              jnp.int32(i))
    return logits, cache


def generate(params, prompts, cfg: ModelConfig, tcfg: TrainConfig,
             n_new: int = 16, greedy: bool = True, rng=None):
    b, plen = prompts.shape
    max_len = plen + n_new + 1
    logits, cache = prefill(params, prompts, cfg, tcfg, max_len)
    serve = jax.jit(make_serve_step(cfg, tcfg), donate_argnums=(1,))
    out = []
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    for i in range(n_new):
        out.append(tok)
        logits, cache = serve(params, cache, tok, jnp.int32(plen + i))
        if greedy:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        else:
            rng, sub = jax.random.split(rng)
            tok = jax.random.categorical(sub, logits)[:, None].astype(jnp.int32)
    out.append(tok)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen15_05b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    tcfg = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                       attn_chunk=64)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 3,
                                 cfg.vocab_size, jnp.int32)
    t0 = time.time()
    toks = generate(params, prompts, cfg, tcfg, n_new=args.new_tokens)
    dt = time.time() - t0
    print(f"generated {toks.shape} in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print(np.asarray(toks)[:2])


if __name__ == "__main__":
    main()
