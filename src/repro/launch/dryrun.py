import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod)
  2. builds abstract inputs (ShapeDtypeStruct + NamedSharding — no allocation)
  3. lowers + compiles the appropriate step:
       train_4k     -> train_step (fwd+bwd+AdamW, grad accumulation, remat)
       prefill_32k  -> prefill_step (teacher-forced fwd, last-token logits)
       decode_*     -> serve_step (1 token against a donated KV/state cache)
  4. records memory_analysis, cost_analysis, and the collective-bytes tally
     parsed from the compiled HLO into benchmarks/results/dryrun/*.json
     together with the three roofline terms (TPU v5e constants).

Collective wire-bytes model (documented here, used by §Roofline):
  all-gather          result bytes              (~ full gathered tensor)
  reduce-scatter      result bytes x group      (full reduced tensor)
  all-reduce          2 x result bytes          (ring RS + AG)
  all-to-all          result bytes
  collective-permute  result bytes
"""
import argparse
import json
import re
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.config import SHAPES, ModelConfig, ShapeSpec, TrainConfig, dtype_of
from repro.core.step import make_train_step, state_specs
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.param import ParamSpec, tree_map_specs
from repro.sharding import PRESETS, resolve_spec

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")

# per-arch train micro-batching (memory lever; hillclimb overrides via CLI).
# granite/hymba raised after the mem-fix campaign (EXPERIMENTS.md §Dry-run).
TRAIN_MICRO = {
    "command-r-plus-104b": 16, "dbrx-132b": 16, "granite-34b": 16,
    "phi3.5-moe-42b": 8, "qwen2-vl-7b": 8, "minitron-8b": 8,
    "whisper-large-v3": 4, "hymba-1.5b": 8, "qwen1.5-0.5b": 2,
    "mamba2-130m": 2,
}


def cell_train_config(cfg: ModelConfig, shape: ShapeSpec,
                      overrides: Optional[Dict[str, Any]] = None
                      ) -> TrainConfig:
    o = dict(overrides or {})
    if shape.kind == "train":
        base = dict(global_batch=shape.global_batch, seq_len=shape.seq_len,
                    microbatches=TRAIN_MICRO.get(cfg.name, 4),
                    remat_policy="full", attention_impl="streaming",
                    attn_chunk=512, compute_dtype="bfloat16",
                    param_dtype="float32", shard_preset="fsdp_tp",
                    scan_layers=True)
    elif shape.kind == "prefill":
        base = dict(global_batch=shape.global_batch, seq_len=shape.seq_len,
                    remat_policy="none", attention_impl="streaming",
                    attn_chunk=512, compute_dtype="bfloat16",
                    param_dtype="bfloat16", shard_preset="fsdp_tp",
                    # bound MoE expert buffers at 1M-token prefill
                    moe_seq_chunks=8 if cfg.n_experts > 0 else 1)
    else:  # decode
        preset = "fsdp_tp_long" if shape.global_batch == 1 else "fsdp_tp"
        base = dict(global_batch=shape.global_batch, seq_len=shape.seq_len,
                    remat_policy="none", attention_impl="streaming",
                    attn_chunk=512, compute_dtype="bfloat16",
                    param_dtype="bfloat16", shard_preset=preset)
    base.update(o)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct + sharding, zero allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype, mesh, pspec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, pspec))


def specs_to_abstract(specs, mesh, preset):
    rules = PRESETS[preset]
    mesh_axes = tuple(mesh.axis_names)

    def one(s: ParamSpec):
        return _sds(s.shape, s.dtype, mesh,
                    resolve_spec(s.axes, rules, mesh_axes))

    return tree_map_specs(one, specs)


def batch_abstract(cfg: ModelConfig, shape: ShapeSpec, mesh, preset: str):
    rules = PRESETS[preset]
    mesh_axes = tuple(mesh.axis_names)
    shapes = registry.batch_shapes(cfg, shape.global_batch, shape.seq_len,
                                   shape.kind)
    out = {}
    for k, (shp, dt) in shapes.items():
        axes = ["batch"] + [None] * (len(shp) - 1)
        out[k] = _sds(shp, dt, mesh, resolve_spec(tuple(axes), rules,
                                                  mesh_axes))
    return out


def input_specs(arch: str, shape_name: str, *, multi_pod: bool = False,
                overrides=None):
    """Harness entry point: ShapeDtypeStruct stand-ins for every model input
    of a cell, sharded for the production mesh."""
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    tcfg = cell_train_config(cfg, shape, overrides)
    return batch_abstract(cfg, shape, mesh, tcfg.shard_preset)


def decode_cache_len(seq_len: int) -> int:
    return seq_len + 512  # mesh-divisible headroom; masked past the index


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------
_DT_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
             "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1}
_COLL_RE = re.compile(
    r"=\s.*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_GROUP_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _line_result_bytes(line: str) -> int:
    """Sum byte sizes of the result shapes on an HLO line (handles tuples)."""
    # result type annotation appears right after '=': take shapes before op name
    m = re.search(r"=\s*(.*?)\s(all-gather|all-reduce|reduce-scatter|"
                  r"all-to-all|collective-permute)", line)
    if not m:
        return 0
    total = 0
    for dt, dims in _SHAPE_RE.findall(m.group(1)):
        if dt not in _DT_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DT_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Any]:
    """Tally collectives from the compiled HLO text.

    NOTE (recorded as a witness, not the roofline source): ops inside
    ``while`` bodies appear once in the text but execute trip-count times —
    exactly the same undercount as cost_analysis.  The analytic model in
    repro/analysis.py is the roofline source; this tally proves which
    collective kinds/groups the partitioner actually emitted.
    """
    per_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    wire = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or (m.group(2) == "-done"):
            continue
        kind = m.group(1)
        rb = _line_result_bytes(line)
        gm = _GROUP_RE.search(line)
        if gm:
            group = len(gm.group(1).split(","))
        else:
            gi = _GROUP_IOTA_RE.search(line)
            group = int(gi.group(2)) if gi else 1
        if kind == "all-gather":
            w = rb
        elif kind == "reduce-scatter":
            w = rb * group
        elif kind == "all-reduce":
            w = 2 * rb
        else:
            w = rb
        per_kind[kind] = per_kind.get(kind, 0) + w
        counts[kind] = counts.get(kind, 0) + 1
        wire += w
    return {"wire_bytes": wire, "per_kind": per_kind, "counts": counts}


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------
def build_train(cfg, tcfg, shape, mesh):
    step = make_train_step(cfg, tcfg)
    st_specs = state_specs(cfg, tcfg)
    st_abs = specs_to_abstract(st_specs, mesh, tcfg.shard_preset)
    b_abs = batch_abstract(cfg, shape, mesh, tcfg.shard_preset)
    jitted = jax.jit(step, donate_argnums=(0,))
    return jitted, (st_abs, b_abs)


def build_prefill(cfg, tcfg, shape, mesh):
    fwd = registry.forward_fn(cfg)

    def prefill_step(params, batch):
        logits, _ = fwd(params, batch, cfg, tcfg)
        return logits[:, -1]

    pspecs = tree_map_specs(
        lambda s: ParamSpec(s.shape, dtype_of(tcfg.param_dtype), s.axes,
                            s.init, s.scale), registry.param_specs(cfg))
    p_abs = specs_to_abstract(pspecs, mesh, tcfg.shard_preset)
    b_abs = batch_abstract(cfg, shape, mesh, tcfg.shard_preset)
    return jax.jit(prefill_step), (p_abs, b_abs)


def build_decode(cfg, tcfg, shape, mesh):
    decode = registry.decode_fn(cfg)

    def serve_step(params, cache, tokens, index):
        return decode(params, cache, tokens, index, cfg, tcfg)

    pspecs = tree_map_specs(
        lambda s: ParamSpec(s.shape, dtype_of(tcfg.param_dtype), s.axes,
                            s.init, s.scale), registry.param_specs(cfg))
    p_abs = specs_to_abstract(pspecs, mesh, tcfg.shard_preset)
    cspecs = registry.cache_specs(cfg, shape.global_batch,
                                  decode_cache_len(shape.seq_len),
                                  jnp.bfloat16)
    c_abs = specs_to_abstract(cspecs, mesh, tcfg.shard_preset)
    b_abs = batch_abstract(cfg, shape, mesh, tcfg.shard_preset)
    idx = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return (jax.jit(serve_step, donate_argnums=(1,)),
            (p_abs, c_abs, b_abs["tokens"], idx))


# ---------------------------------------------------------------------------
# Roofline terms — analytic model (repro/analysis.py) is the source; raw
# cost_analysis / HLO tallies are recorded as witnesses (while-body-once
# undercount documented there).
# ---------------------------------------------------------------------------
from repro.analysis import analytic_roofline  # noqa: E402


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             overrides=None, tag: str = "baseline",
             save: bool = True) -> Dict[str, Any]:
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec = {"arch": cfg.name, "shape": shape_name, "status":
               "SKIP(full-attention)", "tag": tag,
               "mesh": "multi" if multi_pod else "single"}
        if save:
            _save(rec, arch, shape_name, multi_pod, tag)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    tcfg = cell_train_config(cfg, shape, overrides)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            fn, args = build_train(cfg, tcfg, shape, mesh)
        elif shape.kind == "prefill":
            fn, args = build_prefill(cfg, tcfg, shape, mesh)
        else:
            fn, args = build_decode(cfg, tcfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):  # older jax returns [dict]
            cost = cost[0]
        coll = parse_collectives(compiled.as_text())

    rec = {
        "arch": cfg.name, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "n_devices": n_dev, "tag": tag, "status": "OK",
        "kind": shape.kind,
        "tcfg": {k: getattr(tcfg, k) for k in
                 ("microbatches", "remat_policy", "attention_impl",
                  "attn_chunk", "shard_preset", "compute_dtype",
                  "param_dtype", "grad_reduce_dtype", "moe_dispatch_dtype",
                  "moe_seq_chunks")},
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        "cost_raw": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals")
                     if k in cost},
        "collectives_hlo": coll,
        "roofline": analytic_roofline(cfg, tcfg, shape, multi_pod),
    }
    if save:
        _save(rec, arch, shape_name, multi_pod, tag)
    return rec


def _save(rec, arch, shape_name, multi_pod, tag):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    mesh_tag = "multi" if multi_pod else "single"
    path = os.path.join(RESULTS_DIR,
                        f"{arch}__{shape_name}__{mesh_tag}__{tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every remaining baseline cell")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--attn-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--preset", default=None)
    ap.add_argument("--grad-reduce-dtype", default=None)
    ap.add_argument("--moe-dispatch-dtype", default=None)
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--moe-seq-chunks", type=int, default=None)
    args = ap.parse_args()

    o = {}
    if args.microbatches is not None:
        o["microbatches"] = args.microbatches
    if args.attn_chunk is not None:
        o["attn_chunk"] = args.attn_chunk
    if args.remat is not None:
        o["remat_policy"] = args.remat
    if args.preset is not None:
        o["shard_preset"] = args.preset
    if args.grad_reduce_dtype is not None:
        o["grad_reduce_dtype"] = args.grad_reduce_dtype
    if args.moe_dispatch_dtype is not None:
        o["moe_dispatch_dtype"] = args.moe_dispatch_dtype
    if args.param_dtype is not None:
        o["param_dtype"] = args.param_dtype
    if args.moe_seq_chunks is not None:
        o["moe_seq_chunks"] = args.moe_seq_chunks

    cells = []
    archs = [args.arch] if args.arch else list(configs.ASSIGNED)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    for arch, shape_name in cells:
        mesh_tag = "multi" if args.multi_pod else "single"
        path = os.path.join(
            RESULTS_DIR, f"{arch}__{shape_name}__{mesh_tag}__{args.tag}.json")
        if args.skip_done and os.path.exists(path):
            print(f"[skip] {arch} x {shape_name} ({mesh_tag})")
            continue
        print(f"[cell] {arch} x {shape_name} ({mesh_tag}) ...", flush=True)
        try:
            rec = run_cell(arch, shape_name, multi_pod=args.multi_pod,
                           overrides=o, tag=args.tag)
        except Exception as e:  # record the failure — these are bugs to fix
            rec = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                   "tag": args.tag, "status": f"FAIL: {type(e).__name__}",
                   "error": str(e)[:2000]}
            _save(rec, arch, shape_name, args.multi_pod, args.tag)
            print(f"  FAILED: {e}")
            continue
        if rec["status"] == "OK":
            r = rec["roofline"]
            tb = rec["memory"]["temp_bytes"]
            print(f"  OK lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"dominant={r['dominant']} "
                  f"frac={r['roofline_fraction']:.3f} "
                  f"temp={(tb or 0)/1e9:.2f}GB")
        else:
            print(f"  {rec['status']}")


if __name__ == "__main__":
    main()
