"""Tuned process-environment profile for phone-budget runs.

The long-sequence streamed trainer is allocator- and logging-sensitive:
every step mmaps/munmaps segment files, round-trips multi-hundred-MB host
activation buffers through the spill store, and (on glibc malloc) the
transient fp32 spill copies fragment the arena badly enough to inflate
peak RSS well past the analytic resident bound.  This module centralizes
the launch profile the benches and ``examples/run_tuned.sh`` share:

- **tcmalloc** via ``LD_PRELOAD`` when a system copy exists (thread-caching
  allocator: the AsyncWriter / Prefetcher threads allocate and free the
  same segment-sized buffers every step, exactly tcmalloc's sweet spot),
  with ``TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD`` raised so multi-GB
  streaming allocations don't spam stderr;
- **XLA flags**: ``--xla_force_host_platform_device_count`` (host-mesh
  sizing for the dry-run/sharding tools) and step markers for profiler
  alignment;
- ``TF_CPP_MIN_LOG_LEVEL=4`` to silence the XLA/TSL banner noise that
  otherwise pollutes benchmark CSV capture;
- ``REPRO_OFFLOAD_IO`` set to the best *probed* raw segment-read backend
  (io_uring -> O_DIRECT -> pread, see repro/offload/readers.py) so tuned
  runs stop double-buffering segment pulls through the page cache.  An
  existing value in the environment always wins, and every backend is
  bit-identical with the mmap oracle — this is a transport choice, never
  a numerics one.

``LD_PRELOAD`` only takes effect at process start, so the overlay is
applied by *launchers* (``run_tuned.sh``, or ``python -m repro.launch.env
<cmd> ...`` which re-execs), never mid-process.
"""
from __future__ import annotations

import os
import shlex
import sys
from typing import Dict, Optional

# well-known system locations, checked in order (full build first — it
# includes the heap profiler hooks the bench harness can enable)
TCMALLOC_CANDIDATES = (
    "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc.so.4",
    "/usr/lib/aarch64-linux-gnu/libtcmalloc_minimal.so.4",
    "/usr/lib/libtcmalloc.so.4",
    "/usr/lib/libtcmalloc_minimal.so.4",
)

# large-alloc report threshold: 60 GB, i.e. effectively off — streaming
# training legitimately makes multi-GB host allocations every few steps
TCMALLOC_REPORT_THRESHOLD = "60000000000"


def find_tcmalloc() -> Optional[str]:
    """First present tcmalloc shared object, or None (profile degrades
    gracefully on images without gperftools — nothing to install)."""
    for path in TCMALLOC_CANDIDATES:
        if os.path.exists(path):
            return path
    return None


def probe_io_backend() -> str:
    """Best available raw segment-read backend on this kernel/filesystem:
    ``uring`` when ``io_uring_setup`` round-trips, else ``direct`` when
    O_DIRECT reads work in the working directory, else ``pread`` (always
    available).  One cached functional probe per backend — cheap enough
    to run at launcher startup."""
    from repro.offload.readers import backend_available
    for name in ("uring", "direct", "pread"):
        if backend_available(name, "."):
            return name
    return "mmap"   # unreachable in practice: pread always probes true


def tuned_env(host_device_count: int = 0, step_markers: bool = True,
              base: Optional[Dict[str, str]] = None,
              io_backend: str = "auto") -> Dict[str, str]:
    """The env-var *overlay* of the tuned profile (only the keys to set).

    ``host_device_count > 0`` forces that many host-platform XLA devices
    (the mesh tools' CPU stand-in); ``step_markers`` adds the step-marker
    annotation XLA flag so profiles align on step boundaries.  Existing
    ``XLA_FLAGS`` / ``LD_PRELOAD`` in ``base`` (default: this process's
    environment) are extended, not clobbered.
    """
    base = os.environ if base is None else base
    env: Dict[str, str] = {}

    tc = find_tcmalloc()
    if tc is not None:
        pre = base.get("LD_PRELOAD", "")
        if tc not in pre.split(":"):
            env["LD_PRELOAD"] = f"{tc}:{pre}" if pre else tc
        env["TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD"] = \
            base.get("TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD",
                     TCMALLOC_REPORT_THRESHOLD)

    flags = []
    if step_markers:
        # enum-named value — the numeric spelling is rejected (fatally) by
        # XLA's env-flag parser on current jaxlibs
        flags.append("--xla_step_marker_location=STEP_MARK_AT_ENTRY")
    if host_device_count > 0:
        flags.append(
            f"--xla_force_host_platform_device_count={host_device_count}")
    existing = base.get("XLA_FLAGS", "")
    new = [f for f in flags if f.split("=")[0] not in existing]
    if new:
        env["XLA_FLAGS"] = (existing + " " + " ".join(new)).strip()

    env.setdefault("TF_CPP_MIN_LOG_LEVEL",
                   base.get("TF_CPP_MIN_LOG_LEVEL", "4"))

    # raw segment I/O: probe once here, at launcher startup, so every
    # store in the child process picks the backend up from the env var
    # without per-store probing.  ``io_backend=""`` disables; an explicit
    # name skips the probe (SegmentStore still degrades it gracefully)
    if io_backend and "REPRO_OFFLOAD_IO" not in base:
        env["REPRO_OFFLOAD_IO"] = (probe_io_backend()
                                   if io_backend == "auto" else io_backend)
    return env


def main(argv=None) -> int:
    """``python -m repro.launch.env [--print] [--devices N] [--io B] [cmd ...]``

    With a command: re-exec it under the tuned profile (``LD_PRELOAD``
    needs a fresh process).  With ``--print``: emit ``export`` lines for
    shell ``eval`` (what ``examples/run_tuned.sh`` does).
    """
    argv = list(sys.argv[1:] if argv is None else argv)
    devices = 0
    emit = False
    io_backend = "auto"
    while argv and argv[0].startswith("--"):
        if argv[0] == "--print":
            emit = True
            argv.pop(0)
        elif argv[0] == "--devices":
            argv.pop(0)
            devices = int(argv.pop(0))
        elif argv[0] == "--io":
            argv.pop(0)
            io_backend = argv.pop(0)
        else:
            raise SystemExit(f"unknown flag {argv[0]!r}")
    overlay = tuned_env(host_device_count=devices, io_backend=io_backend)
    if emit or not argv:
        for k, v in sorted(overlay.items()):
            print(f"export {k}={shlex.quote(v)}")
        return 0
    env = dict(os.environ)
    env.update(overlay)
    os.execvpe(argv[0], argv, env)
    return 1  # unreachable


if __name__ == "__main__":
    raise SystemExit(main())
