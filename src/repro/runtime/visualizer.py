"""Training visualizer (paper §6.4, Fig 8): a static HTML dashboard.

Decoupled from the training engine: reads the observer's metrics rows and
renders loss/PPL/RSS/energy sparkline panels + a live-log table as one
self-contained HTML file (no JS dependencies), mirroring the paper's
progress / loss / PPL / peak-RSS / log panels.
"""
from __future__ import annotations

import html
import os
from typing import Dict, List


def _sparkline(values: List[float], width=560, height=120, label="") -> str:
    vals = [v for v in values if v == v and v is not None]
    if not vals:
        return f"<div>{label}: no data</div>"
    vmin, vmax = min(vals), max(vals)
    rng = (vmax - vmin) or 1.0
    pts = []
    for i, v in enumerate(values):
        if v is None or v != v:
            continue
        x = 10 + i * (width - 20) / max(len(values) - 1, 1)
        y = height - 15 - (v - vmin) / rng * (height - 30)
        pts.append(f"{x:.1f},{y:.1f}")
    return (
        f'<div class="panel"><h3>{html.escape(label)}</h3>'
        f'<svg width="{width}" height="{height}">'
        f'<polyline fill="none" stroke="#2b6cb0" stroke-width="1.5" '
        f'points="{" ".join(pts)}"/>'
        f'<text x="10" y="12" font-size="11">max {vmax:.4g}</text>'
        f'<text x="10" y="{height-2}" font-size="11">min {vmin:.4g}</text>'
        f"</svg></div>")


def write_dashboard(rows: List[Dict], out_path: str,
                    title: str = "MobileFineTuner-JAX training") -> str:
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    panels = []
    for key, label in [("loss", "Training loss"), ("ppl", "Perplexity"),
                       ("rss_mb", "RSS (MB)"), ("energy_kj", "Energy (kJ)"),
                       ("step_time_s", "Step time (s)"),
                       ("battery", "Battery fraction")]:
        panels.append(_sparkline([r.get(key) for r in rows], label=label))
    tail = rows[-12:]
    log_rows = "".join(
        "<tr>" + "".join(
            f"<td>{html.escape(f'{r.get(k):.4g}' if isinstance(r.get(k), float) else str(r.get(k)))}</td>"
            for k in ("step", "loss", "ppl", "step_time_s", "rss_mb"))
        + "</tr>" for r in tail)
    doc = f"""<!doctype html><html><head><meta charset="utf-8">
<title>{html.escape(title)}</title>
<style>body{{font-family:system-ui;margin:24px;background:#f7fafc}}
.panel{{display:inline-block;background:#fff;border:1px solid #e2e8f0;
border-radius:8px;padding:8px;margin:8px}}h3{{margin:2px 0 6px;font-size:13px}}
table{{border-collapse:collapse;background:#fff}}td,th{{border:1px solid #e2e8f0;
padding:3px 8px;font-size:12px}}</style></head><body>
<h1>{html.escape(title)}</h1>
<p>steps: {len(rows)} | final loss:
{rows[-1]['loss']:.4f} | peak RSS: {max(r['rss_mb'] for r in rows):.0f} MB</p>
{''.join(panels)}
<h3>Live log (last {len(tail)} steps)</h3>
<table><tr><th>step</th><th>loss</th><th>ppl</th><th>t(s)</th><th>rss</th></tr>
{log_rows}</table></body></html>"""
    with open(out_path, "w") as f:
        f.write(doc)
    return out_path
