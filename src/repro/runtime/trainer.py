"""TrainerRuntime: shared scaffolding for every train-loop variant.

Three loop variants compose this runtime (repro/launch/train.py):

  train_loop           fully in-memory jitted step
  offload_train_loop   in-memory fwd/bwd + segment-streamed optimizer (C1)
  stream_train_loop    layer-streamed fwd/bwd + streamed optimizer (C1, full)

The ~50 lines of setup/teardown they used to mirror live here exactly once:
data pipeline + deterministic skip-ahead on resume, MetricsObserver wiring,
CheckpointStore + SIGTERM preemption flush, energy-governor hook, cadence
checkpointing, and the CSV/dashboard teardown.  Each variant keeps only its
own state construction, resume guard and step body.
"""
from __future__ import annotations

import os
import signal
from typing import Callable, Iterator, Optional, Tuple

import jax.numpy as jnp

from repro.checkpoint.store import CheckpointStore, latest_step
from repro.config import ModelConfig, TrainConfig
from repro.offload.state import ensure_base_quant_match
from repro.data.corpus import synthetic_wikitext
from repro.data.dataset import LMDataset, packed_batches
from repro.data.tokenizer import ByteTokenizer
from repro.runtime.metrics import MetricsObserver
from repro.runtime.visualizer import write_dashboard


def build_data(cfg: ModelConfig, tcfg: TrainConfig, n_sentences: int = 4000,
               seed: int = 0):
    tok = ByteTokenizer()
    text = synthetic_wikitext(n_sentences, seed=seed)
    ds = LMDataset(text, tok, tcfg.seq_len)
    # token ids must stay inside the model vocab
    assert tok.vocab_size <= cfg.vocab_size, (tok.vocab_size, cfg.vocab_size)
    return ds


class TrainerRuntime:
    """One instance per training run; owns observer, data and checkpoints."""

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig, *,
                 out_dir: Optional[str], seed: int = 0,
                 governor=None, dataset=None, print_fn=print):
        self.cfg, self.tcfg = cfg, tcfg
        self.out_dir, self.seed = out_dir, seed
        self.governor, self.print_fn = governor, print_fn
        self.ds = dataset if dataset is not None else build_data(
            cfg, tcfg, seed=seed)
        self.obs = MetricsObserver(out_dir=out_dir, print_fn=print_fn)
        self.ckdir = (os.path.join(out_dir, "ckpt")
                      if (tcfg.checkpoint_every > 0 and out_dir) else None)
        self.store: Optional[CheckpointStore] = (
            CheckpointStore(self.ckdir, keep=tcfg.keep_checkpoints)
            if self.ckdir else None)
        self.tokens_per_step = tcfg.global_batch * tcfg.seq_len
        self._preempt_signum: Optional[int] = None
        self._preempt_flush: Optional[Callable[[], None]] = None
        self._prev_sigterm = None

    # ------------------------------------------------------------------
    # resume / fault tolerance
    # ------------------------------------------------------------------
    def latest_checkpoint(self) -> Optional[int]:
        return latest_step(self.ckdir) if self.ckdir else None

    def log(self, msg: str):
        if self.print_fn:
            self.print_fn(msg)

    def guard_segment_layout(self, ostate):
        """Reconcile CLI storage flags against an existing segment layout
        (one shared guard for every offload loop variant — this used to be
        mirrored per-loop).  Storage choices are fixed when the layout is
        created: a differing ``--offload-moment-dtype`` is merely ignored
        (warn), but a differing ``--base-quant`` would hand the jitted
        program the wrong encoding, so it hard-errors."""
        tcfg = self.tcfg
        if getattr(ostate, "frozen", False):
            if tcfg.offload_moment_dtype != "float32":
                self.log(f"[warn] --offload-moment-dtype "
                         f"{tcfg.offload_moment_dtype} ignored: the frozen "
                         "base layout stores params only (no m/v segments); "
                         "the adapter's moments live in RAM")
        elif ostate.moment_dtype != tcfg.offload_moment_dtype:
            self.log(f"[warn] --offload-moment-dtype "
                     f"{tcfg.offload_moment_dtype} ignored: the resumed "
                     f"segment files store {ostate.moment_dtype} moments "
                     "(fixed at create time)")
        ensure_base_quant_match(ostate, tcfg.base_quant)

    def install_sigterm(self, flush_fn: Callable[[], None],
                        defer: bool = False):
        """Preemption tolerance: flush a checkpoint on SIGTERM, then exit.

        ``defer=True`` records the signal and lets ``steps()`` run the flush
        at the next step *boundary* instead of inside the handler — required
        for the offload variants, whose segment files are mutated in place
        mid-step (a handler-time snapshot could capture a half-applied
        update sweep with a stale step count).
        """
        if self.store is None:
            return

        if defer:
            def _flush(signum, frame):
                self._preempt_signum = signum
                self._preempt_flush = flush_fn
        else:
            def _flush(signum, frame):
                flush_fn()
                raise SystemExit(128 + signum)
        try:
            self._prev_sigterm = signal.signal(signal.SIGTERM, _flush)
        except ValueError:
            pass  # not the main thread

    def restore_sigterm(self):
        """Hand SIGTERM back to whoever owned it before install_sigterm —
        a deferred handler whose flush only runs inside steps() must never
        outlive the loop (it would swallow termination requests)."""
        if self._prev_sigterm is not None:
            try:
                signal.signal(signal.SIGTERM, self._prev_sigterm)
            except ValueError:
                pass
            self._prev_sigterm = None

    # ------------------------------------------------------------------
    # the step scaffold
    # ------------------------------------------------------------------
    def steps(self, start: int) -> Iterator[Tuple[int, dict]]:
        """(step, device batch) pairs from ``start`` to total_steps, with the
        data iterator skipped ahead so resumed runs see the exact same
        order, and the observer's step timer armed."""
        batches = packed_batches(self.ds, self.tcfg.global_batch,
                                 seed=self.seed, epochs=10_000)
        for _ in range(start):
            next(batches)  # deterministic data order on resume
        try:
            for step in range(start, self.tcfg.total_steps):
                if self._preempt_signum is not None:  # deferred SIGTERM
                    self._preempt_flush()
                    raise SystemExit(128 + self._preempt_signum)
                batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
                self.obs.start_step()
                yield step, batch
        finally:
            # also runs when the consuming loop dies on an exception (the
            # generator is closed), so a crashed run stays killable
            self.restore_sigterm()

    def end_step(self, step: int, metrics) -> dict:
        row = self.obs.end_step(step, metrics, tokens=self.tokens_per_step,
                                battery=(self.governor.monitor.fraction()
                                         if self.governor else 1.0))
        if self.governor is not None:
            self.governor.after_step(step, row["step_time_s"])
        return row

    def checkpoint_due(self, step: int) -> bool:
        return (self.store is not None
                and (step + 1) % self.tcfg.checkpoint_every == 0)

    # ------------------------------------------------------------------
    # teardown
    # ------------------------------------------------------------------
    def finish(self, title: str) -> MetricsObserver:
        self.restore_sigterm()
        if self.store is not None:
            self.store.wait()
        self.obs.flush_csv()
        if self.out_dir:
            write_dashboard(self.obs.rows,
                            os.path.join(self.out_dir, "dashboard.html"),
                            title=title)
        if self._preempt_signum is not None:
            # SIGTERM landed after the last step: the loop's end-of-run save
            # already persisted the final state, so just exit as requested
            raise SystemExit(128 + self._preempt_signum)
        return self.obs
