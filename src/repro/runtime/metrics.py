"""Metrics observer (paper §6.1.2): step, losses, PPL/accuracy, RSS, power.

The paper reads RSS via ``dumpsys procstats`` and power via
``power_profile.xml``; here RSS comes from ``/proc/self/statm`` and power from
the pluggable power model (see core/energy.py) — same observer interface,
host-appropriate sources.  Writes JSONL + CSV; the visualizer renders them.
"""
from __future__ import annotations

import csv
import json
import math
import os
import time
from typing import Any, Dict, List, Optional


def read_rss_mb() -> float:
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE") / 1e6
    except Exception:
        return 0.0


class MetricsObserver:
    FIELDS = ("step", "loss", "ppl", "accuracy", "grad_norm", "lr",
              "step_time_s", "rss_mb", "power_w", "energy_kj", "battery",
              "tokens_per_s")

    def __init__(self, out_dir: Optional[str] = None, power_watts: float = 6.0,
                 log_every: int = 1, print_fn=print):
        self.out_dir = out_dir
        self.power_watts = power_watts  # phone-class sustained draw default
        self.log_every = log_every
        self.print_fn = print_fn
        self.rows: List[Dict[str, Any]] = []
        self.energy_kj = 0.0
        self._t0 = None
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)

    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int, metrics: Dict[str, Any],
                 tokens: float = 0.0, battery: float = 1.0):
        dt = (time.perf_counter() - self._t0) if self._t0 else 0.0
        self.energy_kj += self.power_watts * dt / 1000.0
        loss = float(metrics.get("loss", float("nan")))
        row = {
            "step": step,
            "loss": loss,
            "ppl": float(math.exp(min(loss, 30.0))) if loss == loss else None,
            "accuracy": float(metrics.get("accuracy", float("nan"))),
            "grad_norm": float(metrics.get("grad_norm", float("nan"))),
            "lr": float(metrics.get("lr", float("nan"))),
            "step_time_s": dt,
            "rss_mb": read_rss_mb(),
            "power_w": self.power_watts,
            "energy_kj": self.energy_kj,
            "battery": battery,
            "tokens_per_s": tokens / dt if dt > 0 else 0.0,
        }
        self.rows.append(row)
        if self.out_dir:
            with open(os.path.join(self.out_dir, "metrics.jsonl"), "a") as f:
                f.write(json.dumps(row) + "\n")
        if self.print_fn and step % self.log_every == 0:
            self.print_fn(
                f"step {step:5d} | loss {row['loss']:.4f} | "
                f"ppl {row['ppl']:.2f} | {dt*1e3:.0f} ms | "
                f"rss {row['rss_mb']:.0f} MB | energy {self.energy_kj:.2f} kJ")
        return row

    def flush_csv(self):
        if not (self.out_dir and self.rows):
            return None
        path = os.path.join(self.out_dir, "metrics.csv")
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(self.rows[0].keys()))
            w.writeheader()
            w.writerows(self.rows)
        return path

    @property
    def peak_rss_mb(self) -> float:
        return max((r["rss_mb"] for r in self.rows), default=0.0)
