from repro.runtime.metrics import MetricsObserver, read_rss_mb  # noqa: F401
from repro.runtime.trainer import TrainerRuntime, build_data  # noqa: F401
from repro.runtime.visualizer import write_dashboard  # noqa: F401
