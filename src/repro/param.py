"""ParamSpec: single source of truth for parameter shape/dtype/init/logical axes.

Every model module declares a pytree (nested dict) of ``ParamSpec``.  From that
one declaration we derive:

- abstract params for the AOT dry-run (``jax.ShapeDtypeStruct``, zero allocation)
- real initialization (``init_params``)
- NamedShardings (via ``repro.sharding`` rules)
- LoRA targeting and trainable masks
- checkpoint manifests

This is the JAX analogue of MobileFineTuner's shard "mapping table" (§4.1.1):
the physical location/state of every parameter segment is a pure function of
its logical axes + the active sharding rules.
"""
from __future__ import annotations

import math
import zlib
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_path(tree, is_leaf=None):
    # jax.tree.flatten_with_path only exists on newer jax; fall back to
    # jax.tree_util on the pinned 0.4.x
    fn = getattr(jax.tree, "flatten_with_path", None)
    if fn is None:
        fn = jax.tree_util.tree_flatten_with_path
    return fn(tree, is_leaf=is_leaf)


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    axes: Tuple[Optional[str], ...]   # logical axis name per dim (None = replicated dim)
    init: str = "normal"              # normal | zeros | ones | fanin | embed
    scale: float = 1.0


def spec(shape, axes, init="fanin", dtype=jnp.float32, scale=1.0) -> ParamSpec:
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(tuple(int(s) for s in shape), dtype, tuple(axes), init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], specs):
    return jax.tree.map(fn, specs, is_leaf=is_spec)


def abstract_params(specs, dtype=None):
    """ShapeDtypeStruct pytree — used by jax.eval_shape-free dry-run lowering."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype or s.dtype), specs)


def _init_leaf(key, s: ParamSpec):
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        return (jax.random.normal(key, s.shape) * s.scale).astype(s.dtype)
    if s.init == "embed":
        return (jax.random.normal(key, s.shape) * 0.02 * s.scale).astype(s.dtype)
    if s.init == "fanin":
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        std = s.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, s.shape) * std).astype(s.dtype)
    raise ValueError(f"unknown init {s.init}")


def init_params(rng, specs, dtype=None):
    """Materialize parameters.  Deterministic per-leaf fold of the path hash."""
    leaves, treedef = _flatten_with_path(specs, is_leaf=is_spec)
    out = []
    for path, s in leaves:
        path_str = "/".join(str(p) for p in path)
        # crc32, not hash(): str hash is randomized per process, which would
        # make "same seed" give different params across runs
        key = jax.random.fold_in(rng, zlib.crc32(path_str.encode()) % (2 ** 31))
        x = _init_leaf(key, s)
        if dtype is not None:
            x = x.astype(dtype)
        out.append(x)
    return jax.tree.unflatten(treedef, out)


def logical_axes(specs):
    return tree_map_specs(lambda s: s.axes, specs)


def tree_param_count(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def tree_bytes(tree) -> int:
    return int(sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)))


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def flatten_names(tree, is_leaf=None):
    """[(dotted.name, leaf)] — used for checkpoint manifests and LoRA targeting."""
    leaves, _ = _flatten_with_path(tree, is_leaf=is_leaf)
    out = []
    for path, leaf in leaves:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        out.append((".".join(parts), leaf))
    return out
