"""Learning-rate schedules (linear warmup + cosine/linear/constant decay)."""
from __future__ import annotations

import jax.numpy as jnp


def lr_schedule(step, *, base_lr: float, warmup_steps: int, total_steps: int,
                kind: str = "cosine", min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
    if kind == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((step - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        if kind == "cosine":
            decay = min_ratio + (1 - min_ratio) * 0.5 * (1 +
                                                         jnp.cos(jnp.pi * frac))
        elif kind == "linear":
            decay = 1.0 - (1 - min_ratio) * frac
        else:
            raise ValueError(kind)
    return base_lr * warm * decay
