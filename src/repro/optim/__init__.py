from repro.optim.adamw import (adamw_init, adamw_update,  # noqa: F401
                               clip_by_global_norm, global_norm)
from repro.optim.schedule import lr_schedule  # noqa: F401
