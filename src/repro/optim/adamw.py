"""AdamW from scratch (Abstract-layer optimizer, paper §3.1).

State (m, v) mirrors the parameter tree — and therefore the parameter
*sharding* (ZeRO-1 for free under the FSDP preset).  fp32 moments regardless
of param dtype; optional int8 moment quantization is provided as a
beyond-paper memory lever for the mem-chain benchmark.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def adamw_init(params) -> Dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, global_norm(grads)
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, opt_state, params, *, lr, beta1=0.9, beta2=0.999,
                 eps=1e-8, weight_decay=0.01):
    """Returns (new_params, new_opt_state)."""
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** cf
    bc2 = 1.0 - beta2 ** cf

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = beta1 * m + (1 - beta1) * g
        v = beta2 * v + (1 - beta2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        step = mh / (jnp.sqrt(vh) + eps)
        newp = p.astype(jnp.float32) - lr * (step + weight_decay *
                                             p.astype(jnp.float32))
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    new_p, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        np_, nm, nv = upd(g, m, v, p)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            {"m": jax.tree.unflatten(treedef, new_m),
             "v": jax.tree.unflatten(treedef, new_v),
             "count": count})
