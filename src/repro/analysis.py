"""Analytic roofline model: FLOPs / HBM bytes / collective wire bytes.

WHY ANALYTIC: XLA's HLOCostAnalysis counts every ``while`` body ONCE, so any
program built on ``lax.scan`` (layers, grad-accumulation micro-batches, the
streaming-attention chunk loop) under-reports FLOPs/bytes by the product of
trip counts (verified empirically: an 8-step scan reports exactly 1/8 the
unrolled flops).  The dry-run therefore records BOTH the raw
``compiled.cost_analysis()`` numbers (as a witness) and this analytic model
(as the roofline source).  The model is exact for matmul FLOPs (derived from
the same ParamSpec tree that builds the weights) and a documented
approximation for HBM/wire traffic; every TrainConfig knob the perf loop
tunes (microbatches, remat, preset, dtypes, chunk) enters explicitly.

Conventions:
  - FLOPs are GLOBAL (whole step across all chips).
  - HBM bytes are PER-DEVICE.
  - Collective bytes are PER-DEVICE wire traffic (the roofline divides global
    = per_dev x chips by chips x link_bw, so the chips cancel).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

from repro.config import ModelConfig, ShapeSpec, TrainConfig
from repro.param import is_spec
import jax


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------
def _dtype_bytes(name: str) -> int:
    return {"float32": 4, "bfloat16": 2, "float16": 2,
            "float8_e4m3fn": 1, "int8": 1}[name]


def _mesh_sizes(multi_pod: bool) -> Dict[str, int]:
    return ({"pod": 2, "data": 16, "model": 16} if multi_pod
            else {"pod": 1, "data": 16, "model": 16})


def parallel_sizes(preset: str, multi_pod: bool):
    """(dp_total, tp, fsdp_shards) for a rule preset on the production mesh.

    fsdp_dp uses the model axis as extra data parallelism: weights shard over
    ``data`` only, batch over pod x data x model, no tensor parallelism.
    """
    m = _mesh_sizes(multi_pod)
    if preset == "fsdp_dp":
        return m["pod"] * m["data"] * m["model"], 1, m["data"]
    if preset == "dp":
        return m["pod"] * m["data"], 1, 1
    if preset == "fsdp":
        return m["pod"] * m["data"], 1, m["data"]
    if preset == "tp":
        return m["pod"] * m["data"], m["model"], 1
    # fsdp_tp / fsdp_tp_long
    return m["pod"] * m["data"], m["model"], m["data"]


def ar_per_layer(cfg: ModelConfig) -> float:
    """TP all-reduces of the residual activation per layer: one per parallel
    projection block whose output dim is model-sharded."""
    return {"dense": 2.0, "vlm": 2.0,
            "moe": 1.0,      # attn only; the expert path pays a2a instead
            "ssm": 1.0,      # mamba out-projection
            "hybrid": 3.0,   # attn + mamba (parallel heads) + mlp
            "encdec": 3.0,   # decoder: self + cross + mlp (encoder uses 2)
            }[cfg.family]


def _named_specs(cfg: ModelConfig):
    from repro.models import registry
    from repro.param import flatten_names
    return flatten_names(registry.param_specs(cfg), is_leaf=is_spec)


# ---------------------------------------------------------------------------
# parameter-derived matmul FLOPs per token (forward)
# ---------------------------------------------------------------------------
def matmul_flops_per_token(cfg: ModelConfig) -> Dict[str, float]:
    """2 * prod(weight shape) per token for every >=2-D non-embedding weight.
    Stacked layer dims multiply in naturally.  MoE expert weights scale by
    top_k / n_experts (only active experts touch a token).  Whisper encoder
    weights are tallied separately (different token count)."""
    out = {"dec": 0.0, "enc": 0.0}
    for name, s in _named_specs(cfg):
        if len(s.shape) < 2 or s.init == "embed":
            continue  # biases/norms/tables
        f = 2.0 * float(np.prod(s.shape))
        if "experts" in (s.axes or ()):
            f *= cfg.top_k / max(cfg.n_experts, 1)
        bucket = "enc" if name.startswith("enc_blocks") or "wpe_enc" in name \
            else "dec"
        out[bucket] += f
    if cfg.tie_embeddings:
        out["dec"] += 2.0 * cfg.padded_vocab * cfg.d_model  # tied unembed
    return out


def attention_flops(cfg: ModelConfig, batch: int, sq: int, skv: int,
                    causal: bool = True) -> float:
    """scores + PV: 4 * B * H * sq * skv_eff * head_dim, per layer pattern."""
    if cfg.family == "ssm":
        return 0.0
    from repro.models.transformer import layer_windows
    wins = np.asarray(jax.device_get(layer_windows(cfg)))
    total = 0.0
    for w in wins:
        if causal and sq == skv:
            eff = (skv + 1) / 2 if w == 0 else min(w, (skv + 1) / 2)
        else:
            eff = skv if w == 0 else min(w, skv)
        total += 4.0 * batch * cfg.n_heads * sq * eff * cfg.head_dim
    return total


def ssd_flops(cfg: ModelConfig, batch: int, s: int) -> float:
    if cfg.family not in ("ssm", "hybrid"):
        return 0.0
    from repro.models.mamba2 import d_inner, n_ssm_heads
    nh, hd, ds = n_ssm_heads(cfg), cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    n = -(-s // q)
    per_layer = (
        2.0 * batch * n * q * q * ds        # scores C B^T
        + 1.0 * batch * n * nh * q * q      # decay mask multiply
        + 2.0 * batch * n * nh * q * q * hd  # y_intra = M @ x
        + 2.0 * batch * n * nh * q * hd * ds  # chunk states
        + 2.0 * batch * n * nh * q * hd * ds  # y_inter
    )
    return per_layer * cfg.n_layers


def whisper_tokens(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[float, float]:
    from repro.models.whisper import enc_len
    return (shape.global_batch * shape.seq_len,
            shape.global_batch * enc_len(cfg, shape.seq_len))


def step_flops(cfg: ModelConfig, tcfg: TrainConfig, shape: ShapeSpec
               ) -> Dict[str, float]:
    """Global FLOPs for one step of the cell's kind."""
    per_tok = matmul_flops_per_token(cfg)
    if shape.kind == "decode":
        dec_tokens = shape.global_batch * 1.0
        enc_tokens = 0.0  # encoder precomputed into the cross cache
        skv = shape.seq_len
        attn = attention_flops(cfg, shape.global_batch, 1, skv, causal=True)
        if cfg.family == "encdec":
            from repro.models.whisper import enc_len
            attn += attention_flops(cfg, shape.global_batch, 1,
                                    enc_len(cfg, shape.seq_len), causal=False)
        ssd = ssd_flops(cfg, shape.global_batch, 1) if cfg.family in (
            "ssm", "hybrid") else 0.0
        fwd = per_tok["dec"] * dec_tokens + attn + ssd
        return {"fwd": fwd, "total": fwd, "attn": attn + ssd,
                "matmul": per_tok["dec"] * dec_tokens}

    dec_tokens = shape.global_batch * float(shape.seq_len)
    enc_tokens = 0.0
    s_eff = shape.seq_len + cfg.n_meta_tokens
    attn = attention_flops(cfg, shape.global_batch, s_eff, s_eff, causal=True)
    if cfg.family == "encdec":
        dec_tokens, enc_tokens = whisper_tokens(cfg, shape)
        enc_s = int(enc_tokens // shape.global_batch)
        # encoder self-attn (bidirectional) + decoder cross-attn
        attn = attention_flops(cfg, shape.global_batch, shape.seq_len,
                               shape.seq_len, causal=True)
        attn += 4.0 * shape.global_batch * cfg.n_heads * enc_s * enc_s * \
            cfg.head_dim * cfg.n_enc_layers / max(cfg.n_layers, 1) * \
            max(cfg.n_layers, 1) / max(cfg.n_enc_layers, 1)  # enc self-attn
        attn += 4.0 * shape.global_batch * cfg.n_heads * shape.seq_len * \
            enc_s * cfg.head_dim * cfg.n_layers  # cross
    ssd = ssd_flops(cfg, shape.global_batch, s_eff)
    fwd = per_tok["dec"] * dec_tokens + per_tok["enc"] * enc_tokens + attn + ssd

    if shape.kind == "prefill":
        return {"fwd": fwd, "total": fwd, "attn": attn + ssd,
                "matmul": fwd - attn - ssd}
    # train: fwd + 2x bwd + remat recompute
    remat_extra = {"none": 0.0, "dots": 0.5, "full": 1.0,
                   "offload": 1.0}[tcfg.remat_policy or "none"]
    total = fwd * (3.0 + remat_extra)
    return {"fwd": fwd, "total": total, "attn": attn + ssd,
            "matmul": fwd - attn - ssd,
            "remat_factor": 3.0 + remat_extra}


# ---------------------------------------------------------------------------
# per-device HBM bytes (approximate, documented terms)
# ---------------------------------------------------------------------------
def param_bytes_total(cfg: ModelConfig, dtype_bytes: int) -> float:
    return sum(float(np.prod(s.shape)) * dtype_bytes
               for _, s in _named_specs(cfg))


def step_hbm_bytes(cfg: ModelConfig, tcfg: TrainConfig, shape: ShapeSpec,
                   multi_pod: bool) -> Dict[str, float]:
    m = _mesh_sizes(multi_pod)
    dp, tp, _ = parallel_sizes(tcfg.shard_preset, multi_pod)
    n_dev = m["pod"] * m["data"] * m["model"]
    cd = _dtype_bytes(tcfg.compute_dtype)
    pd = _dtype_bytes(tcfg.param_dtype)

    w_total = param_bytes_total(cfg, 1.0)          # element count
    w_tp = w_total / tp                             # per-device after FSDP gather
    b_local = max(shape.global_batch // dp, 1)
    s = shape.seq_len
    d = cfg.d_model

    if shape.kind == "decode":
        # weights read once (bf16), cache read+write once
        cache_elems = _cache_elems(cfg, shape)
        cache_dev = cache_elems / n_dev * 2          # bf16
        weights = w_tp * pd / (1 if dp == 1 else 1)  # gathered tile read
        hbm = weights + 2.0 * cache_dev
        return {"weights": weights, "cache": 2.0 * cache_dev, "acts": 0.0,
                "opt": 0.0, "total": hbm}

    micro = max(tcfg.microbatches, 1) if shape.kind == "train" else 1
    b_micro = max(b_local // micro, 1)
    # weights: read per microbatch, fwd + bwd (re-gathered under remat)
    passes = 2.0 if shape.kind == "train" else 1.0
    weights = micro * passes * w_tp * cd
    # activations: layer checkpoints written+read (remat full saves carry only)
    n_l = cfg.n_layers + cfg.n_enc_layers
    act_elem = b_micro * s * d * n_l
    save_factor = {"none": 6.0, "dots": 3.0, "full": 2.0, "offload": 2.0}[
        tcfg.remat_policy or "none"]
    acts = micro * act_elem * cd * save_factor
    opt = 0.0
    if shape.kind == "train":
        w_state_dev = w_total / n_dev
        # read m, v, master, grads; write m, v, master  (fp32)
        opt = 7.0 * w_state_dev * 4
    total = weights + acts + opt
    return {"weights": weights, "acts": acts, "opt": opt, "cache": 0.0,
            "total": total}


def _cache_elems(cfg: ModelConfig, shape: ShapeSpec) -> float:
    # cache_len policy mirrors repro.launch.dryrun: seq + 512 decode pad
    max_len = shape.seq_len + 512
    elems = 0.0
    if cfg.family != "ssm":
        elems += 2.0 * cfg.n_layers * shape.global_batch * max_len * \
            cfg.n_kv_heads * cfg.head_dim
    if cfg.family in ("ssm", "hybrid"):
        from repro.models.mamba2 import d_inner, n_ssm_heads
        elems += cfg.n_layers * shape.global_batch * (
            n_ssm_heads(cfg) * cfg.ssm_head_dim * cfg.ssm_state * 2  # fp32
            + (cfg.ssm_conv_width - 1) * (d_inner(cfg) + 2 * cfg.ssm_state))
    if cfg.family == "encdec":
        from repro.models.whisper import enc_len
        elems += 2.0 * cfg.n_layers * shape.global_batch * \
            enc_len(cfg, shape.seq_len) * cfg.n_kv_heads * cfg.head_dim
    return elems


# ---------------------------------------------------------------------------
# per-device collective wire bytes
# ---------------------------------------------------------------------------
def step_wire_bytes(cfg: ModelConfig, tcfg: TrainConfig, shape: ShapeSpec,
                    multi_pod: bool) -> Dict[str, float]:
    """Ring-collective wire model, per device:
       all-gather of full tensor T over g:   receives T (g-1)/g  ~ T
       reduce-scatter of T over g:           sends    T (g-1)/g  ~ T
       all-reduce of T over g:               2 T (g-1)/g         ~ 2 T
    FSDP(+TP) training traffic:
       fwd+bwd weight all-gathers over the fsdp axis: micro * 2 * (W/tp)
       grad reduce-scatter over fsdp:        (W/tp)
       TP activation all-reduces:            ar_per_layer * tokens_loc * d * 2
       DP grad all-reduce over axes where weights replicate (pod; model
       under fsdp_dp):                       2 * W_local
       MoE all-to-all: dispatch (moe_dispatch_dtype) + combine (compute) of
       token activations x top_k over the expert (model) axis.
    """
    m = _mesh_sizes(multi_pod)
    data, pod, model = m["data"], m["pod"], m["model"]
    dp, tp, fsdp_shards = parallel_sizes(tcfg.shard_preset, multi_pod)
    cd = _dtype_bytes(tcfg.compute_dtype)
    gd = _dtype_bytes(tcfg.grad_reduce_dtype or tcfg.compute_dtype)
    dd = _dtype_bytes(tcfg.moe_dispatch_dtype or tcfg.compute_dtype)
    w_elems = param_bytes_total(cfg, 1.0)
    w_tp = w_elems / tp
    s = shape.seq_len + cfg.n_meta_tokens
    d = cfg.d_model
    b_local = max(shape.global_batch // dp, 1)

    fsdp_on = fsdp_shards > 1
    tp_on = tp > 1
    apl = ar_per_layer(cfg)
    n_layers_eff = cfg.n_layers + cfg.n_enc_layers * (2.0 / 3.0 if
                                                      cfg.family == "encdec"
                                                      else 1.0)

    if shape.kind == "decode":
        ag = w_tp * _dtype_bytes(tcfg.param_dtype) * (fsdp_shards - 1) / \
            fsdp_shards if fsdp_on else 0.0
        ar = 2.0 * apl * n_layers_eff * b_local * 1 * d * cd * (tp - 1) / tp \
            if tp_on else 0.0
        total = ag + ar
        return {"ag_weights": ag, "ar_tp": ar, "rs_grads": 0.0,
                "ar_pod": 0.0, "a2a_moe": 0.0, "total": total}

    micro = max(tcfg.microbatches, 1) if shape.kind == "train" else 1
    b_micro = max(b_local // micro, 1)
    gathers_per_step = (2.0 if shape.kind == "train" else 1.0) * micro
    ag = gathers_per_step * w_tp * cd * (fsdp_shards - 1) / fsdp_shards \
        if fsdp_on else 0.0
    ar = 2.0 * apl * n_layers_eff * micro * b_micro * s * d * cd * \
        (tp - 1) / tp if tp_on else 0.0
    rs = w_tp * gd * (fsdp_shards - 1) / fsdp_shards \
        if (shape.kind == "train" and fsdp_on) else 0.0
    if shape.kind == "train" and not fsdp_on and dp > 1:
        rs = 2.0 * w_tp * gd  # pure DP: grad all-reduce instead
    # grad all-reduce over replicated-weight axes: pod always; model if the
    # preset turned the model axis into data parallelism
    repl_ways = pod * (model if tcfg.shard_preset == "fsdp_dp" else 1)
    ar_pod = 2.0 * (w_elems / (tp * fsdp_shards)) * gd * \
        (repl_ways - 1) / repl_ways if (shape.kind == "train" and
                                        repl_ways > 1) else 0.0
    a2a = 0.0
    if cfg.n_experts > 0 and tp_on:
        tokens_local = b_micro * s
        a2a = micro * cfg.n_layers * tokens_local * d * (dd + cd) * \
            cfg.top_k * (tp - 1) / tp
        if shape.kind == "train":
            a2a *= 2.0  # backward mirrors dispatch/combine
    total = ag + ar + rs + ar_pod + a2a
    return {"ag_weights": ag, "ar_tp": ar, "rs_grads": rs, "ar_pod": ar_pod,
            "a2a_moe": a2a, "total": total}


# ---------------------------------------------------------------------------
# assembled roofline
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def analytic_roofline(cfg: ModelConfig, tcfg: TrainConfig, shape: ShapeSpec,
                      multi_pod: bool) -> Dict[str, Any]:
    m = _mesh_sizes(multi_pod)
    n_dev = m["pod"] * m["data"] * m["model"]
    fl = step_flops(cfg, tcfg, shape)
    hbm = step_hbm_bytes(cfg, tcfg, shape, multi_pod)
    wire = step_wire_bytes(cfg, tcfg, shape, multi_pod)

    t_compute = fl["total"] / n_dev / PEAK_FLOPS
    t_memory = hbm["total"] / HBM_BW
    t_coll = wire["total"] / LINK_BW
    bound = max(t_compute, t_memory, t_coll)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        mf = 6.0 * n_active * shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        mf = 2.0 * n_active * shape.global_batch * shape.seq_len
    else:
        mf = 2.0 * n_active * shape.global_batch
    return {
        "flops": fl, "hbm_bytes_dev": hbm, "wire_bytes_dev": wire,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": ("compute" if bound == t_compute else
                     "memory" if bound == t_memory else "collective"),
        "model_flops": mf,
        "useful_flops_ratio": mf / fl["total"] if fl["total"] else 0.0,
        "roofline_fraction": (mf / n_dev / PEAK_FLOPS) / bound if bound else 0.0,
        "step_time_bound_s": bound,
    }
