"""Per-step spill store for layer-boundary activations (long-seq streaming).

Layer streaming made resident *params* depth-independent, but the streamed
two-sweep driver (``repro/core/stream.py``) still pinned every boundary
activation ``acts[0..L]`` on device, so memory scaled with ``seq_len x
depth``.  This module closes that wall with the same machinery the param
path already trusts:

- the forward sweep ``sink``s boundary ``i`` into a layer-aligned scratch
  ``SegmentStore`` (one single-leaf segment per boundary, sparse files —
  rewritten every step, never read before written), the bytes riding the
  bounded background :class:`AsyncWriter` behind the next block's compute;
- the backward sweep pulls boundaries back in **reverse** order through the
  slot-bounded :class:`Prefetcher` (boundary ``i-1`` pages in while block
  ``i``'s VJP runs), with the prefetcher's pooled ``out=`` buffers keeping
  the steady-state loop allocation-free (identity/bf16 codecs);
- a boundary still sitting in the write queue is ``steal``-ed straight
  back (a *write hit*): with a 2-deep queue the two most recently sunk
  activations — exactly the first two the reverse walk wants — never touch
  flash at all.

Activation codecs (``repro/offload/codecs.py``): ``identity`` (fp32,
bit-exact spill), ``bf16`` (the window stays bf16 — half the buffer
bytes), ``act_int8`` (per-*token* absmax — activations carry outliers
along the channel axis, so scales go per position, the transpose of the
weight codec).  ``sink`` applies ``storage_roundtrip`` up front so a
stolen (never-written) boundary is numerically identical to one that
round-tripped through flash — the loss trajectory cannot depend on writer
timing.

Threading: the store itself is **single-owner** — ``sink``/``prefetch``/
``take``/``barrier``/``close`` are issued by the step thread only (the
same discipline as the ``OffloadEngine`` window).  All cross-thread state
lives inside the internally-locked ``Prefetcher``/``AsyncWriter``; errors
from either background thread surface on the next ``sink``/``take``/
``barrier`` by their own contracts.
"""
from __future__ import annotations

import os
import time
from typing import Dict, Optional, Tuple

import numpy as np

from repro.offload.codecs import get_codec
from repro.offload.engine import AsyncWriter, Prefetcher
from repro.offload.segments import SegmentStore



class ActivationStore:
    """Scratch store spilling ``n_acts`` boundary activations of one shape.

    ``shape``/``dtype`` are the *logical* (fp32 host) activation geometry;
    every segment shares one signature so the prefetcher's buffer pool
    recycles across boundaries.  ``depth`` bounds completed prefetch
    buffers (reverse-walk lookahead); ``max_pending`` bounds the write
    queue — both count toward :meth:`peak_inflight_bytes`.
    """

    def __init__(self, directory: str, n_acts: int, shape: Tuple[int, ...],
                 codec: str = "identity", depth: int = 2,
                 max_pending: int = 2, io_backend: str = ""):
        if n_acts < 1:
            raise ValueError(f"n_acts must be >= 1, got {n_acts}")
        self.n_acts = int(n_acts)
        self.shape = tuple(int(d) for d in shape)
        self.codec_name = codec
        self._codec = get_codec(codec)
        os.makedirs(directory, exist_ok=True)
        groups = [[(f"act.{i}", np.zeros(self.shape, np.float32), codec)]
                  for i in range(self.n_acts)]
        # sparse layout: every boundary is re-sunk before it is re-read,
        # so there is no reason to burst n_acts * act_bytes of zeros onto
        # flash-wear-sensitive storage at construction
        self.store = SegmentStore.create(
            directory, groups, self.n_acts,
            meta={"kind": "act_scratch_v1", "codec": codec},
            group_labels=[f"act:{i}" for i in range(self.n_acts)],
            write=False, io_backend=io_backend)
        self._pf = Prefetcher(self.store, depth=max(1, depth))
        # identity spills recycle the written-out fp32 buffer back into the
        # prefetcher pool (same signature as the read path's window form);
        # converting codecs submit fp32 but read back the window dtype, so
        # their writer buffers would only pollute the bounded pool
        recycle = self._recycle_writable if codec == "identity" else None
        self._writer = AsyncWriter(self.store, max_pending=max(1, max_pending),
                                   recycle=recycle)
        self._sunk = [False] * self.n_acts
        self.write_hits = 0
        self.takes = 0
        self.bytes_sunk = 0
        self.bytes_taken = 0
        self.t_read_block_s = 0.0
        self.t_write_block_s = 0.0
        self.peak_inflight_bytes = 0
        self._closed = False

    # ------------------------------------------------------------------
    def _note_inflight(self):
        self.peak_inflight_bytes = max(
            self.peak_inflight_bytes,
            self._writer.pending_bytes() + self._pf.buffer_bytes())

    def sink(self, i: int, x: np.ndarray) -> None:  # hot-path
        """Queue boundary ``i``'s host array for background write-back.
        Blocks only while the bounded write queue is full (billed to
        ``t_write_block_s``).  The caller must hand over ownership of
        ``x`` — the writer thread reads it until the write lands."""
        if x.shape != self.shape:
            raise ValueError(
                f"activation {i} has shape {x.shape}, store laid out for "
                f"{self.shape} — recreate the store when the batch geometry "
                "changes")
        # round-trip through storage precision *now*: a stolen boundary
        # must be bit-equal to one re-read from flash, or the loss would
        # depend on writer timing (identity: a no-op returning x itself)
        x = self._codec.storage_roundtrip(
            np.asarray(x, np.float32))  # sync-point: the spill is host-side
        #                                 by design; the caller already
        #                                 pulled the boundary off device
        # a buffered/in-flight read of this boundary (prior micro-batch's
        # unconsumed lookahead) holds stale bytes now
        self._pf.invalidate(i)
        t0 = time.perf_counter()
        self._writer.submit(i, {f"act.{i}": x})
        self.t_write_block_s += time.perf_counter() - t0
        self.bytes_sunk += x.nbytes
        self._sunk[i] = True
        self._note_inflight()

    def prefetch(self, i: int) -> None:
        """Schedule a background read of boundary ``i`` (reverse-walk
        lookahead).  Skipped while the writer still holds the boundary —
        reading the file would race the write and land stale bytes; the
        later ``take`` steals it from the queue instead."""
        if not (0 <= i < self.n_acts) or not self._sunk[i]:
            return
        if self._writer.holds(i):
            return
        self._pf.schedule(i)

    def take(self, i: int) -> np.ndarray:  # hot-path
        """Boundary ``i`` back in window form (fp32 for identity/act_int8,
        bf16 for the bf16 codec).  Steals from the write queue when the
        bytes never landed; otherwise a prefetch hit or (counted) sync
        read.  The caller owns the returned buffer — hand it back via
        :meth:`recycle` once consumed.

        **Consume-once**: a dirty steal hands over bytes that never
        landed on flash, so a second ``take`` of the same boundary would
        read whatever older spill the file still holds.  Taking marks the
        boundary un-sunk; the driver re-sinks every boundary each
        forward sweep, so the contract costs nothing there."""
        if not self._sunk[i]:
            raise KeyError(
                f"activation boundary {i} was never sunk (or was already "
                "consumed — takes are consume-once)")
        self._sunk[i] = False
        self.takes += 1
        t0 = time.perf_counter()
        stolen = self._writer.steal(i)
        if stolen is not None:
            data, _dirty = stolen
            # a racing prefetch issued before the writer picked i up would
            # read pre-steal file bytes — poison it
            self._pf.invalidate(i)
            self.write_hits += 1
            arr = data[f"act.{i}"]
            # the stolen array is the fp32 submit copy; converting codecs
            # hand back the window form so the consumer sees one dtype
            if self.codec_name == "bf16":
                arr = arr.astype(self._codec.window_np_dtype("float32"))
            self.t_read_block_s += time.perf_counter() - t0
            self.bytes_taken += arr.nbytes
            return arr
        data = self._pf.take(i)
        self.t_read_block_s += time.perf_counter() - t0
        arr = data[f"act.{i}"]
        self.bytes_taken += arr.nbytes
        self._note_inflight()
        return arr

    def _recycle_writable(self, seg: int, data: Dict[str, np.ndarray]):
        """Writer recycle hook: spilled boundaries are often *read-only*
        zero-copy views of device buffers — those must never enter the
        reusable-destination pool (``read_segment(out=)`` writes into it)."""
        if all(isinstance(a, np.ndarray) and a.flags.writeable
               for a in data.values()):
            self._pf.recycle(seg, data)

    def recycle(self, i: int, arr: np.ndarray) -> None:
        """Return a consumed ``take`` buffer to the prefetcher pool (no-op
        when pooling is off — i.e. when the jit boundary zero-copies host
        arrays and reuse would corrupt live device buffers — and for
        read-only stolen views)."""
        self._recycle_writable(i, {f"act.{i}": arr})

    # ------------------------------------------------------------------
    def barrier(self) -> None:
        """Drain the write queue (durability fence — tests and snapshots)."""
        self._writer.barrier()

    def inflight_bytes(self) -> int:
        """Current bounded host-buffer footprint: queued/mid-flight writes
        plus the prefetcher's completed buffers and recycle pool."""
        return self._writer.pending_bytes() + self._pf.buffer_bytes()

    def hit_rate(self) -> float:
        """Fraction of takes served without a synchronous flash read
        (write-queue steals + prefetch hits)."""
        if not self.takes:
            return 1.0
        return (self.write_hits + self._pf.prefetch_hits) / self.takes

    def stats(self) -> Dict[str, float]:
        return {
            "write_hits": self.write_hits,
            "prefetch_hits": self._pf.prefetch_hits,
            "sync_loads": self._pf.sync_loads,
            "forced_drops": self._pf.forced_drops,
            "buffer_reuses": self._pf.buffer_reuses,
            "takes": self.takes,
            "bytes_sunk": self.bytes_sunk,
            "bytes_taken": self.bytes_taken,
            "t_read_block_s": self.t_read_block_s,
            "t_write_block_s": self.t_write_block_s,
            "writeback_busy_s": self._writer.busy_s,
            "peak_inflight_bytes": self.peak_inflight_bytes,
            "store_bytes": self.store.total_bytes,
            **self.store.io_stats(),
        }

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._writer.close()
        finally:
            try:
                self._pf.close()
            finally:
                self.store.close_io()


def act_store_for(directory: str, n_acts: int, shape, codec: str,
                  existing: Optional[ActivationStore] = None,
                  io_backend: str = "") -> ActivationStore:
    """Reuse ``existing`` when its geometry still matches, else (re)build —
    the streamed step creates the store lazily at the first forward sweep
    (the batch shape is not known at construction time)."""
    shape = tuple(int(d) for d in shape)
    if existing is not None:
        if existing.shape == shape and existing.n_acts == n_acts:
            return existing
        existing.close()
    return ActivationStore(directory, n_acts, shape, codec=codec,
                           io_backend=io_backend)
