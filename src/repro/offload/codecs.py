"""Pluggable segment codecs: how a leaf's logical array maps to stored bytes.

Every leaf in a ``SegmentStore`` mapping table carries a codec name; the
codec owns the storage layout of that leaf inside its segment file.  The
engine decodes on pull and encodes on dirty write-back, so all dtype
conversion lives here instead of being smeared across the offload stack
(the old ``_cast_moment`` / fp32 round-trip special cases).

  identity   stored bytes == the logical array's bytes (no conversion)
  bf16       stored as bfloat16; ``decode`` returns the logical (fp32)
             dtype, but the *window* representation stays bfloat16 — the
             half-sized AdamW moment segments keep their resident-memory
             win, and the update's fp32 math happens at the consumption
             point (cast on use, ``storage_roundtrip`` on store), exactly
             the pre-codec numerics
  int8       per-channel absmax symmetric quantization (QLoRA-style frozen
             base): int8 codes over the last axis' channels plus one fp32
             scale per channel, packed [codes | scales] inside the segment.
             ~4x smaller than fp32 both on flash and in the resident window.

A codec therefore distinguishes three representations of one leaf: the
stored bytes, the *window* form the engine keeps resident (``window`` —
compact: bf16 stays bf16, int8 stays encoded), and the fully decoded
logical array (``decode`` — what ``read_segment`` hands to generic
consumers).  For the quantized frozen base the window must stay int8 —
decoding happens *inside* the jitted per-block apply/VJP
(``repro.models.lm``), so fp32 weights exist one block at a time.
``read_segment(..., encoded=True)`` returns ``QuantLeaf(codes, scales)``
views instead of decoded arrays; ``dequant_leaf``/``dequant_tree`` are the
jnp-side decoders.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import numpy as np


def np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


class QuantLeaf(NamedTuple):
    """Encoded leaf handed to the jit boundary: int8 codes in the logical
    shape + per-channel fp32 scales.  ``scales.size == 0`` marks a leaf the
    codec passes through undecoded (identity)."""
    codes: np.ndarray
    scales: np.ndarray

    @property
    def nbytes(self) -> int:
        return self.codes.nbytes + self.scales.nbytes


def _n_scales(shape: Tuple[int, ...]) -> int:
    """int8 channel count: one scale per last-axis channel for matrices,
    one per-tensor scale for vectors (0-d leaves are not quantizable)."""
    return int(shape[-1]) if len(shape) >= 2 else 1


class SegmentCodec:
    """Base codec: identity (stored bytes are the logical array's bytes)."""

    name = "identity"

    def encoded_nbytes(self, shape: Tuple[int, ...], dtype: str) -> int:
        return int(np.prod(shape, dtype=np.int64)) * np_dtype(dtype).itemsize

    def encode(self, arr: np.ndarray, dtype: str) -> np.ndarray:
        """Logical array -> flat uint8 storage bytes."""
        a = np.ascontiguousarray(np.asarray(arr), np_dtype(dtype))
        return a.reshape(-1).view(np.uint8) if a.ndim else a.view(np.uint8)

    def decode(self, buf: np.ndarray, shape: Tuple[int, ...], dtype: str,
               copy: bool = True) -> np.ndarray:
        """Flat uint8 storage bytes -> logical array.  ``copy=False`` may
        return a view into ``buf`` (identity only)."""
        arr = buf.view(np_dtype(dtype)).reshape(shape)
        return np.array(arr) if copy else arr

    def decode_encoded(self, buf: np.ndarray, shape: Tuple[int, ...],
                       dtype: str) -> QuantLeaf:
        """Storage bytes -> the still-encoded representation for the jit
        boundary.  Non-quantizing codecs decode fully (empty scales)."""
        return QuantLeaf(self.decode(buf, shape, dtype),
                         np.empty((0,), np.float32))

    def window(self, buf: np.ndarray, shape: Tuple[int, ...],
               dtype: str) -> np.ndarray:
        """Storage bytes -> the representation the engine keeps resident.
        Defaults to the decoded logical array; compact codecs override so
        the window never inflates (bf16 moments stay bf16-resident — the
        consumer casts to fp32 at use and re-rounds on store)."""
        return self.decode(buf, shape, dtype)

    def storage_view(self, buf: np.ndarray, shape: Tuple[int, ...],
                     dtype: str):
        """Zero-copy storage-typed view of one leaf's bytes, or None when
        the codec has no flat array storage form (int8's packed
        codes+scales).  The allocation-free read path copies this view into
        a reusable destination buffer instead of allocating."""
        return buf.view(np_dtype(dtype)).reshape(shape)

    def window_np_dtype(self, dtype: str) -> np.dtype:
        """Numpy dtype of the *window* representation (what ``window``
        returns) — the dtype a reusable window buffer must carry."""
        return np_dtype(dtype)

    def storage_np_dtype(self, dtype: str):
        """Numpy dtype the on-flash bytes carry when storage is a flat
        array of that dtype, else None (int8's packed codes+scales).  The
        raw read backends use this to decide — without allocating — when
        a leaf can be read *straight into* its destination window buffer
        versus staged through a scratch chunk and decoded."""
        return np_dtype(dtype)

    def storage_roundtrip(self, arr: np.ndarray) -> np.ndarray:
        """decode(encode(arr)) without touching bytes: what a value becomes
        after one trip through storage.  The state layer applies this when
        storing updated values into a decoded window copy, so in-window
        precision always equals on-flash precision."""
        return arr


class Bf16Codec(SegmentCodec):
    name = "bf16"

    def encoded_nbytes(self, shape, dtype):
        return int(np.prod(shape, dtype=np.int64)) * 2

    def encode(self, arr, dtype):
        a = np.ascontiguousarray(
            np.asarray(arr, np.float32).astype(np_dtype("bfloat16")))
        return a.reshape(-1).view(np.uint8) if a.ndim else a.view(np.uint8)

    def decode(self, buf, shape, dtype, copy=True):
        arr = buf.view(np_dtype("bfloat16")).reshape(shape)
        return np.asarray(arr, np_dtype(dtype))

    def window(self, buf, shape, dtype):
        # resident form stays bfloat16: decoding moments to fp32 here would
        # silently hand back the halved window bytes this codec exists for
        return np.array(buf.view(np_dtype("bfloat16")).reshape(shape))

    def storage_view(self, buf, shape, dtype):
        return buf.view(np_dtype("bfloat16")).reshape(shape)

    def window_np_dtype(self, dtype):
        return np_dtype("bfloat16")

    def storage_np_dtype(self, dtype):
        return np_dtype("bfloat16")

    def storage_roundtrip(self, arr):
        a = np.asarray(arr)
        return a.astype(np_dtype("bfloat16")).astype(a.dtype)


class Int8Codec(SegmentCodec):
    """Per-channel absmax symmetric int8: codes = round(x / scale) in
    [-127, 127] with scale = absmax / 127 over each last-axis channel
    (per-tensor for 1-D leaves).  Storage layout: [codes | fp32 scales]."""

    name = "int8"

    def encoded_nbytes(self, shape, dtype):
        return int(np.prod(shape, dtype=np.int64)) + _n_scales(shape) * 4

    def _quantize(self, arr) -> QuantLeaf:
        a = np.asarray(arr, np.float32)
        if a.ndim == 0:
            raise ValueError("int8 codec cannot quantize 0-d leaves")
        red = tuple(range(a.ndim - 1)) if a.ndim >= 2 else None
        absmax = np.max(np.abs(a), axis=red) if a.ndim >= 2 else \
            np.max(np.abs(a), keepdims=True)
        absmax = np.asarray(absmax, np.float32).reshape(_n_scales(a.shape))
        scales = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
        codes = np.clip(np.rint(a / scales), -127, 127).astype(np.int8)
        return QuantLeaf(codes, scales)

    def encode(self, arr, dtype):
        q = self._quantize(arr)
        return np.concatenate([q.codes.reshape(-1).view(np.uint8),
                               q.scales.view(np.uint8)])

    def decode(self, buf, shape, dtype, copy=True):
        q = self.decode_encoded(buf, shape, dtype)
        return dequant_np(q).astype(np_dtype(dtype), copy=False)

    def decode_encoded(self, buf, shape, dtype):
        n = int(np.prod(shape, dtype=np.int64))
        codes = np.array(buf[:n].view(np.int8)).reshape(shape)
        scales = np.array(buf[n:].view(np.float32))
        return QuantLeaf(codes, scales)

    def storage_view(self, buf, shape, dtype):
        return None     # packed [codes | scales]: no flat array view

    def storage_np_dtype(self, dtype):
        return None     # packed: never readable straight into a window

    def storage_roundtrip(self, arr):
        a = np.asarray(arr)
        return dequant_np(self._quantize(a)).astype(a.dtype, copy=False)


class ActInt8Codec(Int8Codec):
    """Per-token absmax symmetric int8 for *activations*: the transpose of
    ``Int8Codec``'s weight layout.  Boundary activations are (B, S, D) with
    outlier structure along the channel axis, so each token position gets
    its own scale — absmax reduces over the **last** (channel) axis and the
    scales are shaped to the leading B*S positions.  Storage layout stays
    [codes | fp32 scales]."""

    name = "act_int8"

    def encoded_nbytes(self, shape, dtype):
        return (int(np.prod(shape, dtype=np.int64))
                + _n_act_scales(shape) * 4)

    def _quantize(self, arr) -> QuantLeaf:
        a = np.asarray(arr, np.float32)
        if a.ndim == 0:
            raise ValueError("act_int8 codec cannot quantize 0-d leaves")
        absmax = np.max(np.abs(a), axis=-1, keepdims=True) if a.ndim >= 2 \
            else np.max(np.abs(a), keepdims=True)
        scales = np.where(absmax > 0, absmax / 127.0, 1.0).astype(np.float32)
        codes = np.clip(np.rint(a / scales), -127, 127).astype(np.int8)
        return QuantLeaf(codes, scales.reshape(_n_act_scales(a.shape)))

    def decode(self, buf, shape, dtype, copy=True):
        q = self.decode_encoded(buf, shape, dtype)
        scales = q.scales.reshape(shape[:-1] + (1,)) if len(shape) >= 2 \
            else q.scales
        out = np.asarray(q.codes, np.float32) * scales
        return out.astype(np_dtype(dtype), copy=False)

    def storage_roundtrip(self, arr):
        a = np.asarray(arr)
        q = self._quantize(a)
        scales = q.scales.reshape(a.shape[:-1] + (1,)) if a.ndim >= 2 \
            else q.scales
        return (np.asarray(q.codes, np.float32) * scales).astype(
            a.dtype, copy=False)


def _n_act_scales(shape: Tuple[int, ...]) -> int:
    """act_int8 scale count: one per leading (token) position."""
    return int(np.prod(shape[:-1], dtype=np.int64)) if len(shape) >= 2 else 1


CODECS: Dict[str, SegmentCodec] = {c.name: c for c in
                                   (SegmentCodec(), Bf16Codec(), Int8Codec(),
                                    ActInt8Codec())}


def get_codec(name: str) -> SegmentCodec:
    try:
        return CODECS[name]
    except KeyError:
        raise ValueError(
            f"unknown segment codec {name!r}; this build provides "
            f"{sorted(CODECS)} — the segment layout was written by a newer "
            "build (upgrade) or the mapping table is corrupt (re-create the "
            "layout)") from None


def moment_codec(moment_dtype: str) -> str:
    """Map the user-facing --offload-moment-dtype knob to a codec name."""
    if moment_dtype in ("", "float32"):
        return "identity"
    if moment_dtype == "bfloat16":
        return "bf16"
    raise ValueError(f"unsupported moment dtype {moment_dtype!r} "
                     "(float32 or bfloat16)")


def activation_codec(name: str) -> str:
    """Map the user-facing --activation-codec knob to a codec name.  fp32 is
    the identity codec (bit-exact spill); int8 maps to the *activation*
    variant (per-token scales), not the weight codec."""
    if name in ("", "fp32", "float32"):
        return "identity"
    if name in ("bf16", "bfloat16"):
        return "bf16"
    if name == "int8":
        return "act_int8"
    raise ValueError(f"unsupported activation codec {name!r} "
                     "(fp32, bf16 or int8)")


# ----------------------------------------------------------------------------
# decode helpers for QuantLeaf trees (numpy side + jit side)
# ----------------------------------------------------------------------------
def dequant_np(leaf: QuantLeaf) -> np.ndarray:
    """Numpy dequantization (materialize / export path)."""
    if leaf.scales.size == 0:
        return leaf.codes
    return (np.asarray(leaf.codes, np.float32)
            * leaf.scales.astype(np.float32))


def dequant_leaf(codes, scales):
    """jnp dequantization of one leaf — runs inside the jitted per-block
    apply/VJP, so the fp32 copy of a quantized weight exists only as a
    transient inside XLA.  Empty scales mark identity passthrough."""
    if scales.shape == (0,):
        return codes
    import jax.numpy as jnp
    return codes.astype(jnp.float32) * scales


def dequant_tree(pair):
    """(codes_tree, scales_tree) -> decoded param tree, leaf-wise.  The pair
    is what ``LayerStreamedState.layer_params``/``head_params`` return for a
    quantized frozen base; plain (unpaired) trees pass through untouched."""
    if not (isinstance(pair, tuple) and len(pair) == 2):
        return pair
    import jax
    codes, scales = pair
    return jax.tree.map(dequant_leaf, codes, scales)
