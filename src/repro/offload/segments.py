"""Segment files + mapping table (paper §4.1.1).

The paper partitions the parameter space into contiguous segments, keeps the
active segment in RAM and pages the rest to flash, tracked by a mapping
table.  Here a ``SegmentStore`` owns a directory of raw segment files
(``seg_00000.bin`` ...) plus ``table.json`` — the mapping table recording,
for every pytree leaf, which segment holds it and at which byte offset.

Leaves are grouped (a group is never split across segments — e.g. the
(param, m, v) triple of one tensor) and groups are packed contiguously into
``num_segments`` byte-balanced segments.

I/O is memory-mapped: reads slice an ``np.memmap`` (page-cache backed, no
user-space staging), writes go through an ``r+`` map and are flushed before
the map is dropped.  ``snapshot``/``link_clone`` hardlink the segment files
(zero-copy checkpointing) and flip the store into copy-on-write mode so the
snapshot inode is never mutated: the first later write to a segment rewrites
it under a fresh inode via copy + atomic replace.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np


class LeafRecord(NamedTuple):
    name: str
    segment: int
    offset: int      # byte offset inside the segment file
    nbytes: int
    shape: Tuple[int, ...]
    dtype: str       # numpy dtype name ("float32", "bfloat16", ...)


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import ml_dtypes
        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


def _as_bytes(arr: np.ndarray) -> np.ndarray:
    """Contiguous uint8 view of an array's buffer."""
    arr = np.ascontiguousarray(arr)
    return arr.reshape(-1).view(np.uint8) if arr.ndim else arr.view(np.uint8)


def plan_segments(group_nbytes: Sequence[int], num_segments: int
                  ) -> List[Tuple[int, int]]:
    """Partition groups (in order) into ``num_segments`` contiguous,
    byte-balanced spans.  Returns [start, end) group-index bounds; never
    splits a group; never emits an empty segment (fewer segments than
    requested when there are fewer groups)."""
    n_groups = len(group_nbytes)
    if n_groups == 0:
        return []
    n = max(1, min(int(num_segments), n_groups))
    bounds: List[Tuple[int, int]] = []
    start = 0
    bytes_left = float(sum(group_nbytes))
    for seg in range(n):
        segs_left = n - seg
        take_max = (n_groups - start) - (segs_left - 1)
        target = bytes_left / segs_left
        acc = 0.0
        end = start
        while end < start + take_max:
            acc += group_nbytes[end]
            end += 1
            if segs_left > 1 and acc >= target:
                break
        bounds.append((start, end))
        bytes_left -= acc
        start = end
    return bounds


class SegmentStore:
    """Mapping table + mmap-backed segment files for a flat named leaf set."""

    TABLE = "table.json"

    def __init__(self, directory: str, records: List[LeafRecord],
                 seg_nbytes: List[int], meta: Optional[Dict] = None):
        self.directory = directory
        self.records = records
        self.seg_nbytes = seg_nbytes
        self.meta = dict(meta or {})
        self._by_name = {r.name: r for r in records}
        self._seg_leaves: List[List[LeafRecord]] = [
            [] for _ in range(len(seg_nbytes))]
        for r in records:
            self._seg_leaves[r.segment].append(r)
        self._cow = [False] * len(seg_nbytes)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, directory: str,
               groups: Sequence[Sequence[Tuple[str, np.ndarray]]],
               num_segments: int, meta: Optional[Dict] = None,
               group_labels: Optional[Sequence[str]] = None,
               write: bool = True) -> "SegmentStore":
        """Write ``groups`` (ordered lists of (name, array); a group is kept
        within one segment) into ``num_segments`` segment files.

        ``group_labels`` (one per *group*) turns on aligned mode: each group
        gets its own segment (``num_segments`` must equal the group count) and
        ``meta["labels"]`` records the label of every segment — the
        layer-streamed path uses this to map block index -> segment without
        consulting leaf names.

        ``write=False`` lays out the geometry only: segment files are
        truncated to size (sparse, read back as zeros) and the array
        *contents* are never written — for scratch stores whose first use
        overwrites everything (e.g. the gradient sink).
        """
        os.makedirs(directory, exist_ok=True)
        # drop any previous mapping table first: an interrupted re-layout
        # must never leave a stale table pointing at partially overwritten
        # segment bytes (the table lands again, atomically, at the end)
        stale = os.path.join(directory, cls.TABLE)
        if os.path.exists(stale):
            os.remove(stale)
        arrs = [[(n, np.asarray(a)) for n, a in g] for g in groups]
        sizes = [sum(a.nbytes for _, a in g) for g in arrs]
        if group_labels is not None:
            assert len(group_labels) == len(groups) == num_segments, (
                len(group_labels), len(groups), num_segments)
            meta = dict(meta or {})
            meta["labels"] = list(group_labels)
        bounds = plan_segments(sizes, num_segments)
        records: List[LeafRecord] = []
        seg_nbytes: List[int] = []
        for seg, (g0, g1) in enumerate(bounds):
            offset = 0
            for name, a in (pair for g in arrs[g0:g1] for pair in g):
                records.append(LeafRecord(name, seg, offset, a.nbytes,
                                          tuple(a.shape), a.dtype.name))
                offset += a.nbytes
            seg_nbytes.append(offset)
        store = cls(directory, records, seg_nbytes, meta)
        flat = {n: a for g in arrs for n, a in g}
        for seg in range(len(seg_nbytes)):
            with open(store.segment_path(seg), "wb") as f:
                f.truncate(seg_nbytes[seg])
            if write:
                store.write_segment(
                    seg,
                    {r.name: flat[r.name] for r in store._seg_leaves[seg]})
        store._write_table()
        return store

    @classmethod
    def open(cls, directory: str) -> "SegmentStore":
        with open(os.path.join(directory, cls.TABLE)) as f:
            table = json.load(f)
        records = [LeafRecord(r["name"], r["segment"], r["offset"],
                              r["nbytes"], tuple(r["shape"]), r["dtype"])
                   for r in table["leaves"]]
        return cls(directory, records, table["seg_nbytes"],
                   table.get("meta", {}))

    @classmethod
    def link_clone(cls, src_dir: str, dest_dir: str) -> "SegmentStore":
        """Open a zero-copy working clone of ``src_dir`` at ``dest_dir``:
        segment files are hardlinked (copied if the filesystem refuses) and
        every segment starts in copy-on-write mode, so writes through the
        clone never touch ``src_dir``."""
        src = cls.open(src_dir)
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(src.num_segments):
            _link_or_copy(src.segment_path(seg),
                          os.path.join(dest_dir, cls._seg_name(seg)))
        shutil.copyfile(os.path.join(src_dir, cls.TABLE),
                        os.path.join(dest_dir, cls.TABLE))
        store = cls(dest_dir, src.records, src.seg_nbytes, src.meta)
        store._cow = [True] * store.num_segments
        return store

    def _write_table(self):
        table = {"version": 1, "seg_nbytes": self.seg_nbytes,
                 "meta": self.meta,
                 "leaves": [r._asdict() for r in self.records]}
        tmp = os.path.join(self.directory, self.TABLE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(table, f)
        os.replace(tmp, os.path.join(self.directory, self.TABLE))

    def write_meta(self, **kw):
        """Update mapping-table metadata (step counters etc.) atomically."""
        self.meta.update(kw)
        self._write_table()

    # ------------------------------------------------------------------
    # geometry
    # ------------------------------------------------------------------
    @staticmethod
    def _seg_name(seg: int) -> str:
        return f"seg_{seg:05d}.bin"

    def segment_path(self, seg: int) -> str:
        return os.path.join(self.directory, self._seg_name(seg))

    @property
    def num_segments(self) -> int:
        return len(self.seg_nbytes)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.seg_nbytes))

    def names(self) -> List[str]:
        return [r.name for r in self.records]

    @property
    def labels(self) -> List[str]:
        """Per-segment labels (aligned mode only; [] otherwise)."""
        return list(self.meta.get("labels", []))

    def record(self, name: str) -> LeafRecord:
        return self._by_name[name]

    def segment_names(self, seg: int) -> List[str]:
        return [r.name for r in self._seg_leaves[seg]]

    # ------------------------------------------------------------------
    # I/O
    # ------------------------------------------------------------------
    def read_segment(self, seg: int, copy: bool = True
                     ) -> Dict[str, np.ndarray]:
        """All leaves of one segment.

        ``copy=True`` returns private arrays safe to mutate; the memory map
        (and its file descriptor) is closed before returning — relying on GC
        to drop the map would pin one fd per call until collection.

        ``copy=False`` returns read-only views into the page-cache mmap
        (zero-copy restore path).  Each view's ``.base`` chain keeps the map
        — and its fd — alive until *every* view is garbage-collected, so
        hold the result only for as long as the zero-copy read is needed and
        never across a ``write_segment``/``_break_cow`` of the same segment
        (the views would keep reading the replaced inode)."""
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r")
        try:
            out = {}
            for r in self._seg_leaves[seg]:
                flat = mm[r.offset:r.offset + r.nbytes].view(
                    _np_dtype(r.dtype))
                arr = flat.reshape(r.shape)
                out[r.name] = np.array(arr) if copy else arr
            return out
        finally:
            if copy:
                mm._mmap.close()   # release the fd now, not at GC time

    def write_segment(self, seg: int, named: Dict[str, np.ndarray]):
        """Write (a subset of) one segment's leaves back and flush.  Breaks
        any snapshot hardlink first (copy-on-write)."""
        self._break_cow(seg)
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r+")
        try:
            for name, value in named.items():
                r = self._by_name[name]
                assert r.segment == seg, (name, r.segment, seg)
                a = np.ascontiguousarray(np.asarray(value), _np_dtype(r.dtype))
                assert a.nbytes == r.nbytes, (name, a.nbytes, r.nbytes)
                mm[r.offset:r.offset + r.nbytes] = _as_bytes(a)
            mm.flush()
        finally:
            mm._mmap.close()       # no views escape this scope

    def _break_cow(self, seg: int):
        if not self._cow[seg]:
            return
        path = self.segment_path(seg)
        tmp = path + ".cow"
        shutil.copyfile(path, tmp)   # fresh inode; snapshot keeps the old one
        os.replace(tmp, path)
        self._cow[seg] = False

    def snapshot(self, dest_dir: str):
        """Zero-copy snapshot: hardlink every segment file + mapping table
        into ``dest_dir`` and flip this store to copy-on-write so later
        updates never mutate the snapshot."""
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(self.num_segments):
            _link_or_copy(self.segment_path(seg),
                          os.path.join(dest_dir, self._seg_name(seg)))
        shutil.copyfile(os.path.join(self.directory, self.TABLE),
                        os.path.join(dest_dir, self.TABLE))
        self._cow = [True] * self.num_segments
        return dest_dir


def _link_or_copy(src: str, dest: str):
    if os.path.exists(dest):
        os.remove(dest)
    try:
        os.link(src, dest)
    except OSError:           # cross-device or FS without hardlinks
        shutil.copyfile(src, dest)
