"""Segment files + mapping table (paper §4.1.1).

The paper partitions the parameter space into contiguous segments, keeps the
active segment in RAM and pages the rest to flash, tracked by a mapping
table.  Here a ``SegmentStore`` owns a directory of raw segment files
(``seg_00000.bin`` ...) plus ``table.json`` — the mapping table recording,
for every pytree leaf, which segment holds it and at which byte offset.

Leaves are grouped (a group is never split across segments — e.g. the
(param, m, v) triple of one tensor) and groups are packed contiguously into
``num_segments`` byte-balanced segments.

I/O is memory-mapped: reads slice an ``np.memmap`` (page-cache backed, no
user-space staging), writes go through an ``r+`` map and are flushed before
the map is dropped.  ``snapshot``/``link_clone`` hardlink the segment files
(zero-copy checkpointing) and flip the store into copy-on-write mode so the
snapshot inode is never mutated: the first later write to a segment rewrites
it under a fresh inode via copy + atomic replace.

Every leaf carries a *codec* (repro/offload/codecs.py) deciding how its
logical array maps to stored bytes: ``identity`` (raw), ``bf16`` (half-sized
moments) or ``int8`` (per-channel quantized frozen base).  The mapping table
records the codec per leaf (table version 2); version-1 tables — written
before the codec column existed — upgrade transparently on open (their
bf16-stored moments become ``bf16``-codec leaves with fp32 logical dtype).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.offload.codecs import get_codec, np_dtype

TABLE_VERSION = 2


class LeafRecord(NamedTuple):
    name: str
    segment: int
    offset: int      # byte offset inside the segment file
    nbytes: int      # *stored* bytes (post-codec; != logical for bf16/int8)
    shape: Tuple[int, ...]   # logical shape
    dtype: str       # logical numpy dtype name ("float32", "bfloat16", ...)
    codec: str = "identity"


def plan_segments(group_nbytes: Sequence[int], num_segments: int
                  ) -> List[Tuple[int, int]]:
    """Partition groups (in order) into ``num_segments`` contiguous,
    byte-balanced spans.  Returns [start, end) group-index bounds; never
    splits a group; never emits an empty segment (fewer segments than
    requested when there are fewer groups)."""
    n_groups = len(group_nbytes)
    if n_groups == 0:
        return []
    n = max(1, min(int(num_segments), n_groups))
    bounds: List[Tuple[int, int]] = []
    start = 0
    bytes_left = float(sum(group_nbytes))
    for seg in range(n):
        segs_left = n - seg
        take_max = (n_groups - start) - (segs_left - 1)
        target = bytes_left / segs_left
        acc = 0.0
        end = start
        while end < start + take_max:
            acc += group_nbytes[end]
            end += 1
            if segs_left > 1 and acc >= target:
                break
        bounds.append((start, end))
        bytes_left -= acc
        start = end
    return bounds


class SegmentStore:
    """Mapping table + mmap-backed segment files for a flat named leaf set."""

    TABLE = "table.json"

    def __init__(self, directory: str, records: List[LeafRecord],
                 seg_nbytes: List[int], meta: Optional[Dict] = None):
        self.directory = directory
        self.records = records
        self.seg_nbytes = seg_nbytes
        self.meta = dict(meta or {})
        self._by_name = {r.name: r for r in records}
        self._seg_leaves: List[List[LeafRecord]] = [
            [] for _ in range(len(seg_nbytes))]
        for r in records:
            self._seg_leaves[r.segment].append(r)
        self._cow = [False] * len(seg_nbytes)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, directory: str,
               groups: Sequence[Sequence[Tuple]],
               num_segments: int, meta: Optional[Dict] = None,
               group_labels: Optional[Sequence[str]] = None,
               write: bool = True) -> "SegmentStore":
        """Write ``groups`` (ordered lists of (name, array) or
        (name, array, codec); a group is kept within one segment) into
        ``num_segments`` segment files.  Omitted codecs default to identity;
        stored bytes per leaf come from the codec, so a bf16 or int8 leaf
        occupies less flash than its logical array.

        ``group_labels`` (one per *group*) turns on aligned mode: each group
        gets its own segment (``num_segments`` must equal the group count) and
        ``meta["labels"]`` records the label of every segment — the
        layer-streamed path uses this to map block index -> segment without
        consulting leaf names.

        ``write=False`` lays out the geometry only: segment files are
        truncated to size (sparse, read back as zeros) and the array
        *contents* are never written — for scratch stores whose first use
        overwrites everything (e.g. the gradient sink).
        """
        os.makedirs(directory, exist_ok=True)
        # drop any previous mapping table first: an interrupted re-layout
        # must never leave a stale table pointing at partially overwritten
        # segment bytes (the table lands again, atomically, at the end)
        stale = os.path.join(directory, cls.TABLE)
        if os.path.exists(stale):
            os.remove(stale)
        arrs = [[(t[0], np.asarray(t[1]), t[2] if len(t) > 2 else "identity")
                 for t in g] for g in groups]
        sizes = [sum(get_codec(c).encoded_nbytes(a.shape, a.dtype.name)
                     for _, a, c in g) for g in arrs]
        if group_labels is not None:
            assert len(group_labels) == len(groups) == num_segments, (
                len(group_labels), len(groups), num_segments)
            meta = dict(meta or {})
            meta["labels"] = list(group_labels)
        bounds = plan_segments(sizes, num_segments)
        records: List[LeafRecord] = []
        seg_nbytes: List[int] = []
        for seg, (g0, g1) in enumerate(bounds):
            offset = 0
            for name, a, codec in (t for g in arrs[g0:g1] for t in g):
                nbytes = get_codec(codec).encoded_nbytes(a.shape,
                                                         a.dtype.name)
                records.append(LeafRecord(name, seg, offset, nbytes,
                                          tuple(a.shape), a.dtype.name,
                                          codec))
                offset += nbytes
            seg_nbytes.append(offset)
        store = cls(directory, records, seg_nbytes, meta)
        flat = {n: a for g in arrs for n, a, _ in g}
        for seg in range(len(seg_nbytes)):
            with open(store.segment_path(seg), "wb") as f:
                f.truncate(seg_nbytes[seg])
            if write:
                store.write_segment(
                    seg,
                    {r.name: flat[r.name] for r in store._seg_leaves[seg]})
        store._write_table()
        return store

    @classmethod
    def open(cls, directory: str) -> "SegmentStore":
        path = os.path.join(directory, cls.TABLE)
        with open(path) as f:
            table = json.load(f)
        version = table.get("version", 1)
        if version not in (1, TABLE_VERSION):
            raise ValueError(
                f"mapping table {path} has version {version}; this build "
                f"reads versions 1-{TABLE_VERSION}.  The segment layout was "
                "written by a newer build — upgrade the package, or "
                "re-create the layout (delete the segment directory and "
                "rerun) to continue with this one")
        records = [cls._leaf_record(r, version) for r in table["leaves"]]
        return cls(directory, records, table["seg_nbytes"],
                   table.get("meta", {}))

    @staticmethod
    def _leaf_record(r: Dict, version: int) -> LeafRecord:
        """One mapping-table row -> LeafRecord, upgrading version-1 rows:
        they predate the codec column, and their reduced-precision moments
        (``m.``/``v.`` leaves stored as bfloat16 with an ad-hoc cast in the
        update) become ``bf16``-codec leaves with fp32 logical dtype — the
        same bytes on flash, now decoded/encoded by the codec layer."""
        codec = r.get("codec", "identity")
        dtype = r["dtype"]
        if (version == 1 and dtype == "bfloat16"
                and r["name"].startswith(("m.", "v."))):
            codec, dtype = "bf16", "float32"
        return LeafRecord(r["name"], r["segment"], r["offset"], r["nbytes"],
                          tuple(r["shape"]), dtype, codec)

    @classmethod
    def link_clone(cls, src_dir: str, dest_dir: str) -> "SegmentStore":
        """Open a zero-copy working clone of ``src_dir`` at ``dest_dir``:
        segment files are hardlinked (copied if the filesystem refuses) and
        every segment starts in copy-on-write mode, so writes through the
        clone never touch ``src_dir``."""
        src = cls.open(src_dir)
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(src.num_segments):
            _link_or_copy(src.segment_path(seg),
                          os.path.join(dest_dir, cls._seg_name(seg)))
        shutil.copyfile(os.path.join(src_dir, cls.TABLE),
                        os.path.join(dest_dir, cls.TABLE))
        store = cls(dest_dir, src.records, src.seg_nbytes, src.meta)
        store._cow = [True] * store.num_segments
        return store

    def _write_table(self):
        table = {"version": TABLE_VERSION, "seg_nbytes": self.seg_nbytes,
                 "meta": self.meta,
                 "leaves": [r._asdict() for r in self.records]}
        tmp = os.path.join(self.directory, self.TABLE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(table, f)
        os.replace(tmp, os.path.join(self.directory, self.TABLE))

    def write_meta(self, **kw):
        """Update mapping-table metadata (step counters etc.) atomically."""
        self.meta.update(kw)
        self._write_table()

    # ------------------------------------------------------------------
    # geometry
    # ------------------------------------------------------------------
    @staticmethod
    def _seg_name(seg: int) -> str:
        return f"seg_{seg:05d}.bin"

    def segment_path(self, seg: int) -> str:
        return os.path.join(self.directory, self._seg_name(seg))

    @property
    def num_segments(self) -> int:
        return len(self.seg_nbytes)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.seg_nbytes))

    def names(self) -> List[str]:
        return [r.name for r in self.records]

    @property
    def labels(self) -> List[str]:
        """Per-segment labels (aligned mode only; [] otherwise)."""
        return list(self.meta.get("labels", []))

    def record(self, name: str) -> LeafRecord:
        return self._by_name[name]

    def segment_names(self, seg: int) -> List[str]:
        return [r.name for r in self._seg_leaves[seg]]

    def segment_signature(self, seg: int) -> Tuple:
        """Geometry signature of one segment: the (shape, dtype, codec)
        tuple of every leaf, in order.  Two segments with equal signatures
        hold interchangeable buffer sets (layer-aligned stores: every block
        segment) — the prefetcher keys its reusable-buffer pool on this."""
        return tuple((r.shape, r.dtype, r.codec)
                     for r in self._seg_leaves[seg])

    # ------------------------------------------------------------------
    # I/O
    # ------------------------------------------------------------------
    def read_segment(self, seg: int, copy: bool = True,
                     encoded: bool = False,
                     window: bool = False,
                     out: Optional[List[np.ndarray]] = None
                     ) -> Dict[str, np.ndarray]:
        """All leaves of one segment, decoded through each leaf's codec.

        ``copy=True`` returns private arrays safe to mutate; the memory map
        (and its file descriptor) is closed before returning — relying on GC
        to drop the map would pin one fd per call until collection.

        ``copy=False`` returns read-only views into the page-cache mmap
        where the codec allows it (identity; converting codecs always
        allocate).  Each view's ``.base`` chain keeps the map — and its fd —
        alive until *every* view is garbage-collected, so hold the result
        only for as long as the zero-copy read is needed and never across a
        ``write_segment``/``_break_cow`` of the same segment (the views
        would keep reading the replaced inode).

        ``window=True`` returns each leaf's *window* representation (the
        offload engine's resident form): private arrays that stay at
        storage precision where that matters (bf16 moments remain bf16, so
        the halved resident bytes survive; the consumer casts at use).

        ``encoded=True`` skips decoding entirely: every leaf comes back as
        a ``QuantLeaf`` (codes in the logical shape + per-channel scales;
        empty scales for passthrough codecs) — the quantized-frozen-base
        window keeps segments int8-resident and defers dequantization to
        the jitted per-block program.

        ``out`` (readinto-style, allocation-free reads) is an optional list
        of reusable destination arrays, positionally aligned with this
        segment's leaves: a leaf whose entry matches its decoded/window
        representation (shape + dtype) is copied *into* that array instead
        of allocating a fresh one — the prefetcher recycles evicted window
        buffers through this path so steady-state streaming stops paying a
        segment-sized allocation per pull.  Mismatched (or None) entries
        fall back to allocation; incompatible with ``copy=False``."""
        leaves = self._seg_leaves[seg]
        if out is not None and (not copy or encoded
                                or len(out) != len(leaves)):
            out = None
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r")
        try:
            named = {}
            for i, r in enumerate(leaves):
                buf = mm[r.offset:r.offset + r.nbytes]
                codec = get_codec(r.codec)
                if encoded:
                    named[r.name] = codec.decode_encoded(buf, r.shape,
                                                         r.dtype)
                    continue
                dst = out[i] if out is not None else None
                if dst is not None:
                    want = (codec.window_np_dtype(r.dtype) if window
                            else np_dtype(r.dtype))
                    view = (codec.storage_view(buf, r.shape, r.dtype)
                            if (isinstance(dst, np.ndarray)
                                and dst.shape == tuple(r.shape)
                                and dst.dtype == want) else None)
                    if view is not None:
                        np.copyto(dst, view)   # in-place; casts bf16->fp32
                        named[r.name] = dst
                        continue
                if window:
                    named[r.name] = codec.window(buf, r.shape, r.dtype)
                else:
                    named[r.name] = codec.decode(buf, r.shape, r.dtype,
                                                 copy=copy)
            return named
        finally:
            if copy or encoded or window:
                mm._mmap.close()   # release the fd now, not at GC time

    def write_segment(self, seg: int, named: Dict[str, np.ndarray],
                      sync: bool = True):
        """Encode (a subset of) one segment's leaves back through their
        codecs and flush.  Breaks any snapshot hardlink first
        (copy-on-write).

        ``sync=False`` skips the msync: bytes land in the page cache (fully
        visible to every later read) but durability is deferred — the async
        write-back path uses this so background writes are memcpy-cheap,
        then settles durability with one ``sync_segment`` per touched file
        at the flush/snapshot barrier."""
        self._break_cow(seg)
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r+")
        try:
            for r, enc in self._encoded_leaves(seg, named):
                mm[r.offset:r.offset + r.nbytes] = enc
            if sync:
                mm.flush()
        finally:
            mm._mmap.close()       # no views escape this scope

    def _encoded_leaves(self, seg: int, named: Dict[str, np.ndarray]):
        """(record, encoded uint8 bytes) per leaf — the one encode loop
        both write paths share, so the sync (memmap) and async (pwrite)
        writers can never drift in what bytes they persist."""
        for name, value in named.items():
            r = self._by_name[name]
            assert r.segment == seg, (name, r.segment, seg)
            enc = get_codec(r.codec).encode(np.asarray(value), r.dtype)
            assert enc.nbytes == r.nbytes, (name, enc.nbytes, r.nbytes)
            yield r, enc

    def pwrite_segment(self, seg: int, named: Dict[str, np.ndarray],
                       sync: bool = False):
        """``write_segment`` via positional ``pwrite(2)`` on a plain fd —
        no memory map, and the kernel's copy into the page cache runs with
        the GIL *released*, so the async writer's background writes truly
        overlap main-thread work (a memmap slice-assign holds the GIL for
        the whole copy).  Identity-codec leaves encode as zero-copy views,
        making the background write almost pure syscall time.  Reads via
        mmap see these bytes immediately (one unified page cache)."""
        self._break_cow(seg)
        fd = os.open(self.segment_path(seg), os.O_WRONLY)
        try:
            for r, enc in self._encoded_leaves(seg, named):
                mv, off = memoryview(enc), r.offset
                while len(mv):                 # pwrite may write short
                    n = os.pwrite(fd, mv, off)
                    mv, off = mv[n:], off + n
            if sync:
                os.fsync(fd)
        finally:
            os.close(fd)

    def sync_segment(self, seg: int):
        """fsync one segment file — settles the durability a
        ``write_segment(..., sync=False)``/``pwrite_segment`` deferred."""
        fd = os.open(self.segment_path(seg), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _break_cow(self, seg: int):
        if not self._cow[seg]:
            return
        path = self.segment_path(seg)
        tmp = path + ".cow"
        shutil.copyfile(path, tmp)   # fresh inode; snapshot keeps the old one
        os.replace(tmp, path)
        self._cow[seg] = False

    def snapshot(self, dest_dir: str):
        """Zero-copy snapshot: hardlink every segment file + mapping table
        into ``dest_dir`` and flip this store to copy-on-write so later
        updates never mutate the snapshot."""
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(self.num_segments):
            _link_or_copy(self.segment_path(seg),
                          os.path.join(dest_dir, self._seg_name(seg)))
        shutil.copyfile(os.path.join(self.directory, self.TABLE),
                        os.path.join(dest_dir, self.TABLE))
        self._cow = [True] * self.num_segments
        return dest_dir


def _link_or_copy(src: str, dest: str):
    if os.path.exists(dest):
        os.remove(dest)
    try:
        os.link(src, dest)
    except OSError:           # cross-device or FS without hardlinks
        shutil.copyfile(src, dest)
