"""Segment files + mapping table (paper §4.1.1).

The paper partitions the parameter space into contiguous segments, keeps the
active segment in RAM and pages the rest to flash, tracked by a mapping
table.  Here a ``SegmentStore`` owns a directory of raw segment files
(``seg_00000.bin`` ...) plus ``table.json`` — the mapping table recording,
for every pytree leaf, which segment holds it and at which byte offset.

Leaves are grouped (a group is never split across segments — e.g. the
(param, m, v) triple of one tensor) and groups are packed contiguously into
``num_segments`` byte-balanced segments.

I/O is memory-mapped by default: reads slice an ``np.memmap`` (page-cache
backed, no user-space staging), writes go through an ``r+`` map and are
flushed before the map is dropped.  The read side is additionally
*pluggable* (``io_backend`` / ``$REPRO_OFFLOAD_IO``; see
repro/offload/readers.py): ``pread`` batches positional reads straight
into destination buffers, ``direct`` bypasses the page cache with
O_DIRECT, ``uring`` submits one SQE batch per segment pull.  ``mmap``
stays the numerics oracle — every raw backend decodes through the same
per-leaf codec loop, so bytes are bit-identical across backends.
``snapshot``/``link_clone`` hardlink the segment files
(zero-copy checkpointing) and flip the store into copy-on-write mode so the
snapshot inode is never mutated: the first later write to a segment rewrites
it under a fresh inode via copy + atomic replace.

Every leaf carries a *codec* (repro/offload/codecs.py) deciding how its
logical array maps to stored bytes: ``identity`` (raw), ``bf16`` (half-sized
moments) or ``int8`` (per-channel quantized frozen base).  The mapping table
records the codec per leaf (table version 2); version-1 tables — written
before the codec column existed — upgrade transparently on open (their
bf16-stored moments become ``bf16``-codec leaves with fp32 logical dtype).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
import weakref
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.offload.codecs import get_codec, np_dtype
from repro.offload.readers import (aligned_empty, make_reader,
                                   resolve_io_backend)

TABLE_VERSION = 2

# store kinds whose segment files are write-once scratch (re-created every
# run, never re-read after training): their durability barrier may evict
# the written pages from the page cache instead of leaving them to fight
# the streamed base's reads
SCRATCH_KINDS = ("grad_scratch_v1", "act_scratch_v1")


class LeafRecord(NamedTuple):
    name: str
    segment: int
    offset: int      # byte offset inside the segment file
    nbytes: int      # *stored* bytes (post-codec; != logical for bf16/int8)
    shape: Tuple[int, ...]   # logical shape
    dtype: str       # logical numpy dtype name ("float32", "bfloat16", ...)
    codec: str = "identity"


def plan_segments(group_nbytes: Sequence[int], num_segments: int
                  ) -> List[Tuple[int, int]]:
    """Partition groups (in order) into ``num_segments`` contiguous,
    byte-balanced spans.  Returns [start, end) group-index bounds; never
    splits a group; never emits an empty segment (fewer segments than
    requested when there are fewer groups)."""
    n_groups = len(group_nbytes)
    if n_groups == 0:
        return []
    n = max(1, min(int(num_segments), n_groups))
    bounds: List[Tuple[int, int]] = []
    start = 0
    bytes_left = float(sum(group_nbytes))
    for seg in range(n):
        segs_left = n - seg
        take_max = (n_groups - start) - (segs_left - 1)
        target = bytes_left / segs_left
        acc = 0.0
        end = start
        while end < start + take_max:
            acc += group_nbytes[end]
            end += 1
            if segs_left > 1 and acc >= target:
                break
        bounds.append((start, end))
        bytes_left -= acc
        start = end
    return bounds


class SegmentStore:
    """Mapping table + mmap-backed segment files for a flat named leaf set."""

    TABLE = "table.json"

    def __init__(self, directory: str, records: List[LeafRecord],
                 seg_nbytes: List[int], meta: Optional[Dict] = None,
                 io_backend: str = ""):
        self.directory = directory
        self.records = records
        self.seg_nbytes = seg_nbytes
        self.meta = dict(meta or {})
        self._by_name = {r.name: r for r in records}
        self._seg_leaves: List[List[LeafRecord]] = [
            [] for _ in range(len(seg_nbytes))]
        for r in records:
            self._seg_leaves[r.segment].append(r)
        self._cow = [False] * len(seg_nbytes)
        self._scratch = self.meta.get("kind") in SCRATCH_KINDS
        # read-backend selection: explicit arg > $REPRO_OFFLOAD_IO > mmap;
        # direct/uring degrade to pread when their kernel/fs probe fails
        self.io_requested, self.io_backend = resolve_io_backend(
            io_backend, directory)
        self._reader = None             # built lazily (first raw read)
        self._io_lock = threading.Lock()
        # copy=False view-lifetime debug guard ($REPRO_OFFLOAD_VIEW_GUARD=1)
        self._view_guard = os.environ.get(
            "REPRO_OFFLOAD_VIEW_GUARD", "") == "1"
        self._live_views: Dict[int, int] = {}   # guarded-by: _io_lock
        self.cow_breaks = 0
        self.cow_break_s = 0.0

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, directory: str,
               groups: Sequence[Sequence[Tuple]],
               num_segments: int, meta: Optional[Dict] = None,
               group_labels: Optional[Sequence[str]] = None,
               write: bool = True, io_backend: str = "") -> "SegmentStore":
        """Write ``groups`` (ordered lists of (name, array) or
        (name, array, codec); a group is kept within one segment) into
        ``num_segments`` segment files.  Omitted codecs default to identity;
        stored bytes per leaf come from the codec, so a bf16 or int8 leaf
        occupies less flash than its logical array.

        ``group_labels`` (one per *group*) turns on aligned mode: each group
        gets its own segment (``num_segments`` must equal the group count) and
        ``meta["labels"]`` records the label of every segment — the
        layer-streamed path uses this to map block index -> segment without
        consulting leaf names.

        ``write=False`` lays out the geometry only: segment files are
        truncated to size (sparse, read back as zeros) and the array
        *contents* are never written — for scratch stores whose first use
        overwrites everything (e.g. the gradient sink).
        """
        os.makedirs(directory, exist_ok=True)
        # drop any previous mapping table first: an interrupted re-layout
        # must never leave a stale table pointing at partially overwritten
        # segment bytes (the table lands again, atomically, at the end)
        stale = os.path.join(directory, cls.TABLE)
        if os.path.exists(stale):
            os.remove(stale)
        arrs = [[(t[0], np.asarray(t[1]), t[2] if len(t) > 2 else "identity")
                 for t in g] for g in groups]
        sizes = [sum(get_codec(c).encoded_nbytes(a.shape, a.dtype.name)
                     for _, a, c in g) for g in arrs]
        if group_labels is not None:
            assert len(group_labels) == len(groups) == num_segments, (
                len(group_labels), len(groups), num_segments)
            meta = dict(meta or {})
            meta["labels"] = list(group_labels)
        bounds = plan_segments(sizes, num_segments)
        records: List[LeafRecord] = []
        seg_nbytes: List[int] = []
        for seg, (g0, g1) in enumerate(bounds):
            offset = 0
            for name, a, codec in (t for g in arrs[g0:g1] for t in g):
                nbytes = get_codec(codec).encoded_nbytes(a.shape,
                                                         a.dtype.name)
                records.append(LeafRecord(name, seg, offset, nbytes,
                                          tuple(a.shape), a.dtype.name,
                                          codec))
                offset += nbytes
            seg_nbytes.append(offset)
        store = cls(directory, records, seg_nbytes, meta,
                    io_backend=io_backend)
        flat = {n: a for g in arrs for n, a, _ in g}
        for seg in range(len(seg_nbytes)):
            with open(store.segment_path(seg), "wb") as f:
                f.truncate(seg_nbytes[seg])
            if write:
                store.write_segment(
                    seg,
                    {r.name: flat[r.name] for r in store._seg_leaves[seg]})
        store._write_table()
        return store

    @classmethod
    def open(cls, directory: str, io_backend: str = "") -> "SegmentStore":
        path = os.path.join(directory, cls.TABLE)
        with open(path) as f:
            table = json.load(f)
        version = table.get("version", 1)
        if version not in (1, TABLE_VERSION):
            raise ValueError(
                f"mapping table {path} has version {version}; this build "
                f"reads versions 1-{TABLE_VERSION}.  The segment layout was "
                "written by a newer build — upgrade the package, or "
                "re-create the layout (delete the segment directory and "
                "rerun) to continue with this one")
        records = [cls._leaf_record(r, version) for r in table["leaves"]]
        return cls(directory, records, table["seg_nbytes"],
                   table.get("meta", {}), io_backend=io_backend)

    @staticmethod
    def _leaf_record(r: Dict, version: int) -> LeafRecord:
        """One mapping-table row -> LeafRecord, upgrading version-1 rows:
        they predate the codec column, and their reduced-precision moments
        (``m.``/``v.`` leaves stored as bfloat16 with an ad-hoc cast in the
        update) become ``bf16``-codec leaves with fp32 logical dtype — the
        same bytes on flash, now decoded/encoded by the codec layer."""
        codec = r.get("codec", "identity")
        dtype = r["dtype"]
        if (version == 1 and dtype == "bfloat16"
                and r["name"].startswith(("m.", "v."))):
            codec, dtype = "bf16", "float32"
        return LeafRecord(r["name"], r["segment"], r["offset"], r["nbytes"],
                          tuple(r["shape"]), dtype, codec)

    @classmethod
    def link_clone(cls, src_dir: str, dest_dir: str,
                   io_backend: str = "") -> "SegmentStore":
        """Open a zero-copy working clone of ``src_dir`` at ``dest_dir``:
        segment files are hardlinked (copied if the filesystem refuses) and
        every segment starts in copy-on-write mode, so writes through the
        clone never touch ``src_dir``."""
        src = cls.open(src_dir)
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(src.num_segments):
            _link_or_copy(src.segment_path(seg),
                          os.path.join(dest_dir, cls._seg_name(seg)))
        shutil.copyfile(os.path.join(src_dir, cls.TABLE),
                        os.path.join(dest_dir, cls.TABLE))
        store = cls(dest_dir, src.records, src.seg_nbytes, src.meta,
                    io_backend=io_backend)
        store._cow = [True] * store.num_segments
        return store

    def _write_table(self):
        table = {"version": TABLE_VERSION, "seg_nbytes": self.seg_nbytes,
                 "meta": self.meta,
                 "leaves": [r._asdict() for r in self.records]}
        tmp = os.path.join(self.directory, self.TABLE + ".tmp")
        with open(tmp, "w") as f:
            json.dump(table, f)
        os.replace(tmp, os.path.join(self.directory, self.TABLE))

    def write_meta(self, **kw):
        """Update mapping-table metadata (step counters etc.) atomically."""
        self.meta.update(kw)
        self._write_table()

    # ------------------------------------------------------------------
    # geometry
    # ------------------------------------------------------------------
    @staticmethod
    def _seg_name(seg: int) -> str:
        return f"seg_{seg:05d}.bin"

    def segment_path(self, seg: int) -> str:
        return os.path.join(self.directory, self._seg_name(seg))

    @property
    def num_segments(self) -> int:
        return len(self.seg_nbytes)

    @property
    def total_bytes(self) -> int:
        return int(sum(self.seg_nbytes))

    def names(self) -> List[str]:
        return [r.name for r in self.records]

    @property
    def labels(self) -> List[str]:
        """Per-segment labels (aligned mode only; [] otherwise)."""
        return list(self.meta.get("labels", []))

    def record(self, name: str) -> LeafRecord:
        return self._by_name[name]

    def segment_names(self, seg: int) -> List[str]:
        return [r.name for r in self._seg_leaves[seg]]

    def segment_signature(self, seg: int) -> Tuple:
        """Geometry signature of one segment: the (shape, dtype, codec)
        tuple of every leaf, in order.  Two segments with equal signatures
        hold interchangeable buffer sets (layer-aligned stores: every block
        segment) — the prefetcher keys its reusable-buffer pool on this."""
        return tuple((r.shape, r.dtype, r.codec)
                     for r in self._seg_leaves[seg])

    # ------------------------------------------------------------------
    # read backend (readers.py)
    # ------------------------------------------------------------------
    def set_io_backend(self, io_backend: str) -> str:
        """Re-select the read backend (probing again); returns the
        *actual* backend name after fallback resolution."""
        self.close_io()
        self.io_requested, self.io_backend = resolve_io_backend(
            io_backend, self.directory)
        return self.io_backend

    def _ensure_reader(self):
        # double-checked under the lock: read_segment runs concurrently on
        # the prefetcher thread and a consumer's sync-load fallback
        r = self._reader
        if r is None and self.io_backend != "mmap":
            with self._io_lock:
                r = self._reader
                if r is None:
                    r = self._reader = make_reader(self.io_backend,
                                                   self.directory)
        return r

    def close_io(self):
        """Release the reader's ring/pool.  Idempotent; a later read
        lazily re-creates the reader, so close-then-reuse stays legal."""
        with self._io_lock:
            r, self._reader = self._reader, None
        if r is not None:
            r.close()

    def io_stats(self) -> Dict[str, float]:
        """Numeric reader counters (empty for mmap) + COW-break cost."""
        r = self._reader
        s = dict(r.stats()) if r is not None else {}
        s["cow_breaks"] = self.cow_breaks
        s["cow_break_s"] = self.cow_break_s
        return s

    def io_pool_bytes(self) -> int:
        """Bytes held by the reader's staging pool — counted into the
        engine's peak-residency accounting so raw backends can't hide
        memory in their scratch buffers."""
        r = self._reader
        return r.pool_bytes() if r is not None else 0

    def drop_cache(self):
        """Evict every segment file from the page cache (fsync first so
        dirty pages survive the drop).  The cold-cache benchmark mode
        calls this between steps so reads measure flash, not RAM."""
        for seg in range(self.num_segments):
            fd = os.open(self.segment_path(seg), os.O_RDONLY)
            try:
                os.fsync(fd)
                os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
            finally:
                os.close(fd)

    # ------------------------------------------------------------------
    # copy=False view-lifetime guard ($REPRO_OFFLOAD_VIEW_GUARD=1)
    # ------------------------------------------------------------------
    def _track_views(self, seg: int, named: Dict[str, np.ndarray], mm):
        """Register a finalizer on every returned array that aliases the
        mmap, so writes to a segment with live zero-copy views can raise
        instead of silently mutating (or orphaning, post-COW) the bytes
        under the caller's feet."""
        target = mm._mmap

        def _dead(s=seg):
            with self._io_lock:
                n = self._live_views.get(s, 1) - 1
                if n <= 0:
                    self._live_views.pop(s, None)
                else:
                    self._live_views[s] = n

        for arr in named.values():
            base = arr if isinstance(arr, np.ndarray) else None
            while base is not None and not isinstance(base, np.memmap):
                base = getattr(base, "base", None)
            if base is None or base._mmap is not target:
                continue
            with self._io_lock:
                self._live_views[seg] = self._live_views.get(seg, 0) + 1
            weakref.finalize(arr, _dead)

    def _check_no_views(self, seg: int, op: str):
        if not self._view_guard:
            return
        with self._io_lock:
            n = self._live_views.get(seg, 0)
        if n:
            raise RuntimeError(
                f"{op} on segment {seg} while {n} zero-copy view(s) from "
                f"read_segment(copy=False) are still alive — drop them "
                f"first (they would keep reading stale/replaced bytes)")

    # ------------------------------------------------------------------
    # I/O
    # ------------------------------------------------------------------
    def _decode_leaf(self, r: LeafRecord, buf: np.ndarray, encoded: bool,
                     window: bool, dst: Optional[np.ndarray]):
        """One leaf's storage bytes -> its requested representation.  The
        single decode body every backend shares: mmap hands in page-cache
        slices, the raw staged paths hand in pooled-buffer slices — same
        codec calls either way, so backends are bit-identical by
        construction."""
        codec = get_codec(r.codec)
        if encoded:
            return codec.decode_encoded(buf, r.shape, r.dtype)
        if dst is not None:
            want = (codec.window_np_dtype(r.dtype) if window
                    else np_dtype(r.dtype))
            view = (codec.storage_view(buf, r.shape, r.dtype)
                    if (isinstance(dst, np.ndarray)
                        and dst.shape == tuple(r.shape)
                        and dst.dtype == want) else None)
            if view is not None:
                np.copyto(dst, view)   # in-place; casts bf16->fp32
                return dst
        if window:
            return codec.window(buf, r.shape, r.dtype)
        return codec.decode(buf, r.shape, r.dtype, copy=True)
    def read_segment(self, seg: int, copy: bool = True,
                     encoded: bool = False,
                     window: bool = False,
                     out: Optional[List[np.ndarray]] = None
                     ) -> Dict[str, np.ndarray]:
        """All leaves of one segment, decoded through each leaf's codec.

        ``copy=True`` returns private arrays safe to mutate; the memory map
        (and its file descriptor) is closed before returning — relying on GC
        to drop the map would pin one fd per call until collection.

        ``copy=False`` returns read-only views into the page-cache mmap
        where the codec allows it (identity; converting codecs always
        allocate).  Each view's ``.base`` chain keeps the map — and its fd —
        alive until *every* view is garbage-collected, so hold the result
        only for as long as the zero-copy read is needed and never across a
        ``write_segment``/``_break_cow`` of the same segment (the views
        would keep reading the replaced inode).

        ``window=True`` returns each leaf's *window* representation (the
        offload engine's resident form): private arrays that stay at
        storage precision where that matters (bf16 moments remain bf16, so
        the halved resident bytes survive; the consumer casts at use).

        ``encoded=True`` skips decoding entirely: every leaf comes back as
        a ``QuantLeaf`` (codes in the logical shape + per-channel scales;
        empty scales for passthrough codecs) — the quantized-frozen-base
        window keeps segments int8-resident and defers dequantization to
        the jitted per-block program.

        ``out`` (readinto-style, allocation-free reads) is an optional list
        of reusable destination arrays, positionally aligned with this
        segment's leaves: a leaf whose entry matches its decoded/window
        representation (shape + dtype) is copied *into* that array instead
        of allocating a fresh one — the prefetcher recycles evicted window
        buffers through this path so steady-state streaming stops paying a
        segment-sized allocation per pull.  Mismatched (or None) entries
        fall back to allocation; incompatible with ``copy=False``.

        The read transport is the store's configured backend
        (``io_backend``); ``copy=False`` always uses the mmap path — a
        raw read has no page-cache map to hand out views of."""
        leaves = self._seg_leaves[seg]
        if out is not None and (not copy or encoded
                                or len(out) != len(leaves)):
            out = None
        reader = self._ensure_reader() if copy else None
        if reader is not None:
            if reader.whole_segment:
                return self._read_staged(reader, seg, leaves, encoded,
                                         window, out)
            return self._read_batched(reader, seg, leaves, encoded,
                                      window, out)
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r")
        try:
            named = {}
            for i, r in enumerate(leaves):
                buf = mm[r.offset:r.offset + r.nbytes]
                if not copy and not encoded and not window:
                    named[r.name] = get_codec(r.codec).decode(
                        buf, r.shape, r.dtype, copy=False)
                    continue
                named[r.name] = self._decode_leaf(
                    r, buf, encoded, window,
                    out[i] if out is not None else None)
            if not copy and self._view_guard:
                self._track_views(seg, named, mm)
            return named
        finally:
            if copy or encoded or window:
                mm._mmap.close()   # release the fd now, not at GC time

    def _read_staged(self, reader, seg: int, leaves, encoded: bool,
                     window: bool, out) -> Dict[str, np.ndarray]:
        """Whole-segment raw read (O_DIRECT): one staged pull into an
        aligned pooled buffer, then the shared per-leaf decode loop."""
        buf, release = reader.read_segment_bytes(self.segment_path(seg),
                                                 self.seg_nbytes[seg])
        try:
            return {r.name: self._decode_leaf(
                        r, buf[r.offset:r.offset + r.nbytes], encoded,
                        window, out[i] if out is not None else None)
                    for i, r in enumerate(leaves)}
        finally:
            release()   # _decode_leaf never leaks views of a staged buffer

    def _read_batched(self, reader, seg: int, leaves, encoded: bool,
                      window: bool, out) -> Dict[str, np.ndarray]:
        """Per-leaf raw read (pread/uring): flat-storage leaves are read
        *straight into* their destination arrays (recycled ``out`` buffers
        when compatible, fresh 4096-aligned ones otherwise — so buffers
        recirculating through the prefetcher pool stay O_DIRECT-ready);
        converting leaves (int8 packs, bf16->fp32 decodes) stage through a
        small pooled chunk each.  The whole segment is one request batch —
        under uring that is one SQE batch + one syscall."""
        requests: List[Tuple[int, np.ndarray]] = []
        results: List[Optional[np.ndarray]] = [None] * len(leaves)
        staged: List[Tuple[LeafRecord, np.ndarray, int]] = []
        try:
            for i, r in enumerate(leaves):
                codec = get_codec(r.codec)
                want = (codec.window_np_dtype(r.dtype) if window
                        else np_dtype(r.dtype))
                if not encoded and codec.storage_np_dtype(r.dtype) == want:
                    dst = out[i] if out is not None else None
                    if (not isinstance(dst, np.ndarray)
                            or dst.shape != tuple(r.shape)
                            or dst.dtype != want
                            or not dst.flags.c_contiguous):
                        dst = aligned_empty(r.shape, want)
                    requests.append(
                        (r.offset,
                         dst.reshape(-1) if dst.ndim == 0 else dst))
                    results[i] = dst
                else:
                    chunk = reader.pool.get(r.nbytes)
                    staged.append((r, chunk, i))
                    requests.append((r.offset, chunk[:r.nbytes]))
            reader.read_leaves(self.segment_path(seg), requests,
                               staged=len(staged))
            for r, chunk, i in staged:
                results[i] = self._decode_leaf(
                    r, chunk[:r.nbytes], encoded, window,
                    out[i] if out is not None else None)
        finally:
            for _, chunk, _ in staged:
                reader.pool.put(chunk)
        return {r.name: results[i] for i, r in enumerate(leaves)}

    def write_segment(self, seg: int, named: Dict[str, np.ndarray],
                      sync: bool = True):
        """Encode (a subset of) one segment's leaves back through their
        codecs and flush.  Breaks any snapshot hardlink first
        (copy-on-write).

        ``sync=False`` skips the msync: bytes land in the page cache (fully
        visible to every later read) but durability is deferred — the async
        write-back path uses this so background writes are memcpy-cheap,
        then settles durability with one ``sync_segment`` per touched file
        at the flush/snapshot barrier."""
        self._check_no_views(seg, "write_segment")
        self._break_cow(seg)
        mm = np.memmap(self.segment_path(seg), dtype=np.uint8, mode="r+")
        try:
            for r, enc in self._encoded_leaves(seg, named):
                mm[r.offset:r.offset + r.nbytes] = enc
            if sync:
                mm.flush()
        finally:
            mm._mmap.close()       # no views escape this scope

    def _encoded_leaves(self, seg: int, named: Dict[str, np.ndarray]):
        """(record, encoded uint8 bytes) per leaf — the one encode loop
        both write paths share, so the sync (memmap) and async (pwrite)
        writers can never drift in what bytes they persist."""
        for name, value in named.items():
            r = self._by_name[name]
            assert r.segment == seg, (name, r.segment, seg)
            enc = get_codec(r.codec).encode(np.asarray(value), r.dtype)
            assert enc.nbytes == r.nbytes, (name, enc.nbytes, r.nbytes)
            yield r, enc

    def pwrite_segment(self, seg: int, named: Dict[str, np.ndarray],
                       sync: bool = False):
        """``write_segment`` via positional ``pwrite(2)`` on a plain fd —
        no memory map, and the kernel's copy into the page cache runs with
        the GIL *released*, so the async writer's background writes truly
        overlap main-thread work (a memmap slice-assign holds the GIL for
        the whole copy).  Identity-codec leaves encode as zero-copy views,
        making the background write almost pure syscall time.  Reads via
        mmap see these bytes immediately (one unified page cache)."""
        self._check_no_views(seg, "pwrite_segment")
        self._break_cow(seg)
        fd = os.open(self.segment_path(seg), os.O_WRONLY)
        try:
            # leaves are written in offset order — tell the kernel so it
            # can batch the page-cache write-back sequentially
            os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_SEQUENTIAL)
            for r, enc in self._encoded_leaves(seg, named):
                mv, off = memoryview(enc), r.offset
                while len(mv):                 # pwrite may write short
                    n = os.pwrite(fd, mv, off)
                    mv, off = mv[n:], off + n
            if sync:
                os.fsync(fd)
        finally:
            os.close(fd)

    def sync_segment(self, seg: int):
        """fsync one segment file — settles the durability a
        ``write_segment(..., sync=False)``/``pwrite_segment`` deferred.

        For write-once scratch stores (grad scratch, activation spill) the
        now-durable pages are also dropped from the page cache: nothing
        reads them again before they are overwritten, and leaving them
        resident evicts the streamed base's segments instead."""
        fd = os.open(self.segment_path(seg), os.O_RDONLY)
        try:
            os.fsync(fd)
            if self._scratch:
                os.posix_fadvise(fd, 0, 0, os.POSIX_FADV_DONTNEED)
        finally:
            os.close(fd)

    def _break_cow(self, seg: int):
        if not self._cow[seg]:
            return
        self._check_no_views(seg, "_break_cow")
        t0 = time.perf_counter()
        path = self.segment_path(seg)
        tmp = path + ".cow"
        _copy_file(path, tmp)   # fresh inode; snapshot keeps the old one
        os.replace(tmp, path)
        self._cow[seg] = False
        self.cow_breaks += 1
        self.cow_break_s += time.perf_counter() - t0

    def snapshot(self, dest_dir: str):
        """Zero-copy snapshot: hardlink every segment file + mapping table
        into ``dest_dir`` and flip this store to copy-on-write so later
        updates never mutate the snapshot."""
        os.makedirs(dest_dir, exist_ok=True)
        for seg in range(self.num_segments):
            _link_or_copy(self.segment_path(seg),
                          os.path.join(dest_dir, self._seg_name(seg)))
        shutil.copyfile(os.path.join(self.directory, self.TABLE),
                        os.path.join(dest_dir, self.TABLE))
        self._cow = [True] * self.num_segments
        return dest_dir


def _copy_file(src: str, dest: str):
    """File copy via ``os.copy_file_range`` — the kernel moves the bytes
    without round-tripping them through userspace, and reflink-capable
    filesystems (btrfs/xfs) satisfy it with a metadata-only clone — with
    a ``shutil.copyfile`` fallback where the syscall is unsupported
    (pre-4.5 kernels, some network/overlay filesystems)."""
    try:
        src_fd = os.open(src, os.O_RDONLY)
        try:
            dst_fd = os.open(dest, os.O_WRONLY | os.O_CREAT | os.O_TRUNC,
                             0o644)
            try:
                left = os.fstat(src_fd).st_size
                off = 0
                while left > 0:
                    n = os.copy_file_range(src_fd, dst_fd, left, off, off)
                    if n == 0:
                        raise OSError("copy_file_range returned 0")
                    off += n
                    left -= n
            finally:
                os.close(dst_fd)
        finally:
            os.close(src_fd)
    except (OSError, AttributeError):
        shutil.copyfile(src, dest)


def _link_or_copy(src: str, dest: str):
    if os.path.exists(dest):
        os.remove(dest)
    try:
        os.link(src, dest)
    except OSError:           # cross-device or FS without hardlinks
        shutil.copyfile(src, dest)
