"""Segment-wise parameter offload (paper §4.1.1, C1 — phone realization).

The TPU realization of C1 lives in ``repro/core/zero.py`` (GSPMD FSDP).
This package is the *single-host* realization the paper actually ships on
phones: the flattened param/optimizer pytree is partitioned into contiguous
segments backed by memory-mapped files; only a small LRU window of segments
is resident, a background double-buffered prefetcher loads segment ``i+1``
while segment ``i`` computes, and dirty (updated) segments are written back.

- codecs.py    SegmentCodec: per-leaf storage codecs (identity / bf16 / int8
               per-channel quantization) — all dtype conversion lives here
- segments.py  SegmentStore: mapping table + mmap segment files + COW snapshot
- engine.py    OffloadEngine: LRU residency window + prefetch + write-back
- act_store.py ActivationStore: per-step layer-boundary activation spill
               (forward sinks ride the AsyncWriter, the backward sweep
               re-pulls in reverse order through the Prefetcher)
- state.py     OffloadedTrainState: segment-by-segment AdamW update;
               LayerStreamedState: layer-aligned segments (one per block +
               head) for the streamed fwd/bwd driver (repro/core/stream.py)
"""
from repro.offload.act_store import ActivationStore  # noqa: F401
from repro.offload.codecs import (CODECS, QuantLeaf,  # noqa: F401
                                  SegmentCodec, activation_codec,
                                  dequant_tree, get_codec)
from repro.offload.segments import (LeafRecord, SegmentStore,  # noqa: F401
                                    plan_segments)
from repro.offload.engine import OffloadEngine, Prefetcher  # noqa: F401
from repro.offload.state import (LayerStreamedState,  # noqa: F401
                                 OffloadedTrainState)
