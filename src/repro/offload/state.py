"""Offloaded training state: segment-by-segment optimizer update (C1).

The (param, m, v) triple of every tensor is kept together in one segment, so
the AdamW update of a segment touches exactly one segment file.  The update
walks segments in order with the double-buffered prefetcher one segment
ahead: segment ``i+1`` pages in while segment ``i``'s update computes —
peak resident optimizer state is ``window / num_segments`` of the whole,
decoupled from model size.

Each segment's sub-pytree goes through the very same ``adamw_update`` with
the shared step count, so bias correction and weight decay match the
monolithic update; residual differences vs the fully-jitted in-memory step
are XLA fusion noise (~1e-7), well inside the smoke-equivalence tolerance.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore
from repro.optim.adamw import adamw_update
from repro.param import flatten_names

P, M, V = "p.", "m.", "v."


class OffloadedTrainState:
    """Full-FT state {params, opt, step} paged to segment files."""

    def __init__(self, store: SegmentStore, *, treedef, names: List[str],
                 max_resident: int = 2, prefetch: bool = True):
        self.store = store
        self.engine = OffloadEngine(store, max_resident=max_resident,
                                    prefetch=prefetch)
        self.treedef = treedef
        self.names = names
        self.count = int(store.meta.get("count", 0))
        self.step = int(store.meta.get("step", 0))
        self._upd = jax.jit(adamw_update)
        # param names per segment, in segment order
        self._seg_pnames: List[List[str]] = [
            [n[len(P):] for n in store.segment_names(s) if n.startswith(P)]
            for s in range(store.num_segments)]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, state: Dict[str, Any], directory: str, num_segments: int,
               *, max_resident: int = 2, prefetch: bool = True
               ) -> "OffloadedTrainState":
        """Page an in-memory ``init_state`` tree {params, opt, step} out to
        ``directory``.  Each group is one tensor's (p, m, v) triple so the
        planner never splits a triple across segments."""
        params = state["params"]
        named_p = flatten_names(params)
        named_m = dict(flatten_names(state["opt"]["m"]))
        named_v = dict(flatten_names(state["opt"]["v"]))
        host = jax.device_get
        groups = [[(P + n, host(leaf)), (M + n, host(named_m[n])),
                   (V + n, host(named_v[n]))] for n, leaf in named_p]
        meta = {"count": int(state["opt"]["count"]),
                "step": int(state["step"]), "kind": "offload_state_v1"}
        store = SegmentStore.create(directory, groups, num_segments,
                                    meta=meta)
        return cls(store, treedef=jax.tree.structure(params),
                   names=[n for n, _ in named_p],
                   max_resident=max_resident, prefetch=prefetch)

    @classmethod
    def open(cls, directory: str, like_params, *, max_resident: int = 2,
             prefetch: bool = True) -> "OffloadedTrainState":
        """Reattach to existing segment files; ``like_params`` supplies the
        pytree structure (values ignored)."""
        store = SegmentStore.open(directory)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, work_dir: str, like_params, *,
                        max_resident: int = 2, prefetch: bool = True
                        ) -> "OffloadedTrainState":
        """Zero-copy restore: hardlink the checkpoint's segment files into
        ``work_dir`` (copy-on-write), no byte of state staged through RAM."""
        store = SegmentStore.link_clone(ckpt_dir, work_dir)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch)

    # ------------------------------------------------------------------
    # use
    # ------------------------------------------------------------------
    def materialize_params(self):
        """Assemble the full in-memory param tree (needed by fwd/bwd; the
        optimizer state stays offloaded)."""
        named = {}
        self.engine.prefetch(0)
        for seg in range(self.store.num_segments):
            self.engine.prefetch(seg + 1)
            data = self.engine.acquire(seg)
            for n in self._seg_pnames[seg]:
                named[n] = jnp.asarray(data[P + n])
        return jax.tree.unflatten(self.treedef,
                                  [named[n] for n in self.names])

    def apply_update(self, grads, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                     weight_decay=0.01):
        """Segment-wise AdamW: stream (p, m, v) through the LRU window,
        update, mark dirty for write-back.  Returns the new in-memory param
        tree for the next forward pass."""
        gnamed = dict(flatten_names(grads))
        count = jnp.asarray(self.count, jnp.int32)
        new_named: Dict[str, Any] = {}
        eng = self.engine
        eng.prefetch(0)
        for seg in range(self.store.num_segments):
            eng.prefetch(seg + 1)          # double-buffered: i+1 loads now
            data = eng.acquire(seg)
            pnames = self._seg_pnames[seg]
            sub_p = {n: data[P + n] for n in pnames}
            sub_g = {n: gnamed[n] for n in pnames}
            opt = {"m": {n: data[M + n] for n in pnames},
                   "v": {n: data[V + n] for n in pnames}, "count": count}
            new_p, new_opt = self._upd(sub_g, opt, sub_p, lr=lr, beta1=beta1,
                                       beta2=beta2, eps=eps,
                                       weight_decay=weight_decay)
            for n in pnames:               # in-place: window owns the arrays
                data[P + n][...] = np.asarray(new_p[n])
                data[M + n][...] = np.asarray(new_opt["m"][n])
                data[V + n][...] = np.asarray(new_opt["v"][n])
                new_named[n] = new_p[n]
            eng.mark_dirty(seg)
        self.count += 1
        self.step += 1
        return jax.tree.unflatten(self.treedef,
                                  [new_named[n] for n in self.names])

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def flush(self):
        self.engine.flush()
        self.store.write_meta(count=self.count, step=self.step)

    def snapshot(self, dest_dir: str):
        """Zero-copy checkpoint of the whole state (see SegmentStore)."""
        self.flush()
        return self.store.snapshot(dest_dir)

    def close(self):
        self.flush()
        self.engine.close()

    @property
    def state_bytes(self) -> int:
        return self.store.total_bytes

    def stats(self):
        return self.engine.stats()


def offload_dir_for(out_dir: Optional[str], explicit: str = "") -> str:
    """Working directory for segment files: --offload-dir wins, else
    <out>/offload, else a fresh per-run temp dir (a shared default would
    let two concurrent runs truncate each other's live mmap files)."""
    if explicit:
        return explicit
    if out_dir:
        return os.path.join(out_dir, "offload")
    import tempfile
    return tempfile.mkdtemp(prefix="repro-offload-")
