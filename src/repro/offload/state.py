"""Offloaded training state: segment-by-segment optimizer update (C1).

The (param, m, v) triple of every tensor is kept together in one segment, so
the AdamW update of a segment touches exactly one segment file.  The update
walks segments in order with the double-buffered prefetcher one segment
ahead: segment ``i+1`` pages in while segment ``i``'s update computes —
peak resident optimizer state is ``window / num_segments`` of the whole,
decoupled from model size.

Each segment's sub-pytree goes through the very same ``adamw_update`` with
the shared step count, so bias correction and weight decay match the
monolithic update; residual differences vs the fully-jitted in-memory step
are XLA fusion noise (~1e-7), well inside the smoke-equivalence tolerance.

Two layouts share the machinery:

- ``OffloadedTrainState``  byte-balanced segments; fwd/bwd still runs on the
  full in-memory param tree, only the optimizer stream is windowed.
- ``LayerStreamedState``   layer-aligned segments (one per transformer block
  plus one head segment holding embed/ln_f/wpe/meta), so the layer-streamed
  fwd/bwd driver (repro/core/stream.py) can pull exactly one block's params
  through the window while computing — peak resident params no longer scale
  with model size.

Storage precision is the codec layer's job (repro/offload/codecs.py):
moments stored in bfloat16 (``moment_dtype="bfloat16"``) are ``bf16``-codec
leaves — the engine pulls each leaf's compact *window* form (bf16 moments
stay bf16-resident, preserving the halved window bytes) and the update
casts to fp32 at use and back on store, so AdamW math stays fp32 and
in-window precision equals on-flash precision.  A frozen base can be
``int8``-quantized per channel (``create_frozen(quant="int8")``): the
window then holds the *encoded* segments and dequantization happens inside
the jitted per-block program.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload.codecs import QuantLeaf, dequant_np, moment_codec
from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore
from repro.optim.adamw import adamw_update
from repro.param import flatten_names

P, M, V = "p.", "m.", "v."

LAYER_LAYOUT = "layer_v1"

BASE_QUANTS = ("", "int8")     # frozen-base quantization choices


def ensure_base_quant_match(lstate, base_quant: str):
    """One shared guard for CLI-flag vs segment-layout quantization: the
    jitted program is built for one base encoding, so feeding it segments
    of another must fail loudly up front, with the same message everywhere
    (TrainerRuntime.guard_segment_layout and StreamedTrainStep both call
    this)."""
    store_quant = getattr(lstate, "base_quant", "") or ""
    if store_quant != (base_quant or ""):
        raise ValueError(
            f"--base-quant {base_quant or 'fp32'} does not match the "
            f"existing segment layout in {lstate.store.directory} "
            f"(stored {store_quant or 'fp32'}); rerun with the original "
            "quantization, or point --offload-dir/--out at a fresh "
            "directory")


class OffloadedTrainState:
    """Full-FT state {params, opt, step} paged to segment files."""

    def __init__(self, store: SegmentStore, *, treedef, names: List[str],
                 max_resident: int = 2, prefetch: bool = True,
                 async_writeback: bool = True, io_backend: str = ""):
        self.store = store
        # frozen layout (PEFT base): p-segments only, no m/v, and the window
        # is read-only — the base is never updated, so nothing is ever
        # dirtied or written back
        self.frozen = bool(store.meta.get("frozen", False))
        # a window below 1 cannot hold the segment being computed on; clamp
        # like the grad engine does (repro/core/stream.py).  A quantized
        # frozen base keeps its window *encoded* (int8-resident): decode
        # happens inside the jitted per-block program, not on pull.
        self.base_quant = str(store.meta.get("base_quant", ""))
        self.engine = OffloadEngine(store, max_resident=max(1, max_resident),
                                    prefetch=prefetch,
                                    read_only=self.frozen,
                                    encoded=bool(self.base_quant),
                                    async_writeback=async_writeback,
                                    io_backend=io_backend)
        self.treedef = treedef
        self.names = names
        self.count = int(store.meta.get("count", 0))
        self.step = int(store.meta.get("step", 0))
        self._upd = jax.jit(adamw_update)
        # param names per segment, in segment order
        self._seg_pnames: List[List[str]] = [
            [n[len(P):] for n in store.segment_names(s) if n.startswith(P)]
            for s in range(store.num_segments)]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, state: Dict[str, Any], directory: str, num_segments: int,
               *, max_resident: int = 2, prefetch: bool = True,
               moment_dtype: str = "float32", async_writeback: bool = True,
               io_backend: str = "") -> "OffloadedTrainState":
        """Page an in-memory ``init_state`` tree {params, opt, step} out to
        ``directory``.  Each group is one tensor's (p, m, v) triple so the
        planner never splits a triple across segments."""
        params = state["params"]
        named_p = flatten_names(params)
        named_m = dict(flatten_names(state["opt"]["m"]))
        named_v = dict(flatten_names(state["opt"]["v"]))
        host = jax.device_get
        mcodec = moment_codec(moment_dtype)
        groups = [[(P + n, host(leaf)),
                   (M + n, host(named_m[n]), mcodec),
                   (V + n, host(named_v[n]), mcodec)]
                  for n, leaf in named_p]
        meta = {"count": int(state["opt"]["count"]),
                "step": int(state["step"]), "kind": "offload_state_v1",
                "moment_dtype": moment_dtype}
        store = SegmentStore.create(directory, groups, num_segments,
                                    meta=meta, io_backend=io_backend)
        return cls(store, treedef=jax.tree.structure(params),
                   names=[n for n, _ in named_p],
                   max_resident=max_resident, prefetch=prefetch,
                   async_writeback=async_writeback)

    @classmethod
    def open(cls, directory: str, like_params, *, max_resident: int = 2,
             prefetch: bool = True, async_writeback: bool = True,
             io_backend: str = "") -> "OffloadedTrainState":
        """Reattach to existing segment files; ``like_params`` supplies the
        pytree structure (values ignored)."""
        store = SegmentStore.open(directory, io_backend=io_backend)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch,
                   async_writeback=async_writeback)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, work_dir: str, like_params, *,
                        max_resident: int = 2, prefetch: bool = True,
                        async_writeback: bool = True, io_backend: str = ""
                        ) -> "OffloadedTrainState":
        """Zero-copy restore: hardlink the checkpoint's segment files into
        ``work_dir`` (copy-on-write), no byte of state staged through RAM."""
        store = SegmentStore.link_clone(ckpt_dir, work_dir,
                                        io_backend=io_backend)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch,
                   async_writeback=async_writeback)

    # ------------------------------------------------------------------
    # use
    # ------------------------------------------------------------------
    def seg_param_names(self, seg: int) -> List[str]:
        """Plain (un-prefixed) param leaf names held by one segment."""
        return list(self._seg_pnames[seg])

    def materialize_params(self):
        """Assemble the full in-memory param tree (needed by fwd/bwd; the
        optimizer state stays offloaded)."""
        named = {}
        self.engine.prefetch(0)
        for seg in range(self.store.num_segments):
            self.engine.prefetch(seg + 1)
            data = self.engine.acquire(seg)
            for n in self._seg_pnames[seg]:
                named[n] = jnp.asarray(data[P + n])
        return jax.tree.unflatten(self.treedef,
                                  [named[n] for n in self.names])

    def _update_segment_dispatch(self, seg: int, gnamed: Dict[str, Any],
                                 count, *, lr, beta1, beta2, eps,
                                 weight_decay):
        """First half of a (possibly pipelined) segment update: pull the
        segment and *dispatch* the jitted AdamW — JAX dispatch is
        asynchronous, so the caller can overlap the next segment's pull
        with this one's compute before forcing the store.  Returns the
        pending tuple ``_update_segment_store`` consumes.

        Pipelined callers must keep the store within one later acquire
        (window >= 2): the pending segment has to still be resident when
        its results land (``repro.core.stream._update_sweep``)."""
        if self.frozen:
            raise RuntimeError(
                "frozen (param-only) layout holds no optimizer state — the "
                "base is read-only; train the adapter instead")
        data = self.engine.acquire(seg)
        pnames = self._seg_pnames[seg]
        sub_p = {n: data[P + n] for n in pnames}
        sub_g = {n: gnamed[n] for n in pnames}
        opt = {"m": {n: np.asarray(data[M + n], np.float32) for n in pnames},
               "v": {n: np.asarray(data[V + n], np.float32) for n in pnames},
               "count": count}
        new_p, new_opt = self._upd(sub_g, opt, sub_p, lr=lr, beta1=beta1,
                                   beta2=beta2, eps=eps,
                                   weight_decay=weight_decay)
        return seg, data, pnames, new_p, new_opt

    def _update_segment_store(self, pending):
        """Second half: force the dispatched results and store them into
        the (still resident) window arrays, marking the segment dirty.
        Returns the new param arrays (name -> jnp)."""
        seg, data, pnames, new_p, new_opt = pending
        out = {}
        for n in pnames:               # in-place: window owns the arrays
            data[P + n][...] = np.asarray(new_p[n])
            data[M + n][...] = np.asarray(new_opt["m"][n]).astype(
                data[M + n].dtype, copy=False)
            data[V + n][...] = np.asarray(new_opt["v"][n]).astype(
                data[V + n].dtype, copy=False)
            out[n] = new_p[n]
        self.engine.mark_dirty(seg)
        return out

    def _update_segment(self, seg: int, gnamed: Dict[str, Any], count,
                        *, lr, beta1, beta2, eps, weight_decay):
        """AdamW one segment in place (window owns the arrays; marked dirty).
        ``gnamed`` maps this segment's plain param names to gradients.  The
        window holds each leaf's codec *window* form — storage precision,
        so bf16 moments stay half-sized while resident; the fp32 math
        round-trips here (cast on load, cast back on the in-place store),
        which also keeps in-window precision equal to on-flash precision.
        Returns the new param arrays (name -> jnp)."""
        return self._update_segment_store(self._update_segment_dispatch(
            seg, gnamed, count, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
            weight_decay=weight_decay))

    def apply_update(self, grads, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                     weight_decay=0.01):
        """Segment-wise AdamW: stream (p, m, v) through the LRU window,
        update, mark dirty for write-back.  Returns the new in-memory param
        tree for the next forward pass."""
        gnamed = dict(flatten_names(grads))
        count = jnp.asarray(self.count, jnp.int32)
        new_named: Dict[str, Any] = {}
        eng = self.engine
        eng.prefetch(0)
        for seg in range(self.store.num_segments):
            eng.prefetch(seg + 1)          # double-buffered: i+1 loads now
            new_named.update(self._update_segment(
                seg, gnamed, count, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                weight_decay=weight_decay))
        self.count += 1
        self.step += 1
        return jax.tree.unflatten(self.treedef,
                                  [new_named[n] for n in self.names])

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def flush(self):
        self.engine.flush()
        if not self.frozen:     # a frozen base carries no step counters
            self.store.write_meta(count=self.count, step=self.step)

    def snapshot(self, dest_dir: str):
        """Zero-copy checkpoint of the whole state (see SegmentStore)."""
        self.flush()
        return self.store.snapshot(dest_dir)

    def close(self):
        self.flush()
        self.engine.close()

    @property
    def moment_dtype(self) -> str:
        """Storage dtype of the m/v segments (fixed at create time; a
        reattach keeps whatever the mapping table records)."""
        return self.store.meta.get("moment_dtype", "float32")

    @property
    def state_bytes(self) -> int:
        return self.store.total_bytes

    def stats(self):
        return self.engine.stats()


class LayerStreamedState(OffloadedTrainState):
    """Layer-aligned offloaded state for the streamed fwd/bwd driver.

    Segment ``i`` (0..L-1) holds block ``i``'s full (p, m, v) triple under
    per-layer leaf names ``blocks.<i>.<leaf>``; segment ``L`` ("head") holds
    everything outside the block stack (embed, ln_f, wpe, meta, ...).  The
    streamed driver pulls one block segment through the LRU window per layer
    of compute and never materializes the stacked tree.

    ``create_frozen`` lays out the *param-only* variant for PEFT: the same
    layer-aligned geometry but p-segments without m/v (the frozen base needs
    no optimizer state), served through a read-only window — no dirty
    tracking, no write-back, no gradient scratch.  The (tiny) trainable
    adapter lives outside this store entirely (repro/core/stream.py).
    """

    def __init__(self, store: SegmentStore, *, like_params,
                 max_resident: int = 2, prefetch: bool = True,
                 async_writeback: bool = True, io_backend: str = ""):
        super().__init__(
            store, treedef=jax.tree.structure(like_params),
            names=[n for n, _ in flatten_names(like_params)],
            max_resident=max_resident, prefetch=prefetch,
            async_writeback=async_writeback, io_backend=io_backend)
        assert store.meta.get("layout") == LAYER_LAYOUT, store.meta
        self.n_layers = int(store.meta["n_layers"])
        blocks = like_params["blocks"]
        head = {k: v for k, v in like_params.items() if k != "blocks"}
        self.block_treedef = jax.tree.structure(blocks)
        self.block_names = [n for n, _ in flatten_names(blocks)]
        self.head_treedef = jax.tree.structure(head)
        self.head_names = [n for n, _ in flatten_names(head)]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @staticmethod
    def _per_layer_name(full_name: str, idx: Optional[int]) -> str:
        """Stacked leaf name -> per-layer leaf name (head leaves unchanged)."""
        if idx is None:
            return full_name
        return ("blocks.%d." % idx) + full_name[len("blocks."):]

    @staticmethod
    def _layer_groups(params, pack):
        """Shared layer-aligned grouping walk: splits the stacked tree into
        one group per block (leading ``layers`` dim sliced off) plus a
        trailing head group.  ``pack(full_name, idx) -> [(name, arr), ...]``
        emits one tensor's leaf records (p only, or the (p, m, v) triple).
        Returns (groups, labels, n_layers)."""
        named_p = flatten_names(params)
        block_names = [n for n, _ in named_p if n.startswith("blocks.")]
        head_names = [n for n, _ in named_p if not n.startswith("blocks.")]
        n_layers = (int(dict(named_p)[block_names[0]].shape[0])
                    if block_names else 0)
        groups, labels = [], []
        for i in range(n_layers):
            groups.append([t for n in block_names for t in pack(n, i)])
            labels.append(f"layer:{i}")
        groups.append([t for n in head_names for t in pack(n, None)])
        labels.append("head")
        return groups, labels, n_layers

    @classmethod
    def create(cls, state: Dict[str, Any], directory: str, *,
               max_resident: int = 2, prefetch: bool = True,
               moment_dtype: str = "float32", async_writeback: bool = True,
               io_backend: str = "") -> "LayerStreamedState":
        """Page a stacked ``init_state`` tree out layer-aligned: the stacked
        block leaves are split on their leading ``layers`` dim into one group
        per block, plus a trailing head group."""
        params = state["params"]
        host = jax.device_get
        named_p = {n: host(x) for n, x in flatten_names(params)}
        named_m = {n: host(x) for n, x in flatten_names(state["opt"]["m"])}
        named_v = {n: host(x) for n, x in flatten_names(state["opt"]["v"])}

        mcodec = moment_codec(moment_dtype)

        def triple(full_name, idx):
            p, m, v = (named_p[full_name], named_m[full_name],
                       named_v[full_name])
            if idx is not None:
                p, m, v = p[idx], m[idx], v[idx]
            name = cls._per_layer_name(full_name, idx)
            return [(P + name, np.asarray(p)),
                    (M + name, np.asarray(m), mcodec),
                    (V + name, np.asarray(v), mcodec)]

        groups, labels, n_layers = cls._layer_groups(params, triple)
        meta = {"count": int(state["opt"]["count"]),
                "step": int(state["step"]), "kind": "offload_state_v1",
                "layout": LAYER_LAYOUT, "n_layers": n_layers,
                "moment_dtype": moment_dtype}
        store = SegmentStore.create(directory, groups, len(groups),
                                    meta=meta, group_labels=labels,
                                    io_backend=io_backend)
        return cls(store, like_params=params, max_resident=max_resident,
                   prefetch=prefetch, async_writeback=async_writeback)

    @classmethod
    def create_frozen(cls, params, directory: str, *, max_resident: int = 2,
                      prefetch: bool = True, base_tag: str = "",
                      quant: str = "",
                      io_backend: str = "") -> "LayerStreamedState":
        """Page a frozen base out param-only (no m/v segments): one p-segment
        per block plus the head segment, read-only through fwd/bwd.  Resident
        bytes per segment drop to ~1/3 of the Full-FT layout.

        ``quant="int8"`` additionally quantizes every matrix leaf (ndim >= 2
        after the per-layer slice) per channel — QLoRA-style: norms/biases
        stay fp32, the weight matrices that dominate the bytes go int8, for
        ~4x less flash *and* ~4x smaller resident window (the window holds
        the encoded segments; the jitted per-block program dequantizes).

        ``base_tag`` identifies how the base was derived (arch + seed +
        dtype + quant); ``open_frozen_if_matching`` uses it to reuse an
        existing store on restart instead of rewriting every segment file."""
        if quant not in BASE_QUANTS:
            raise ValueError(f"unsupported base quantization {quant!r}; "
                             f"choose from {[q or 'fp32' for q in BASE_QUANTS]}")
        host = jax.device_get
        named_p = {n: host(x) for n, x in flatten_names(params)}

        def p_only(full_name, idx):
            p = np.asarray(named_p[full_name])
            if idx is not None:
                p = p[idx]
            codec = "int8" if (quant == "int8" and p.ndim >= 2) else "identity"
            return [(P + cls._per_layer_name(full_name, idx), p, codec)]

        groups, labels, n_layers = cls._layer_groups(params, p_only)
        meta = {"kind": "offload_state_v1", "layout": LAYER_LAYOUT,
                "n_layers": n_layers, "frozen": True, "base_tag": base_tag,
                "base_quant": quant}
        store = SegmentStore.create(directory, groups, len(groups),
                                    meta=meta, group_labels=labels,
                                    io_backend=io_backend)
        return cls(store, like_params=params, max_resident=max_resident,
                   prefetch=prefetch)

    @classmethod
    def open_frozen_if_matching(cls, directory: str, like_params, *,
                                base_tag: str, max_resident: int = 2,
                                prefetch: bool = True, io_backend: str = ""
                                ) -> Optional["LayerStreamedState"]:
        """Reattach to an existing frozen store iff it was created from the
        same base (``base_tag`` match) — the segments are read-only and
        seed-derived, so reuse skips re-paging the whole model to flash on
        every restart.  Returns None on any mismatch or unreadable store."""
        if not os.path.isfile(os.path.join(directory, SegmentStore.TABLE)):
            return None
        try:
            st = cls.open(directory, like_params,
                          max_resident=max_resident, prefetch=prefetch,
                          io_backend=io_backend)
        except Exception:       # corrupt/foreign table -> lay out fresh
            return None
        if (st.frozen and base_tag
                and st.store.meta.get("base_tag") == base_tag):
            return st
        st.close()
        return None

    @classmethod
    def open(cls, directory: str, like_params, *, max_resident: int = 2,
             prefetch: bool = True, async_writeback: bool = True,
             io_backend: str = "") -> "LayerStreamedState":
        return cls(SegmentStore.open(directory, io_backend=io_backend),
                   like_params=like_params,
                   max_resident=max_resident, prefetch=prefetch,
                   async_writeback=async_writeback)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, work_dir: str, like_params, *,
                        max_resident: int = 2, prefetch: bool = True,
                        async_writeback: bool = True, io_backend: str = ""
                        ) -> "LayerStreamedState":
        store = SegmentStore.link_clone(ckpt_dir, work_dir,
                                        io_backend=io_backend)
        return cls(store, like_params=like_params,
                   max_resident=max_resident, prefetch=prefetch,
                   async_writeback=async_writeback)

    # ------------------------------------------------------------------
    # layer access (the streamed driver's working set)
    # ------------------------------------------------------------------
    @property
    def head_segment(self) -> int:
        return self.n_layers

    def prefetch_layer(self, i: int):
        """Hint the double-buffered prefetcher (out-of-range is a no-op)."""
        self.engine.prefetch(i)

    def _tree_of(self, treedef, leaves):
        """Window leaves -> the pytree handed to the per-block program.

        Plain layout: one tree of jnp copies (safe across eviction).
        Quantized layout: the window holds encoded ``QuantLeaf``s — return a
        (codes_tree, scales_tree) pair so the jitted program receives int8
        codes and dequantizes internally (repro.offload.codecs.dequant_tree);
        fp32 copies of the base never exist outside the jit."""
        if not self.base_quant:
            return jax.tree.unflatten(treedef,
                                      [jnp.asarray(v) for v in leaves])
        return (jax.tree.unflatten(treedef,
                                   [jnp.asarray(v.codes) for v in leaves]),
                jax.tree.unflatten(treedef,
                                   [jnp.asarray(v.scales) for v in leaves]))

    def layer_params(self, i: int):
        """One block's param pytree (a (codes, scales) pair when the frozen
        base is quantized)."""
        data = self.engine.acquire(i)
        prefix = f"{P}blocks.{i}."
        return self._tree_of(self.block_treedef,
                             [data[prefix + n] for n in self.block_names])

    def head_params(self):
        """The embed/ln_f/wpe/meta tree (everything outside the stack)."""
        data = self.engine.acquire(self.head_segment)
        return self._tree_of(self.head_treedef,
                             [data[P + n] for n in self.head_names])

    def finish_step(self):
        """Advance the shared AdamW count after a full update sweep."""
        self.count += 1
        self.step += 1

    # ------------------------------------------------------------------
    # whole-tree views (checkpoint equivalence tests / eval)
    # ------------------------------------------------------------------
    @staticmethod
    def _decoded(v):
        """Window leaf -> decoded host array (dequantizes encoded leaves)."""
        return dequant_np(v) if isinstance(v, QuantLeaf) else v

    def materialize_params(self):
        """Re-stack the per-layer segments into the full stacked tree.  A
        quantized base materializes *dequantized* (export/merge path)."""
        per_layer: Dict[str, List[np.ndarray]] = {n: [] for n in
                                                  self.block_names}
        self.engine.prefetch(0)
        for seg in range(self.n_layers):
            self.engine.prefetch(seg + 1)
            data = self.engine.acquire(seg)
            prefix = f"{P}blocks.{seg}."
            for n in self.block_names:
                per_layer[n].append(np.array(self._decoded(data[prefix + n])))
        head = self.engine.acquire(self.head_segment)
        named = {"blocks." + n: jnp.asarray(np.stack(arrs))
                 for n, arrs in per_layer.items()}
        for n in self.head_names:
            named[n] = jnp.asarray(np.array(self._decoded(head[P + n])))
        return jax.tree.unflatten(self.treedef,
                                  [named[n] for n in self.names])

    def apply_update(self, grads, **kw):
        raise NotImplementedError(
            "LayerStreamedState is driven by repro.core.stream (per-segment "
            "updates straight off the backward sweep), not by a full "
            "in-memory gradient tree")


def offload_dir_for(out_dir: Optional[str], explicit: str = "") -> str:
    """Working directory for segment files: --offload-dir wins, else
    <out>/offload, else a fresh per-run temp dir (a shared default would
    let two concurrent runs truncate each other's live mmap files)."""
    if explicit:
        return explicit
    if out_dir:
        return os.path.join(out_dir, "offload")
    import tempfile
    return tempfile.mkdtemp(prefix="repro-offload-")
