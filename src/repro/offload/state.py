"""Offloaded training state: segment-by-segment optimizer update (C1).

The (param, m, v) triple of every tensor is kept together in one segment, so
the AdamW update of a segment touches exactly one segment file.  The update
walks segments in order with the double-buffered prefetcher one segment
ahead: segment ``i+1`` pages in while segment ``i``'s update computes —
peak resident optimizer state is ``window / num_segments`` of the whole,
decoupled from model size.

Each segment's sub-pytree goes through the very same ``adamw_update`` with
the shared step count, so bias correction and weight decay match the
monolithic update; residual differences vs the fully-jitted in-memory step
are XLA fusion noise (~1e-7), well inside the smoke-equivalence tolerance.

Two layouts share the machinery:

- ``OffloadedTrainState``  byte-balanced segments; fwd/bwd still runs on the
  full in-memory param tree, only the optimizer stream is windowed.
- ``LayerStreamedState``   layer-aligned segments (one per transformer block
  plus one head segment holding embed/ln_f/wpe/meta), so the layer-streamed
  fwd/bwd driver (repro/core/stream.py) can pull exactly one block's params
  through the window while computing — peak resident params no longer scale
  with model size.

Moments can be stored in bfloat16 (``moment_dtype="bfloat16"``): m/v segment
bytes halve; the update round-trips them through float32 (cast on load,
cast back on store) so AdamW math stays fp32.
"""
from __future__ import annotations

import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore, _np_dtype
from repro.optim.adamw import adamw_update
from repro.param import flatten_names

P, M, V = "p.", "m.", "v."

LAYER_LAYOUT = "layer_v1"


def _cast_moment(arr: np.ndarray, moment_dtype: str) -> np.ndarray:
    if moment_dtype in ("", "float32"):
        return arr
    return np.asarray(arr).astype(_np_dtype(moment_dtype))


class OffloadedTrainState:
    """Full-FT state {params, opt, step} paged to segment files."""

    def __init__(self, store: SegmentStore, *, treedef, names: List[str],
                 max_resident: int = 2, prefetch: bool = True):
        self.store = store
        # a window below 1 cannot hold the segment being computed on; clamp
        # like the grad engine does (repro/core/stream.py)
        self.engine = OffloadEngine(store, max_resident=max(1, max_resident),
                                    prefetch=prefetch)
        self.treedef = treedef
        self.names = names
        self.count = int(store.meta.get("count", 0))
        self.step = int(store.meta.get("step", 0))
        self._upd = jax.jit(adamw_update)
        # param names per segment, in segment order
        self._seg_pnames: List[List[str]] = [
            [n[len(P):] for n in store.segment_names(s) if n.startswith(P)]
            for s in range(store.num_segments)]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, state: Dict[str, Any], directory: str, num_segments: int,
               *, max_resident: int = 2, prefetch: bool = True,
               moment_dtype: str = "float32") -> "OffloadedTrainState":
        """Page an in-memory ``init_state`` tree {params, opt, step} out to
        ``directory``.  Each group is one tensor's (p, m, v) triple so the
        planner never splits a triple across segments."""
        params = state["params"]
        named_p = flatten_names(params)
        named_m = dict(flatten_names(state["opt"]["m"]))
        named_v = dict(flatten_names(state["opt"]["v"]))
        host = jax.device_get
        groups = [[(P + n, host(leaf)),
                   (M + n, _cast_moment(host(named_m[n]), moment_dtype)),
                   (V + n, _cast_moment(host(named_v[n]), moment_dtype))]
                  for n, leaf in named_p]
        meta = {"count": int(state["opt"]["count"]),
                "step": int(state["step"]), "kind": "offload_state_v1",
                "moment_dtype": moment_dtype}
        store = SegmentStore.create(directory, groups, num_segments,
                                    meta=meta)
        return cls(store, treedef=jax.tree.structure(params),
                   names=[n for n, _ in named_p],
                   max_resident=max_resident, prefetch=prefetch)

    @classmethod
    def open(cls, directory: str, like_params, *, max_resident: int = 2,
             prefetch: bool = True) -> "OffloadedTrainState":
        """Reattach to existing segment files; ``like_params`` supplies the
        pytree structure (values ignored)."""
        store = SegmentStore.open(directory)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, work_dir: str, like_params, *,
                        max_resident: int = 2, prefetch: bool = True
                        ) -> "OffloadedTrainState":
        """Zero-copy restore: hardlink the checkpoint's segment files into
        ``work_dir`` (copy-on-write), no byte of state staged through RAM."""
        store = SegmentStore.link_clone(ckpt_dir, work_dir)
        return cls(store, treedef=jax.tree.structure(like_params),
                   names=[n for n, _ in flatten_names(like_params)],
                   max_resident=max_resident, prefetch=prefetch)

    # ------------------------------------------------------------------
    # use
    # ------------------------------------------------------------------
    def seg_param_names(self, seg: int) -> List[str]:
        """Plain (un-prefixed) param leaf names held by one segment."""
        return list(self._seg_pnames[seg])

    def materialize_params(self):
        """Assemble the full in-memory param tree (needed by fwd/bwd; the
        optimizer state stays offloaded)."""
        named = {}
        self.engine.prefetch(0)
        for seg in range(self.store.num_segments):
            self.engine.prefetch(seg + 1)
            data = self.engine.acquire(seg)
            for n in self._seg_pnames[seg]:
                named[n] = jnp.asarray(data[P + n])
        return jax.tree.unflatten(self.treedef,
                                  [named[n] for n in self.names])

    def _update_segment(self, seg: int, gnamed: Dict[str, Any], count,
                        *, lr, beta1, beta2, eps, weight_decay):
        """AdamW one segment in place (window owns the arrays; marked dirty).
        ``gnamed`` maps this segment's plain param names to gradients.
        Moments stored in a reduced dtype round-trip through float32.
        Returns the new param arrays (name -> jnp)."""
        data = self.engine.acquire(seg)
        pnames = self._seg_pnames[seg]
        sub_p = {n: data[P + n] for n in pnames}
        sub_g = {n: gnamed[n] for n in pnames}
        opt = {"m": {n: np.asarray(data[M + n], np.float32) for n in pnames},
               "v": {n: np.asarray(data[V + n], np.float32) for n in pnames},
               "count": count}
        new_p, new_opt = self._upd(sub_g, opt, sub_p, lr=lr, beta1=beta1,
                                   beta2=beta2, eps=eps,
                                   weight_decay=weight_decay)
        out = {}
        for n in pnames:               # in-place: window owns the arrays
            data[P + n][...] = np.asarray(new_p[n])
            data[M + n][...] = np.asarray(new_opt["m"][n]).astype(
                data[M + n].dtype, copy=False)
            data[V + n][...] = np.asarray(new_opt["v"][n]).astype(
                data[V + n].dtype, copy=False)
            out[n] = new_p[n]
        self.engine.mark_dirty(seg)
        return out

    def apply_update(self, grads, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                     weight_decay=0.01):
        """Segment-wise AdamW: stream (p, m, v) through the LRU window,
        update, mark dirty for write-back.  Returns the new in-memory param
        tree for the next forward pass."""
        gnamed = dict(flatten_names(grads))
        count = jnp.asarray(self.count, jnp.int32)
        new_named: Dict[str, Any] = {}
        eng = self.engine
        eng.prefetch(0)
        for seg in range(self.store.num_segments):
            eng.prefetch(seg + 1)          # double-buffered: i+1 loads now
            new_named.update(self._update_segment(
                seg, gnamed, count, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
                weight_decay=weight_decay))
        self.count += 1
        self.step += 1
        return jax.tree.unflatten(self.treedef,
                                  [new_named[n] for n in self.names])

    # ------------------------------------------------------------------
    # persistence
    # ------------------------------------------------------------------
    def flush(self):
        self.engine.flush()
        self.store.write_meta(count=self.count, step=self.step)

    def snapshot(self, dest_dir: str):
        """Zero-copy checkpoint of the whole state (see SegmentStore)."""
        self.flush()
        return self.store.snapshot(dest_dir)

    def close(self):
        self.flush()
        self.engine.close()

    @property
    def moment_dtype(self) -> str:
        """Storage dtype of the m/v segments (fixed at create time; a
        reattach keeps whatever the mapping table records)."""
        return self.store.meta.get("moment_dtype", "float32")

    @property
    def state_bytes(self) -> int:
        return self.store.total_bytes

    def stats(self):
        return self.engine.stats()


class LayerStreamedState(OffloadedTrainState):
    """Layer-aligned offloaded state for the streamed fwd/bwd driver.

    Segment ``i`` (0..L-1) holds block ``i``'s full (p, m, v) triple under
    per-layer leaf names ``blocks.<i>.<leaf>``; segment ``L`` ("head") holds
    everything outside the block stack (embed, ln_f, wpe, meta, ...).  The
    streamed driver pulls one block segment through the LRU window per layer
    of compute and never materializes the stacked tree.
    """

    def __init__(self, store: SegmentStore, *, like_params,
                 max_resident: int = 2, prefetch: bool = True):
        super().__init__(
            store, treedef=jax.tree.structure(like_params),
            names=[n for n, _ in flatten_names(like_params)],
            max_resident=max_resident, prefetch=prefetch)
        assert store.meta.get("layout") == LAYER_LAYOUT, store.meta
        self.n_layers = int(store.meta["n_layers"])
        blocks = like_params["blocks"]
        head = {k: v for k, v in like_params.items() if k != "blocks"}
        self.block_treedef = jax.tree.structure(blocks)
        self.block_names = [n for n, _ in flatten_names(blocks)]
        self.head_treedef = jax.tree.structure(head)
        self.head_names = [n for n, _ in flatten_names(head)]

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def create(cls, state: Dict[str, Any], directory: str, *,
               max_resident: int = 2, prefetch: bool = True,
               moment_dtype: str = "float32") -> "LayerStreamedState":
        """Page a stacked ``init_state`` tree out layer-aligned: the stacked
        block leaves are split on their leading ``layers`` dim into one group
        per block, plus a trailing head group."""
        params = state["params"]
        named_p = flatten_names(params)
        named_m = dict(flatten_names(state["opt"]["m"]))
        named_v = dict(flatten_names(state["opt"]["v"]))
        host = jax.device_get
        block_items = [(n, host(leaf)) for n, leaf in named_p
                       if n.startswith("blocks.")]
        head_items = [(n, host(leaf)) for n, leaf in named_p
                      if not n.startswith("blocks.")]
        n_layers = int(block_items[0][1].shape[0]) if block_items else 0

        def triple(full_name, p_arr, idx=None):
            m = host(named_m[full_name])
            v = host(named_v[full_name])
            if idx is not None:
                m, v = m[idx], v[idx]
                full_name = ("blocks.%d." % idx) + full_name[len("blocks."):]
            return [(P + full_name, np.asarray(p_arr)),
                    (M + full_name, _cast_moment(np.asarray(m), moment_dtype)),
                    (V + full_name, _cast_moment(np.asarray(v), moment_dtype))]

        groups, labels = [], []
        for i in range(n_layers):
            g = []
            for n, leaf in block_items:
                g += triple(n, leaf[i], idx=i)
            groups.append(g)
            labels.append(f"layer:{i}")
        groups.append([t for n, leaf in head_items for t in triple(n, leaf)])
        labels.append("head")
        meta = {"count": int(state["opt"]["count"]),
                "step": int(state["step"]), "kind": "offload_state_v1",
                "layout": LAYER_LAYOUT, "n_layers": n_layers,
                "moment_dtype": moment_dtype}
        store = SegmentStore.create(directory, groups, len(groups),
                                    meta=meta, group_labels=labels)
        return cls(store, like_params=params, max_resident=max_resident,
                   prefetch=prefetch)

    @classmethod
    def open(cls, directory: str, like_params, *, max_resident: int = 2,
             prefetch: bool = True) -> "LayerStreamedState":
        return cls(SegmentStore.open(directory), like_params=like_params,
                   max_resident=max_resident, prefetch=prefetch)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, work_dir: str, like_params, *,
                        max_resident: int = 2, prefetch: bool = True
                        ) -> "LayerStreamedState":
        store = SegmentStore.link_clone(ckpt_dir, work_dir)
        return cls(store, like_params=like_params,
                   max_resident=max_resident, prefetch=prefetch)

    # ------------------------------------------------------------------
    # layer access (the streamed driver's working set)
    # ------------------------------------------------------------------
    @property
    def head_segment(self) -> int:
        return self.n_layers

    def prefetch_layer(self, i: int):
        """Hint the double-buffered prefetcher (out-of-range is a no-op)."""
        self.engine.prefetch(i)

    def layer_params(self, i: int):
        """One block's param pytree (jnp copies; safe across eviction)."""
        data = self.engine.acquire(i)
        prefix = f"{P}blocks.{i}."
        return jax.tree.unflatten(
            self.block_treedef,
            [jnp.asarray(data[prefix + n]) for n in self.block_names])

    def head_params(self):
        """The embed/ln_f/wpe/meta tree (everything outside the stack)."""
        data = self.engine.acquire(self.head_segment)
        return jax.tree.unflatten(
            self.head_treedef,
            [jnp.asarray(data[P + n]) for n in self.head_names])

    def finish_step(self):
        """Advance the shared AdamW count after a full update sweep."""
        self.count += 1
        self.step += 1

    # ------------------------------------------------------------------
    # whole-tree views (checkpoint equivalence tests / eval)
    # ------------------------------------------------------------------
    def materialize_params(self):
        """Re-stack the per-layer segments into the full stacked tree."""
        per_layer: Dict[str, List[np.ndarray]] = {n: [] for n in
                                                  self.block_names}
        self.engine.prefetch(0)
        for seg in range(self.n_layers):
            self.engine.prefetch(seg + 1)
            data = self.engine.acquire(seg)
            prefix = f"{P}blocks.{seg}."
            for n in self.block_names:
                per_layer[n].append(np.array(data[prefix + n]))
        head = self.engine.acquire(self.head_segment)
        named = {"blocks." + n: jnp.asarray(np.stack(arrs))
                 for n, arrs in per_layer.items()}
        for n in self.head_names:
            named[n] = jnp.asarray(np.array(head[P + n]))
        return jax.tree.unflatten(self.treedef,
                                  [named[n] for n in self.names])

    def apply_update(self, grads, **kw):
        raise NotImplementedError(
            "LayerStreamedState is driven by repro.core.stream (per-segment "
            "updates straight off the backward sweep), not by a full "
            "in-memory gradient tree")


def offload_dir_for(out_dir: Optional[str], explicit: str = "") -> str:
    """Working directory for segment files: --offload-dir wins, else
    <out>/offload, else a fresh per-run temp dir (a shared default would
    let two concurrent runs truncate each other's live mmap files)."""
    if explicit:
        return explicit
    if out_dir:
        return os.path.join(out_dir, "offload")
    import tempfile
    return tempfile.mkdtemp(prefix="repro-offload-")
