"""Raw-speed segment readers: pluggable I/O backends for ``SegmentStore``.

The streamed trainer's read path historically went through page-cache
``np.memmap`` only.  That is the right *oracle* (simple, zero-copy, one
unified cache with the pwrite write-back path) but on slow flash it is not
the fastest way to move segment bytes: every pull double-buffers through
the page cache, cold reads fault one page at a time, and a multi-leaf
segment costs one fault train per leaf.  This module provides the raw
backends ``repro.offload.segments.SegmentStore`` can route
``read_segment`` through instead:

  mmap     the default and the numerics oracle — not in this module; the
           store keeps its original memmap path verbatim
  pread    positional ``os.preadv`` on a plain fd: flat-storage leaves are
           read *straight into* their destination window buffers (same
           copy count as mmap, no page-cache double buffering of the
           user-side buffer, no fault trains), converting leaves stage
           through a small pooled chunk
  direct   ``O_DIRECT`` whole-segment reads into 4096-aligned pooled
           staging buffers (the page cache is bypassed entirely — the
           honest cold-flash path), falling back to buffered pread when
           the open or the alignment contract fails
  uring    batched io_uring submission via ctypes on
           ``io_uring_setup``/``io_uring_enter``: one multi-leaf segment
           pull is one SQE batch + one syscall instead of N sequential
           preads.  Kernel-probe gated; falls back to ``pread``.

Backend selection (``resolve_io_backend``): an explicit name wins, else
the ``REPRO_OFFLOAD_IO`` environment variable, else ``mmap``.  ``auto``
probes ``uring -> direct -> pread`` and picks the first that works
(``repro.launch.env`` exports this under the tuned profile).  ``direct``
and ``uring`` degrade to ``pread`` with a logged one-line fallback when
the kernel / filesystem refuses — requested vs actual backend are both
recorded, so CI can log an explicit skip line instead of silently testing
the wrong thing.

Alignment contract: destination buffers allocated by the raw read path
come from :func:`aligned_empty` (4096-byte base pointers), so a recycled
window buffer handed back through the prefetcher's pool stays a valid
O_DIRECT/readinto target no matter which backend picks it up next.
Pooled staging chunks live in a bounded, lock-guarded
:class:`AlignedBufferPool` per reader.

Thread ownership (see CONCURRENCY.md): a reader is owned by its
``SegmentStore`` and must be callable from any thread that may call
``read_segment`` — the Prefetcher's reader thread and the consumer's
sync-load fallback run concurrently on *different* segments.  Readers are
therefore stateless per call (fd per call) except the buffer pool and the
uring submission ring, which are internally locked.
"""
from __future__ import annotations

import ctypes
import mmap
import os
import struct
import sys
import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# one alignment for everything: O_DIRECT needs the storage logical block
# size (512 or 4096); 4096 satisfies both and matches the page size, so an
# aligned buffer is also a well-formed readinto/DMA target
ALIGN = 4096

IO_BACKENDS = ("mmap", "pread", "direct", "uring")
ENV_VAR = "REPRO_OFFLOAD_IO"


def aligned_empty(shape, dtype, align: int = ALIGN) -> np.ndarray:
    """``np.empty`` whose base pointer is ``align``-byte aligned (numpy
    only guarantees 16/64) — the alignment-aware allocation path: buffers
    born here stay O_DIRECT-compatible through the prefetcher's recycle
    pool."""
    dtype = np.dtype(dtype)
    nbytes = int(np.prod(shape, dtype=np.int64)) * dtype.itemsize
    raw = np.empty(nbytes + align, np.uint8)
    start = (-raw.ctypes.data) % align
    return raw[start:start + nbytes].view(dtype).reshape(shape)


def is_aligned(arr: np.ndarray, align: int = ALIGN) -> bool:
    return arr.ctypes.data % align == 0


def _byte_view(arr: np.ndarray) -> np.ndarray:
    """Writable flat uint8 view of a C-contiguous array (any dtype —
    including ml_dtypes.bfloat16, whose buffer-protocol format numpy
    cannot always export directly)."""
    return arr.reshape(-1).view(np.uint8)


class AlignedBufferPool:
    """Bounded, size-classed pool of 4096-aligned uint8 staging buffers.

    ``get`` rounds the request up to the next multiple of ``align`` (the
    capacity class) and reuses a free buffer of at least that capacity;
    ``put`` returns it.  The pool is globally bounded in *buffers* so a
    pathological mix of sizes cannot accumulate unbounded staging memory;
    ``pool_bytes`` (free + lent) feeds the engine's honest peak-residency
    accounting."""

    def __init__(self, max_buffers: int = 4, align: int = ALIGN):
        self._align = align
        self._max = max(1, max_buffers)
        self._lock = threading.Lock()
        self._free: List[np.ndarray] = []       # guarded-by: _lock
        self._lent_bytes = 0                    # guarded-by: _lock
        self.reuses = 0                         # guarded-by: _lock
        self.allocs = 0                         # guarded-by: _lock

    def get(self, nbytes: int) -> np.ndarray:
        cap = -(-max(1, int(nbytes)) // self._align) * self._align
        with self._lock:
            for i, b in enumerate(self._free):
                if b.nbytes >= cap:
                    buf = self._free.pop(i)
                    self._lent_bytes += buf.nbytes
                    self.reuses += 1
                    return buf
            self.allocs += 1
            self._lent_bytes += cap
        return aligned_empty((cap,), np.uint8, self._align)

    def put(self, buf: np.ndarray) -> None:
        with self._lock:
            self._lent_bytes = max(0, self._lent_bytes - buf.nbytes)
            if len(self._free) < self._max:
                self._free.append(buf)
            # else: drop — the bound wins over reuse

    def pool_bytes(self) -> int:
        with self._lock:
            return int(sum(b.nbytes for b in self._free) + self._lent_bytes)


# ---------------------------------------------------------------------------
# probes (cached: one functional round-trip per process / per directory)
# ---------------------------------------------------------------------------
_probe_lock = threading.Lock()
_direct_cache: Dict[str, bool] = {}      # guarded-by: _probe_lock
_uring_cache: Optional[bool] = None      # guarded-by: _probe_lock


def direct_supported(directory: str) -> bool:
    """True when ``O_DIRECT`` opens *and reads* work for files in
    ``directory`` (per-filesystem: tmpfs and some overlayfs refuse).  One
    aligned-read round trip against a scratch file, cached per realpath."""
    if not hasattr(os, "O_DIRECT"):
        return False
    key = os.path.realpath(directory or ".")
    with _probe_lock:
        if key in _direct_cache:
            return _direct_cache[key]
    ok = False
    probe = os.path.join(directory or ".", f".io_probe_{os.getpid()}")
    try:
        payload = bytes(range(256)) * (ALIGN // 256)
        with open(probe, "wb") as f:
            f.write(payload)
        fd = os.open(probe, os.O_RDONLY | os.O_DIRECT)
        try:
            buf = aligned_empty((ALIGN,), np.uint8)
            ok = (os.preadv(fd, [buf], 0) == ALIGN
                  and bytes(buf) == payload)
        finally:
            os.close(fd)
    except OSError:
        ok = False
    finally:
        try:
            os.unlink(probe)
        except OSError:
            pass
    with _probe_lock:
        _direct_cache[key] = ok
    return ok


def uring_supported() -> bool:
    """True when ``io_uring_setup`` works (seccomp/kernel gated) and a
    small batched read round-trips.  Cached per process."""
    global _uring_cache
    with _probe_lock:
        if _uring_cache is not None:
            return _uring_cache
    ok = False
    try:
        ring = _Uring(entries=4)
        try:
            import tempfile
            payload = os.urandom(8192)
            with tempfile.NamedTemporaryFile(delete=False) as f:
                f.write(payload)
                probe = f.name
            try:
                dst = np.empty(8192, np.uint8)
                fd = os.open(probe, os.O_RDONLY)
                try:
                    ring.read_batch(fd, [(0, dst[:4096]), (4096, dst[4096:])])
                finally:
                    os.close(fd)
                ok = bytes(dst) == payload
            finally:
                os.unlink(probe)
        finally:
            ring.close()
    except (OSError, RuntimeError):
        ok = False
    with _probe_lock:
        _uring_cache = ok
    return ok


def backend_available(name: str, directory: str = ".") -> bool:
    """Probe-level availability of one backend name (CI matrix gating)."""
    if name in ("mmap", "pread"):
        return True
    if name == "direct":
        return direct_supported(directory)
    if name == "uring":
        return uring_supported()
    return False


_warned: set = set()


def _warn_fallback(requested: str, actual: str, why: str) -> None:
    key = (requested, actual)
    if key in _warned:
        return
    _warned.add(key)
    sys.stderr.write(f"[io] requested --offload-io {requested}, using "
                     f"{actual} ({why})\n")


def resolve_io_backend(requested: str, directory: str) -> Tuple[str, str]:
    """-> ``(requested, actual)`` backend names.

    Resolution: explicit ``requested`` wins, else ``$REPRO_OFFLOAD_IO``,
    else ``mmap``.  ``auto`` probes uring -> direct -> pread.  ``direct``
    and ``uring`` degrade to ``pread`` (with a one-line stderr note) when
    their probe fails — a requested raw backend never silently becomes a
    crash on an unsupporting kernel/filesystem."""
    req = (requested or os.environ.get(ENV_VAR, "") or "mmap").strip().lower()
    if req == "auto":
        for name in ("uring", "direct", "pread"):
            if backend_available(name, directory):
                return "auto", name
        return "auto", "mmap"
    if req not in IO_BACKENDS:
        raise ValueError(
            f"unknown offload I/O backend {req!r}; choose from "
            f"{IO_BACKENDS + ('auto',)} (--offload-io / ${ENV_VAR})")
    if req == "direct" and not direct_supported(directory):
        _warn_fallback(req, "pread", "O_DIRECT unsupported on this "
                       "filesystem — probe read failed")
        return req, "pread"
    if req == "uring" and not uring_supported():
        _warn_fallback(req, "pread", "io_uring unavailable — "
                       "io_uring_setup probe failed")
        return req, "pread"
    return req, req


def make_reader(actual: str, directory: str) -> Optional["SegmentReader"]:
    """Reader instance for a *resolved* backend name (None for mmap)."""
    if actual == "mmap":
        return None
    if actual == "pread":
        return PreadReader()
    if actual == "direct":
        return DirectReader()
    if actual == "uring":
        return UringReader()
    raise ValueError(f"unknown resolved backend {actual!r}")


# ---------------------------------------------------------------------------
# readers
# ---------------------------------------------------------------------------
class SegmentReader:
    """Base raw reader: positional buffered preads on a plain fd.

    ``whole_segment`` readers (O_DIRECT) can only serve staged
    whole-segment pulls; the others accept per-leaf request batches and
    read flat leaves straight into their destination arrays."""

    name = "pread"
    whole_segment = False

    def __init__(self, pool_buffers: int = 4):
        self.pool = AlignedBufferPool(max_buffers=pool_buffers)
        self._lock = threading.Lock()
        self.batched_reads = 0     # guarded-by: _lock
        self.staged_reads = 0      # guarded-by: _lock
        self.bytes_read = 0        # guarded-by: _lock
        self.fallbacks = 0         # guarded-by: _lock

    # -- accounting ----------------------------------------------------
    def _note(self, nbytes: int, batches: int = 1, staged: int = 0,
              fallback: int = 0) -> None:
        with self._lock:
            self.batched_reads += batches
            self.staged_reads += staged
            self.bytes_read += nbytes
            self.fallbacks += fallback

    def stats(self) -> Dict[str, float]:
        with self._lock:
            s = {"io_batched_reads": self.batched_reads,
                 "io_staged_reads": self.staged_reads,
                 "io_bytes_read": self.bytes_read,
                 "io_fallbacks": self.fallbacks}
        s["io_pool_bytes"] = self.pool.pool_bytes()
        s["io_pool_reuses"] = self.pool.reuses
        return s

    def pool_bytes(self) -> int:
        return self.pool.pool_bytes()

    def close(self) -> None:
        pass

    # -- I/O -----------------------------------------------------------
    @staticmethod
    def _pread_into(fd: int, offset: int, dst: np.ndarray) -> None:
        """Full positional read into ``dst`` (uint8 view), looping on
        short reads.  A read past EOF (sparse scratch tails) zero-fills —
        matching what the mmap path reads from a hole."""
        mv, off = dst, int(offset)
        while mv.nbytes:
            n = os.preadv(fd, [mv], off)
            if n == 0:                        # EOF: mmap would read zeros
                mv[:] = 0
                return
            mv, off = mv[n:], off + n

    def read_batch(self, fd: int, requests: Sequence[Tuple[int, np.ndarray]]
                   ) -> None:
        """Read every ``(file_offset, destination array)`` request.  The
        base implementation is a pread loop; uring overrides this with one
        SQE batch per call."""
        for off, dst in requests:
            self._pread_into(fd, off, _byte_view(dst))

    def read_leaves(self, path: str,
                    requests: Sequence[Tuple[int, np.ndarray]],
                    staged: int = 0) -> None:
        """One multi-leaf segment pull: open, batch-read, close."""
        if not requests:
            return
        fd = os.open(path, os.O_RDONLY)
        try:
            self.read_batch(fd, requests)
        finally:
            os.close(fd)
        self._note(sum(d.nbytes for _, d in requests), staged=staged)

    def read_segment_bytes(self, path: str, nbytes: int
                           ) -> Tuple[np.ndarray, "callable"]:
        """Whole-segment staged read: ``(uint8 buffer >= nbytes, release)``.
        Only the first ``nbytes`` are meaningful; call ``release()`` once
        every leaf has been decoded out of the buffer."""
        buf = self.pool.get(nbytes)
        fd = os.open(path, os.O_RDONLY)
        try:
            self._pread_into(fd, 0, buf[:nbytes])
        finally:
            os.close(fd)
        self._note(nbytes, staged=1)
        return buf, lambda: self.pool.put(buf)


class PreadReader(SegmentReader):
    name = "pread"


class DirectReader(SegmentReader):
    """O_DIRECT whole-segment reads — the page cache is bypassed, so every
    pull measures (and pays) flash, not RAM.  Per-leaf offsets inside a
    segment are not block-aligned, so this backend always stages the whole
    segment into an aligned pooled buffer and lets the codec loop copy
    out; when O_DIRECT itself is refused at open/read time the pull falls
    back to buffered pread (counted in ``io_fallbacks``)."""

    name = "direct"
    whole_segment = True
    _CHUNK = 8 << 20         # per-preadv span; multiple of ALIGN

    def read_segment_bytes(self, path, nbytes):
        buf = self.pool.get(nbytes)            # capacity is ALIGN-rounded
        assert is_aligned(buf), "pool handed back a misaligned buffer"
        cap = -(-int(nbytes) // ALIGN) * ALIGN
        try:
            fd = os.open(path, os.O_RDONLY | os.O_DIRECT)
        except OSError:
            self._note(0, batches=0, fallback=1)
            return super().read_segment_bytes(path, nbytes)
        try:
            off = 0
            while off < nbytes:
                want = min(self._CHUNK, cap - off)
                try:
                    n = os.preadv(fd, [buf[off:off + want]], off)
                except OSError:
                    # alignment/fs refusal mid-stream: finish buffered
                    self._note(0, batches=0, fallback=1)
                    os.close(fd)
                    fd = os.open(path, os.O_RDONLY)
                    self._pread_into(fd, off, buf[off:nbytes])
                    break
                if n == 0:                     # EOF hole: zeros, like mmap
                    buf[off:nbytes] = 0
                    break
                off += n
        finally:
            os.close(fd)
        self._note(nbytes, staged=1)
        return buf, lambda: self.pool.put(buf)


# ---------------------------------------------------------------------------
# io_uring (ctypes, no external deps)
# ---------------------------------------------------------------------------
_SYS_IO_URING_SETUP = 425
_SYS_IO_URING_ENTER = 426
_IORING_OFF_SQ_RING = 0
_IORING_OFF_CQ_RING = 0x8000000
_IORING_OFF_SQES = 0x10000000
_IORING_OP_READ = 22            # plain buffer read, kernel >= 5.6
_IORING_ENTER_GETEVENTS = 1

_libc = ctypes.CDLL(None, use_errno=True)
_libc.syscall.restype = ctypes.c_long


class _Uring:
    """Minimal single-ring io_uring wrapper: setup, one mmap per ring
    area, batched ``IORING_OP_READ`` submission.  NOT thread-safe — the
    owning reader serializes access with its own lock (the syscall in
    ``read_batch`` doubles as the memory barrier between the userspace
    ring writes and the kernel's reads, per the io_uring contract)."""

    def __init__(self, entries: int = 64):
        params = ctypes.create_string_buffer(120)
        fd = _libc.syscall(ctypes.c_long(_SYS_IO_URING_SETUP),
                           ctypes.c_uint(entries), params)
        if fd < 0:
            raise OSError(ctypes.get_errno() or 1, "io_uring_setup failed")
        self.fd = int(fd)
        raw = params.raw
        self.sq_entries, self.cq_entries = struct.unpack_from("<II", raw, 0)
        (self.sq_head_off, self.sq_tail_off, self.sq_mask_off, _,
         _, _, self.sq_array_off) = struct.unpack_from("<7I", raw, 40)
        (self.cq_head_off, self.cq_tail_off, self.cq_mask_off, _,
         _, self.cq_cqes_off) = struct.unpack_from("<6I", raw, 80)
        try:
            sq_size = self.sq_array_off + self.sq_entries * 4
            cq_size = self.cq_cqes_off + self.cq_entries * 16
            self._sq = mmap.mmap(self.fd, sq_size,
                                 offset=_IORING_OFF_SQ_RING)
            self._cq = mmap.mmap(self.fd, cq_size,
                                 offset=_IORING_OFF_CQ_RING)
            self._sqes = mmap.mmap(self.fd, self.sq_entries * 64,
                                   offset=_IORING_OFF_SQES)
        except OSError:
            os.close(self.fd)
            raise
        self.sq_mask = struct.unpack_from("<I", self._sq,
                                          self.sq_mask_off)[0]
        self.cq_mask = struct.unpack_from("<I", self._cq,
                                          self.cq_mask_off)[0]

    def _enter(self, to_submit: int, min_complete: int) -> int:
        ret = _libc.syscall(ctypes.c_long(_SYS_IO_URING_ENTER),
                            ctypes.c_uint(self.fd),
                            ctypes.c_uint(to_submit),
                            ctypes.c_uint(min_complete),
                            ctypes.c_uint(_IORING_ENTER_GETEVENTS),
                            ctypes.c_void_p(0), ctypes.c_size_t(0))
        if ret < 0:
            raise OSError(ctypes.get_errno() or 1, "io_uring_enter failed")
        return int(ret)

    def read_batch(self, fd: int, requests: Sequence[Tuple[int, np.ndarray]]
                   ) -> None:
        """Submit every ``(file_offset, destination array)`` as one SQE
        batch (chunked by ring size) and reap completions.  Short reads
        (EOF holes in sparse scratch files) zero-fill the tail like the
        mmap oracle; failed SQEs raise the underlying OSError."""
        reqs = [(off, _byte_view(dst)) for off, dst in requests
                if dst.nbytes]
        start = 0
        while start < len(reqs):
            group = reqs[start:start + self.sq_entries]
            start += len(group)
            tail = struct.unpack_from("<I", self._sq, self.sq_tail_off)[0]
            for k, (off, dst) in enumerate(group):
                idx = (tail + k) & self.sq_mask
                base = idx * 64
                self._sqes[base:base + 64] = b"\x00" * 64
                struct.pack_into(
                    "<BBHiQQIIQ", self._sqes, base,
                    _IORING_OP_READ, 0, 0, fd, int(off),
                    dst.ctypes.data, dst.nbytes, 0, k)
                struct.pack_into("<I", self._sq,
                                 self.sq_array_off + idx * 4, idx)
            struct.pack_into("<I", self._sq, self.sq_tail_off,
                             (tail + len(group)) & 0xFFFFFFFF)
            self._enter(len(group), len(group))
            head = struct.unpack_from("<I", self._cq, self.cq_head_off)[0]
            cq_tail = struct.unpack_from("<I", self._cq,
                                         self.cq_tail_off)[0]
            while head != cq_tail:
                idx = head & self.cq_mask
                user_data, res, _flags = struct.unpack_from(
                    "<QiI", self._cq, self.cq_cqes_off + idx * 16)
                off, dst = group[int(user_data)]
                if res < 0:
                    struct.pack_into("<I", self._cq, self.cq_head_off,
                                     cq_tail)
                    raise OSError(-res, f"io_uring read at offset {off} "
                                        f"failed")
                if res < dst.nbytes:
                    # short read: finish synchronously (EOF zero-fills)
                    SegmentReader._pread_into(fd, off + res, dst[res:])
                head = (head + 1) & 0xFFFFFFFF
            struct.pack_into("<I", self._cq, self.cq_head_off, cq_tail)

    def close(self) -> None:
        for m in ("_sqes", "_cq", "_sq"):
            mm = getattr(self, m, None)
            if mm is not None:
                mm.close()
                setattr(self, m, None)
        if self.fd >= 0:
            os.close(self.fd)
            self.fd = -1


class UringReader(SegmentReader):
    """Batched io_uring reads: one multi-leaf segment pull is one SQE
    batch + one ``io_uring_enter`` (GIL released for the syscall), so the
    kernel can service the per-leaf reads at queue depth > 1 instead of
    serially.  The ring is shared per reader and lock-guarded —
    concurrent pulls (prefetcher thread vs a consumer's sync fallback on
    another segment) serialize on submission, which is still one syscall
    each."""

    name = "uring"

    def __init__(self, entries: int = 64, pool_buffers: int = 4):
        super().__init__(pool_buffers=pool_buffers)
        self._ring: Optional[_Uring] = _Uring(entries)  # guarded-by: _ring_lock
        self._ring_lock = threading.Lock()

    def read_batch(self, fd, requests):
        with self._ring_lock:
            ring = self._ring
            if ring is not None:
                try:
                    ring.read_batch(fd, requests)
                    return
                except OSError as e:
                    # ring-level refusal (e.g. an op gated off): fall back
                    # to pread for this and every later pull
                    if e.errno not in (1, 13, 22, 38, 95):  # PERM/ACCES/
                        raise            # INVAL/NOSYS/OPNOTSUPP degrade;
                    #                      real I/O errors surface
                    self._ring = None
                    ring.close()
        self._note(0, batches=0, fallback=1)
        super().read_batch(fd, requests)

    def close(self):
        with self._ring_lock:
            if self._ring is not None:
                self._ring.close()
                self._ring = None
