"""Residency engine over a SegmentStore (paper §4.1.1).

``OffloadEngine`` keeps at most ``max_resident`` segments in RAM in an LRU
window.  A background ``Prefetcher`` thread double-buffers reads: while
segment ``i`` is being consumed by the optimizer, segment ``i+1`` streams in
from its mmap file, hiding the page-in latency behind compute.  Evicted
segments that were marked dirty are written back to their segment files
before leaving the window.

The engine tracks the statistics the mem-chain benchmark reports:
window hits/misses, prefetch hit rate, bytes read/written, and the peak
resident segment bytes (the number the paper's C1 drives down).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Dict, Optional

import numpy as np

from repro.offload.segments import SegmentStore


class Prefetcher:
    """Background double-buffered segment loader.

    ``schedule(i)`` queues segment ``i``; a daemon thread loads it into a
    bounded buffer (``depth`` slots — 2 = classic double buffering).
    ``take(i)`` hands the buffered copy over (or loads synchronously on a
    miss).  The buffer is consume-once: ownership moves to the caller.
    """

    def __init__(self, store: SegmentStore, depth: int = 2,
                 encoded: bool = False):
        self._store = store
        self._depth = max(1, depth)
        self._encoded = encoded
        # window-form reads: leaves stay at their codec's resident
        # representation (bf16 moments bf16, int8 QuantLeafs when encoded)
        self._read = (
            (lambda seg: store.read_segment(seg, copy=True, encoded=True))
            if encoded else
            (lambda seg: store.read_segment(seg, copy=True, window=True)))
        self._lock = threading.Condition()
        self._queue: list = []
        self._buffers: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._inflight: set = set()
        self._closed = False
        self.prefetch_hits = 0
        self.sync_loads = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        while True:
            with self._lock:
                while not self._queue and not self._closed:
                    self._lock.wait()
                if self._closed:
                    return
                seg = self._queue.pop(0)
                if seg in self._buffers or seg in self._inflight:
                    continue
                self._inflight.add(seg)
            try:
                data = self._read(seg)
            except Exception:
                # never strand the id in _inflight (take() would block
                # forever); the consumer's sync fallback re-raises the
                # real I/O error on the main thread
                with self._lock:
                    self._inflight.discard(seg)
                    self._lock.notify_all()
                continue
            with self._lock:
                self._inflight.discard(seg)
                self._buffers[seg] = data
                while len(self._buffers) > self._depth:
                    self._buffers.popitem(last=False)  # drop oldest
                self._lock.notify_all()

    def schedule(self, seg: int):
        if seg < 0 or seg >= self._store.num_segments:
            return
        with self._lock:
            if (seg not in self._buffers and seg not in self._inflight
                    and seg not in self._queue):
                self._queue.append(seg)
                self._lock.notify_all()

    def take(self, seg: int) -> Dict[str, np.ndarray]:
        with self._lock:
            while seg in self._inflight or seg in self._queue:
                self._lock.wait()
            if seg in self._buffers:
                self.prefetch_hits += 1
                return self._buffers.pop(seg)
        self.sync_loads += 1
        return self._read(seg)

    def invalidate(self, seg: int):
        """Drop any buffered copy (stale after a write-back)."""
        with self._lock:
            self._buffers.pop(seg, None)
            if seg in self._queue:
                self._queue.remove(seg)

    def close(self):
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._thread.join(timeout=5.0)


class OffloadEngine:
    """LRU-resident window + prefetch + dirty write-back over segments."""

    def __init__(self, store: SegmentStore, max_resident: int = 2,
                 prefetch: bool = True, read_only: bool = False,
                 encoded: bool = False):
        assert max_resident >= 1
        self.store = store
        self.max_resident = max_resident
        # read-only window mode (frozen-base PEFT streaming): segments are
        # never dirtied, so eviction is a plain drop and mark_dirty is a
        # programming error rather than a silent corruption vector
        self.read_only = read_only
        # encoded window mode (quantized frozen base): pulls skip the codec
        # decode so the window stays int8-resident — dequantization happens
        # inside the jitted per-block program, never in the window.  The
        # window never writes back encoded leaves, so this implies read_only.
        self.encoded = encoded
        if encoded and not read_only:
            raise ValueError("an encoded (no-decode) window cannot write "
                             "back; encoded=True requires read_only=True")
        self._resident: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._dirty: set = set()
        self._prefetcher: Optional[Prefetcher] = (
            Prefetcher(store, depth=max(1, max_resident - 1),
                       encoded=encoded)
            if prefetch else None)
        # --- statistics ---
        self.hits = 0
        self.misses = 0
        self.bytes_read = 0
        self.bytes_written = 0
        self.peak_resident_bytes = 0

    # ------------------------------------------------------------------
    @staticmethod
    def _data_bytes(data: Dict[str, np.ndarray]) -> int:
        # actual bytes held, not storage bytes: a decoded bf16 leaf sits in
        # the window as fp32, an encoded int8 leaf as its codes + scales
        return int(sum(v.nbytes for v in data.values()))

    def _resident_bytes(self) -> int:
        return int(sum(self._data_bytes(d) for d in self._resident.values()))

    def prefetch(self, seg: int):
        if self._prefetcher is not None and seg not in self._resident:
            self._prefetcher.schedule(seg)

    def acquire(self, seg: int) -> Dict[str, np.ndarray]:
        """Make segment ``seg`` resident (evicting + writing back LRU
        segments as needed) and return its leaf dict.  The dict is owned by
        the window: mutate in place and ``mark_dirty`` to persist."""
        if seg in self._resident:
            self.hits += 1
            self._resident.move_to_end(seg)
            return self._resident[seg]
        self.misses += 1
        if self._prefetcher is not None:
            data = self._prefetcher.take(seg)
        else:
            data = self.store.read_segment(
                seg, copy=True, encoded=self.encoded,
                window=not self.encoded)
        self.bytes_read += self.store.seg_nbytes[seg]
        self._resident[seg] = data
        self._resident.move_to_end(seg)
        while len(self._resident) > self.max_resident:
            old, old_data = self._resident.popitem(last=False)
            self._writeback(old, old_data)
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self._resident_bytes()
                                       + self._prefetch_buffer_bytes())
        return data

    def _prefetch_buffer_bytes(self) -> int:
        if self._prefetcher is None:
            return 0
        with self._prefetcher._lock:
            bufs = list(self._prefetcher._buffers.values())
        return int(sum(self._data_bytes(d) for d in bufs))

    def mark_dirty(self, seg: int):
        if self.read_only:
            raise RuntimeError(
                f"segment {seg} is in a read-only window (frozen base "
                "layout) — nothing may be written back")
        assert seg in self._resident, seg
        self._dirty.add(seg)

    def _writeback(self, seg: int, data: Dict[str, np.ndarray]):
        if seg in self._dirty:
            self.store.write_segment(seg, data)
            self.bytes_written += self.store.seg_nbytes[seg]
            self._dirty.discard(seg)
            if self._prefetcher is not None:
                self._prefetcher.invalidate(seg)

    def release(self, seg: int):
        """Drop a segment from the window (writing back if dirty)."""
        data = self._resident.pop(seg, None)
        if data is not None:
            self._writeback(seg, data)

    def flush(self):
        """Write back every dirty resident segment (window stays resident)."""
        for seg in list(self._resident):
            self._writeback(seg, self._resident[seg])

    def drop_all(self):
        for seg in list(self._resident):
            self.release(seg)

    def close(self):
        self.flush()
        if self._prefetcher is not None:
            self._prefetcher.close()

    def stats(self) -> Dict[str, float]:
        pf = self._prefetcher
        return {
            "hits": self.hits, "misses": self.misses,
            "prefetch_hits": pf.prefetch_hits if pf else 0,
            "sync_loads": pf.sync_loads if pf else self.misses,
            "bytes_read": self.bytes_read,
            "bytes_written": self.bytes_written,
            "peak_resident_bytes": self.peak_resident_bytes,
            "store_bytes": self.store.total_bytes,
        }
