"""Residency engine over a SegmentStore (paper §4.1.1).

``OffloadEngine`` keeps at most ``max_resident`` segments in RAM in an LRU
window.  A background ``Prefetcher`` thread double-buffers reads: while
segment ``i`` is being consumed by the optimizer, segment ``i+1`` streams in
from its mmap file, hiding the page-in latency behind compute.

Write-back is pipelined too (``async_writeback=True``): eviction hands a
dirty segment to a bounded background ``AsyncWriter`` instead of blocking
``acquire`` on encode + msync — the flash write hides behind the next
block's compute.  ``flush()``/``close()`` (and therefore every hardlink
snapshot) are barriers that fence the write queue, and re-acquiring a
segment still in the queue hands its bytes straight back to the window
(a *write hit* — no flash round trip, no staleness).  The queue's bytes
count toward ``peak_resident_bytes``: deferring a write must not hide its
memory.

The engine tracks the statistics the benchmarks report: window hits/misses,
prefetch hit rate, bytes read/written, peak resident segment bytes (the
number the paper's C1 drives down), and the overlap timers (wall-clock spent
*blocked* on reads / writes vs. total) that the stream-throughput benchmark
turns into a compute/IO overlap breakdown.
"""
from __future__ import annotations

import functools
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

from repro.offload.segments import SegmentStore


def _data_nbytes(data) -> int:
    # actual bytes held, not storage bytes: a decoded bf16 leaf sits in
    # the window as fp32, an encoded int8 leaf as its codes + scales
    return int(sum(v.nbytes for v in data.values()))


_HOST_COPIES: Dict[Tuple, bool] = {}


def _probe_copies(shape: Tuple, dtype) -> bool:
    """True when ``jnp.asarray`` *copies* a host numpy buffer of exactly
    this geometry: a mutation of the source must be invisible through the
    converted array.  Cached per (shape, dtype) for the process."""
    key = (tuple(shape), np.dtype(dtype).str)
    cached = _HOST_COPIES.get(key)
    if cached is None:
        try:
            import jax.numpy as jnp
            probe = np.zeros(shape, dtype)
            if probe.size == 0:
                cached = True
            else:
                dev = jnp.asarray(probe)
                before = float(dev.reshape(-1)[0])
                probe.reshape(-1)[0] = 1
                cached = float(dev.reshape(-1)[0]) == before
        except Exception:
            cached = False
        _HOST_COPIES[key] = cached
    return cached


def _host_to_device_copies(store: Optional[SegmentStore] = None) -> bool:
    """True when the jit boundary copies host numpy buffers at every size
    probed.  Some CPU backends zero-copy large (page-aligned) host arrays —
    a recycled window buffer would then be overwritten underneath a live
    device array, silently corrupting in-flight compute — so the reuse
    pool only turns on when the probes see copies.  (H2D backends always
    copy; this gates the CPU case.)

    With a ``store``, the probes run at the store's *actual* window leaf
    geometries (deduped shape+dtype) rather than generic sizes, so a
    backend whose zero-copy threshold sits between the generic probes and
    a real weight buffer cannot slip the pool on.  The environment
    variable ``REPRO_OFFLOAD_BUFFER_POOL`` (``0``/``1``) overrides the
    heuristic entirely."""
    env = os.environ.get("REPRO_OFFLOAD_BUFFER_POOL")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "no")
    # generic small + weight-sized fp32 probes (the pre-store fast gate)
    if not all(_probe_copies((n,), np.float32) for n in (16384, 1 << 20)):
        return False
    if store is None:
        return True
    try:
        from repro.offload.codecs import get_codec
        seen = set()
        for r in store.records:
            key = (tuple(r.shape),
                   np.dtype(get_codec(r.codec).window_np_dtype(r.dtype)).str)
            if key in seen:
                continue
            seen.add(key)
            if not _probe_copies(*key):
                return False
    except Exception:
        return False
    return True


class Prefetcher:
    """Background double-buffered segment loader.

    ``schedule(i)`` queues segment ``i``; a daemon thread loads it into a
    bounded buffer (``depth`` slots — 2 = classic double buffering).
    ``take(i)`` hands the buffered copy over (or loads synchronously on a
    miss).  The buffer is consume-once: ownership moves to the caller.

    The reader thread never loads past the buffer bound, so a completed
    read can never silently drop a segment another consumer scheduled and
    is about to ``take`` (``forced_drops`` in ``stats()`` counts the
    defensive fallback, which should stay 0).  ``invalidate(i)`` poisons
    *in-flight* reads as well as buffered copies: a read racing a
    write-back of the same segment may return torn/stale bytes, so its
    result is discarded on completion and the consumer falls back to a
    fresh synchronous load.

    Evicted window buffers come back through ``recycle`` and are reused
    for later reads of geometry-identical segments (layer-aligned stores:
    every block segment), so steady-state streaming stops allocating a
    fresh segment-sized array per pull (``repro.offload.segments
    .read_segment``'s ``out=`` path).
    """

    def __init__(self, store: SegmentStore, depth: int = 2,
                 encoded: bool = False):
        self._store = store
        self._depth = max(1, depth)
        self._encoded = encoded
        self._lock = threading.Condition()
        self._queue: list = []                  # guarded-by: _lock
        self._buffers: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()  # guarded-by: _lock
        self._inflight: set = set()             # guarded-by: _lock
        self._stale: set = set()                # guarded-by: _lock
        # reuse pool: only when the jit boundary copies host buffers at
        # this store's actual leaf geometries (else an overwritten recycled
        # buffer could mutate a live device array)
        self._pooling = not encoded and _host_to_device_copies(store)
        self._pool: "OrderedDict[Tuple, list]" = OrderedDict()  # guarded-by: _lock
        self._pool_sets = 0     # guarded-by: _lock (total buffer sets, all signatures)
        self._closed = False                    # guarded-by: _lock
        self.prefetch_hits = 0
        self.sync_loads = 0
        self.forced_drops = 0
        self.buffer_reuses = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------------
    def _read(self, seg: int) -> Dict[str, np.ndarray]:
        """One segment in window form, reusing pooled buffers when a set
        with this segment's geometry is free."""
        bufs = None
        if self._pooling:
            sig = self._store.segment_signature(seg)
            with self._lock:
                free = self._pool.get(sig)
                if free:
                    bufs = free.pop()
                    self._pool_sets -= 1
                    if not free:
                        del self._pool[sig]   # never leave an empty list
        data = self._store.read_segment(
            seg, copy=True, encoded=self._encoded,
            window=not self._encoded, out=bufs)
        if bufs is not None:
            self.buffer_reuses += 1
        return data

    def recycle(self, seg: int, data: Optional[Dict[str, np.ndarray]]):
        """Return a consumed window buffer set to the reuse pool.  Only
        plain-array (non-encoded) sets are pooled; callers guarantee no
        live reference remains (the window's acquire contract: consumers
        copy at the jit boundary before the next acquire).  The pool is
        bounded *globally* (not per signature), so a byte-balanced layout
        whose segments all differ can never accumulate a whole model of
        'free' buffers; pooled bytes are visible via ``buffer_bytes`` and
        therefore count toward ``peak_resident_bytes``."""
        if not self._pooling or not data:
            return
        arrs = list(data.values())
        if not all(isinstance(a, np.ndarray) for a in arrs):
            return
        sig = self._store.segment_signature(seg)
        with self._lock:
            while self._pool_sets >= self._depth + 1 and self._pool:
                old_sig, free = next(iter(self._pool.items()))  # global bound
                if not free:        # defensive: an emptied signature must
                    del self._pool[old_sig]   # never crash the evictor
                    continue
                free.pop()
                self._pool_sets -= 1
                if not free:
                    del self._pool[old_sig]
            self._pool.setdefault(sig, []).append(arrs)
            self._pool.move_to_end(sig)
            self._pool_sets += 1

    # ------------------------------------------------------------------
    def _run(self):
        while True:
            with self._lock:
                seg = None
                while True:
                    if self._closed:
                        return
                    # read only when a buffer slot is free: completing a
                    # read must never force out a segment that was
                    # scheduled and is about to be take()n
                    if len(self._buffers) < self._depth:
                        seg = next((s for s in self._queue
                                    if s not in self._inflight
                                    and s not in self._buffers), None)
                        if seg is not None:
                            self._queue.remove(seg)
                            self._inflight.add(seg)
                            break
                    self._lock.wait()
            try:
                data = self._read(seg)
            except Exception:
                # never strand the id in _inflight (take() would block
                # forever); the consumer's sync fallback re-raises the
                # real I/O error on the main thread
                with self._lock:
                    self._inflight.discard(seg)
                    self._stale.discard(seg)
                    self._lock.notify_all()
                continue
            with self._lock:
                self._inflight.discard(seg)
                if seg in self._stale:
                    # invalidated mid-read (a write-back raced this read):
                    # the bytes may be torn or stale — discard them; a
                    # waiting take() falls back to a fresh sync load
                    self._stale.discard(seg)
                    self.recycle(seg, data)
                else:
                    self._buffers[seg] = data
                    while len(self._buffers) > self._depth:  # defensive
                        self.forced_drops += 1
                        old, old_data = self._buffers.popitem(last=False)
                        self.recycle(old, old_data)
                self._lock.notify_all()

    def schedule(self, seg: int):
        if seg < 0 or seg >= self._store.num_segments:
            return
        with self._lock:
            if seg in self._buffers or seg in self._queue:
                return
            if seg in self._inflight and seg not in self._stale:
                return  # already being read (and the read is still good)
            self._queue.append(seg)
            self._lock.notify_all()

    def take(self, seg: int) -> Dict[str, np.ndarray]:
        forced = False
        with self._lock:
            while not self._closed:
                if seg in self._buffers:
                    self.prefetch_hits += 1
                    data = self._buffers.pop(seg)
                    self._lock.notify_all()      # a buffer slot freed
                    return data
                if seg in self._inflight:
                    self._lock.wait()
                elif seg in self._queue:
                    # front-run the queue: the next free slot must go to
                    # the segment the consumer is actually blocked on, not
                    # whatever happened to be scheduled first
                    if self._queue[0] != seg:
                        self._queue.remove(seg)
                        self._queue.insert(0, seg)
                        self._lock.notify_all()
                    if len(self._buffers) >= self._depth and not forced:
                        # every slot is full of segments nobody has taken
                        # yet: the oldest buffered entry is a stranded
                        # prefetch — drop it so the reader can get to this
                        # one.  At most one drop per take(): spurious
                        # wakeups (every state change notify_all()s) must
                        # not bleed still-useful prefetched segments back
                        # to flash re-reads
                        forced = True
                        self.forced_drops += 1
                        old, old_data = self._buffers.popitem(last=False)
                        self.recycle(old, old_data)
                        self._lock.notify_all()   # wake the reader: a slot
                        #                           just freed
                    self._lock.wait()
                else:
                    break
            if seg in self._queue:
                self._queue.remove(seg)   # closed mid-wait: load inline
        self.sync_loads += 1
        return self._read(seg)

    def invalidate(self, seg: int):
        """Drop buffered/queued copies AND poison any in-flight read of
        ``seg`` (stale after a write-back: a read racing the write may
        return torn bytes — its result is discarded on completion)."""
        dropped = None
        with self._lock:
            dropped = self._buffers.pop(seg, None)
            if seg in self._queue:
                self._queue.remove(seg)
            if seg in self._inflight:
                self._stale.add(seg)
            self._lock.notify_all()
        if dropped is not None:
            self.recycle(seg, dropped)

    def buffer_bytes(self) -> int:
        """Bytes held outside the window: completed prefetch buffers plus
        the (globally bounded) reuse pool — both count toward the engine's
        honest peak accounting."""
        with self._lock:
            bufs = list(self._buffers.values())
            pooled = [a for free in self._pool.values()
                      for arrs in free for a in arrs]
        return int(sum(_data_nbytes(d) for d in bufs)
                   + sum(a.nbytes for a in pooled))

    def close(self):
        with self._lock:
            self._closed = True
            self._lock.notify_all()
        self._thread.join(timeout=5.0)


class AsyncWriter:
    """Bounded background dirty-segment writer — the write-back half of the
    overlap pipeline.  Eviction ``submit``s (seg, data) instead of encoding
    + msync-ing on the critical path; ``barrier()`` is the flush/snapshot
    fence.  ``steal`` hands a still-queued segment straight back to the
    window (a write hit): re-acquiring a just-evicted segment never round
    trips through flash, and a queued steal returns *dirty* (its bytes
    never landed).  Background I/O errors surface on the next
    submit/steal/barrier rather than disappearing with the thread."""

    def __init__(self, store: SegmentStore, max_pending: int = 2,
                 recycle=None):
        self._store = store
        self._max = max(1, max_pending)
        self._recycle = recycle
        self._lock = threading.Condition()
        self._pending: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()  # guarded-by: _lock
        self._writing: Optional[int] = None     # guarded-by: _lock
        self._writing_data: Optional[Dict[str, np.ndarray]] = None  # guarded-by: _lock
        self._stolen = False                    # guarded-by: _lock
        self._closed = False                    # guarded-by: _lock
        self._error: Optional[BaseException] = None  # guarded-by: _lock
        # background writes land in the page cache only (memcpy-cheap and
        # immediately visible to reads); segments touched since the last
        # barrier are fsynced there — durability exactly at the fence
        self._unsynced: set = set()             # guarded-by: _lock
        self.writes = 0
        self.bytes_landed = 0    # bytes that actually reached flash — a
        #                          stolen-back segment never counts
        self.busy_s = 0.0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _raise_pending_error(self):   # holds: _lock
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async segment write-back failed") from err

    def submit(self, seg: int, data: Dict[str, np.ndarray]):
        """Queue one dirty segment (blocks while the bounded queue is
        full — that wait is the engine's ``t_write_block_s``)."""
        with self._lock:
            self._raise_pending_error()
            while len(self._pending) >= self._max and not self._closed:
                self._lock.wait()
            self._pending[seg] = data
            self._pending.move_to_end(seg)
            self._lock.notify_all()

    def steal(self, seg: int):
        """(data, dirty) if the writer still holds ``seg``, else None.  A
        queued segment comes back dirty; one mid-write is waited out and
        comes back clean (its bytes just landed)."""
        with self._lock:
            if seg in self._pending:
                data = self._pending.pop(seg)
                self._lock.notify_all()
                return data, True
            if self._writing == seg:
                self._stolen = True       # the thread must not recycle it
                data = self._writing_data
                while self._writing == seg and self._error is None:
                    self._lock.wait()
                self._raise_pending_error()
                return data, False
        return None

    def holds(self, seg: int) -> bool:
        """True while ``seg`` is queued or being written — prefetching it
        would race the write and read stale flash bytes."""
        with self._lock:
            return seg in self._pending or self._writing == seg

    def pending_bytes(self) -> int:
        with self._lock:
            n = sum(_data_nbytes(d) for d in self._pending.values())
            if self._writing_data is not None:
                n += _data_nbytes(self._writing_data)
        return int(n)

    def barrier(self):
        """Block until every submitted write has landed durably — the
        fence ``flush()`` (and therefore every hardlink snapshot) runs
        behind.  Background writes defer their msync, so the barrier
        settles it: one fsync per segment file touched since the last
        fence."""
        with self._lock:
            while ((self._pending or self._writing is not None)
                   and self._error is None):
                self._lock.wait()
            self._raise_pending_error()
            unsynced, self._unsynced = self._unsynced, set()
        for seg in unsynced:
            self._store.sync_segment(seg)

    def close(self):
        try:
            self.barrier()
        finally:
            with self._lock:
                self._closed = True
                self._lock.notify_all()
            self._thread.join(timeout=5.0)

    def _run(self):
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._lock.wait()
                if not self._pending:
                    return                       # closed and drained
                seg, data = self._pending.popitem(last=False)
                self._writing, self._writing_data = seg, data
                self._stolen = False
                self._lock.notify_all()          # a queue slot freed
            t0 = time.perf_counter()
            err = None
            try:
                # pwrite path: the kernel copy runs GIL-released, so this
                # thread's I/O genuinely overlaps main-thread dispatch
                self._store.pwrite_segment(seg, data)
            except BaseException as e:           # surfaced on next barrier
                err = e
            self.busy_s += time.perf_counter() - t0
            with self._lock:
                stolen = self._stolen
                self._writing = self._writing_data = None
                if err is not None:
                    self._error = err
                else:
                    self.writes += 1
                    self.bytes_landed += self._store.seg_nbytes[seg]
                    self._unsynced.add(seg)
                self._lock.notify_all()
            if err is None and not stolen and self._recycle is not None:
                # a recycle failure must surface like a write failure: an
                # unhandled exception here would kill the thread silently,
                # after which submit() blocks forever on a full queue and
                # barrier() hangs with _pending nonempty
                try:
                    self._recycle(seg, data)
                except BaseException as e:
                    with self._lock:
                        self._error = e
                        self._lock.notify_all()


def _single_owner(fn):
    """Detect concurrent entry into a window-mutating OffloadEngine call.

    The window state (``_resident``/``_dirty``/``_pinned``) is deliberately
    unlocked: the engine's contract is single-owner-at-a-time — exactly one
    thread issues window calls at any moment, though ownership may transfer
    at quiescent points (e.g. construction on the main thread, then the
    StreamedBase staging worker for the steady-state walk).  This wrapper
    records the thread currently inside a window call and raises on overlap.
    It is a *detector*, not a lock: a true race may slip the check on a
    given run, but under the schedule-fuzzing harness (which stretches
    every interleaving window) violations surface deterministically."""
    name = fn.__name__

    @functools.wraps(fn)
    def wrapped(self, *args, **kwargs):
        me = threading.get_ident()
        owner = self._owner
        if owner is not None and owner != me:
            raise RuntimeError(
                f"concurrent OffloadEngine.{name}(): thread {me} entered "
                f"while thread {owner} is inside a window call — window "
                "operations are single-owner-at-a-time (see CONCURRENCY.md); "
                "route pulls through one thread")
        self._owner = me
        self._owner_depth += 1
        try:
            return fn(self, *args, **kwargs)
        finally:
            self._owner_depth -= 1
            if self._owner_depth == 0:
                self._owner = None
    return wrapped


class OffloadEngine:
    """LRU-resident window + prefetch + dirty write-back over segments."""

    def __init__(self, store: SegmentStore, max_resident: int = 2,
                 prefetch: bool = True, read_only: bool = False,
                 encoded: bool = False, async_writeback: bool = False,
                 io_backend: str = ""):
        assert max_resident >= 1
        self.store = store
        if io_backend:
            # re-resolve the store's read backend (probing again) before
            # any reader thread exists — selection stays single-threaded
            store.set_io_backend(io_backend)
        self.max_resident = max_resident
        # read-only window mode (frozen-base PEFT streaming): segments are
        # never dirtied, so eviction is a plain drop and mark_dirty is a
        # programming error rather than a silent corruption vector
        self.read_only = read_only
        # encoded window mode (quantized frozen base): pulls skip the codec
        # decode so the window stays int8-resident — dequantization happens
        # inside the jitted per-block program, never in the window.  The
        # window never writes back encoded leaves, so this implies read_only.
        self.encoded = encoded
        if encoded and not read_only:
            raise ValueError("an encoded (no-decode) window cannot write "
                             "back; encoded=True requires read_only=True")
        # single-owner window state: no lock by design — every mutating
        # call is wrapped in @_single_owner, which raises on concurrent
        # entry from a second thread (ownership transfers only at
        # quiescent points; ``prefetch`` is the one cross-thread-safe call)
        self._resident: "OrderedDict[int, Dict[str, np.ndarray]]" = OrderedDict()
        self._dirty: set = set()
        self._pinned: set = set()
        self._owner: Optional[int] = None    # thread inside a window call
        self._owner_depth = 0
        self._prefetcher: Optional[Prefetcher] = (
            Prefetcher(store, depth=max(1, max_resident - 1),
                       encoded=encoded)
            if prefetch else None)
        # a read-only window has nothing to write back — no writer thread
        self._writer: Optional[AsyncWriter] = (
            AsyncWriter(store, max_pending=max(1, max_resident - 1),
                        recycle=(self._prefetcher.recycle
                                 if self._prefetcher else None))
            if (async_writeback and not read_only) else None)
        # --- statistics ---
        self.hits = 0
        self.misses = 0
        # per-segment miss counts: lets consumers assert residency
        # contracts on *specific* segments (e.g. the serving tier's pinned
        # head segment must miss exactly once per run)
        self.seg_misses: Dict[int, int] = {}
        self.write_hits = 0
        self.bytes_read = 0
        self.bytes_written = 0
        self.peak_resident_bytes = 0
        self.t_read_block_s = 0.0     # acquire blocked waiting for bytes
        self.t_write_block_s = 0.0    # blocked on write-back (queue full /
        #                               inline write / barrier)

    # ------------------------------------------------------------------
    def _resident_bytes(self) -> int:
        return int(sum(_data_nbytes(d) for d in self._resident.values()))

    def prefetch(self, seg: int):
        # cross-thread-safe by design (NOT @_single_owner): the serving
        # main thread prefetches ahead while the staging worker acquires —
        # it only reads _resident opportunistically and hands off to the
        # (internally locked) Prefetcher/AsyncWriter
        if self._prefetcher is None or seg in self._resident:
            return
        if self._writer is not None and self._writer.holds(seg):
            return   # acquire will steal it back; a read now races the write
        self._prefetcher.schedule(seg)

    @_single_owner
    def acquire(self, seg: int) -> Dict[str, np.ndarray]:
        """Make segment ``seg`` resident (evicting + writing back LRU
        segments as needed) and return its leaf dict.  The dict is owned by
        the window: mutate in place and ``mark_dirty`` to persist; hold the
        reference only until the next ``acquire`` (evicted buffers are
        recycled for later reads)."""
        if seg in self._resident:
            self.hits += 1
            self._resident.move_to_end(seg)
            return self._resident[seg]
        self.misses += 1
        self.seg_misses[seg] = self.seg_misses.get(seg, 0) + 1
        data = dirty = None
        if self._writer is not None:
            t0 = time.perf_counter()
            hit = self._writer.steal(seg)
            if hit is not None:
                self.t_write_block_s += time.perf_counter() - t0
                data, dirty = hit
                self.write_hits += 1
                if self._prefetcher is not None:
                    # a prefetch issued before the eviction could still be
                    # racing the (now resolved) write — poison it
                    self._prefetcher.invalidate(seg)
        if data is None:
            t0 = time.perf_counter()
            if self._prefetcher is not None:
                data = self._prefetcher.take(seg)
            else:
                data = self.store.read_segment(
                    seg, copy=True, encoded=self.encoded,
                    window=not self.encoded)
            self.t_read_block_s += time.perf_counter() - t0
            self.bytes_read += self.store.seg_nbytes[seg]
            dirty = False
        self._resident[seg] = data
        self._resident.move_to_end(seg)
        if dirty:
            self._dirty.add(seg)   # stolen bytes never reached flash
        while len(self._resident) > self.max_resident:
            victim = next((s for s in self._resident
                           if s not in self._pinned), None)
            if victim is None:
                break   # everything resident is pinned: let the window grow
            self._writeback(victim, self._resident.pop(victim))
        self.peak_resident_bytes = max(
            self.peak_resident_bytes,
            self._resident_bytes() + self._prefetch_buffer_bytes()
            + (self._writer.pending_bytes() if self._writer else 0)
            + self.store.io_pool_bytes())   # raw readers' staging scratch
        return data

    def _prefetch_buffer_bytes(self) -> int:
        return (self._prefetcher.buffer_bytes()
                if self._prefetcher is not None else 0)

    @_single_owner
    def mark_dirty(self, seg: int):
        if self.read_only:
            raise RuntimeError(
                f"segment {seg} is in a read-only window (frozen base "
                "layout) — nothing may be written back")
        assert seg in self._resident, seg
        self._dirty.add(seg)

    def _writeback(self, seg: int, data: Dict[str, np.ndarray]):
        """Persist one evicted segment (async when a writer is attached;
        clean evictions just recycle their buffers)."""
        if seg not in self._dirty:
            if self._prefetcher is not None:
                self._prefetcher.recycle(seg, data)
            return
        self._write_dirty(seg, data, inline=False)

    def _write_dirty(self, seg: int, data: Dict[str, np.ndarray],
                     inline: bool):
        """The one dirty-write protocol both eviction and ``flush`` run:
        un-dirty, poison racing prefetches, write, account the blocked
        time.  ``inline=True`` bypasses the background writer (flush of a
        still-resident segment: the window still owns — and may mutate —
        these arrays, so they must not enter the writer's recycle path)."""
        self._dirty.discard(seg)
        if self._prefetcher is not None:
            # before the bytes change: in-flight reads of this segment
            # must not land stale data in the buffer
            self._prefetcher.invalidate(seg)
        t0 = time.perf_counter()
        if self._writer is not None and not inline:
            # bytes count when they land (writer.bytes_landed): a segment
            # stolen back out of the queue was never written
            self._writer.submit(seg, data)
        else:
            self.store.write_segment(seg, data)
            self.bytes_written += self.store.seg_nbytes[seg]
        self.t_write_block_s += time.perf_counter() - t0

    @_single_owner
    def pin(self, seg: int):
        """Exempt ``seg`` from LRU eviction while it stays resident.  The
        serving tier pins the head segment (embed/ln_f), which is touched
        twice per decode step (input embedding + logits) — without the pin
        the layer walk evicts it every step and each token pays a head-sized
        re-read.  Pinned residency counts toward ``peak_resident_bytes``
        like any other; it is a residency floor, not free memory."""
        self._pinned.add(seg)

    @_single_owner
    def unpin(self, seg: int):
        self._pinned.discard(seg)

    @_single_owner
    def release(self, seg: int):
        """Drop a segment from the window (writing back if dirty)."""
        data = self._resident.pop(seg, None)
        if data is not None:
            self._writeback(seg, data)

    @_single_owner
    def flush(self):
        """Write back every dirty resident segment and fence the background
        write queue (the window stays resident).  This is the barrier every
        hardlink snapshot runs behind — after ``flush`` returns, the
        segment files hold the current state."""
        for seg in list(self._resident):
            if seg in self._dirty:
                self._write_dirty(seg, self._resident[seg], inline=True)
        if self._writer is not None:
            t0 = time.perf_counter()
            self._writer.barrier()
            self.t_write_block_s += time.perf_counter() - t0

    @_single_owner
    def drop_all(self):
        for seg in list(self._resident):
            self.release(seg)

    @_single_owner
    def close(self):
        self.flush()
        if self._writer is not None:
            self._writer.close()
        if self._prefetcher is not None:
            self._prefetcher.close()
        # after the reader thread is gone: release the io backend's
        # ring/staging pool (lazily re-created if the store is reused)
        self.store.close_io()

    def stats(self) -> Dict[str, float]:
        pf = self._prefetcher
        return {
            "hits": self.hits, "misses": self.misses,
            "write_hits": self.write_hits,
            "prefetch_hits": pf.prefetch_hits if pf else 0,
            "sync_loads": pf.sync_loads if pf else self.misses,
            "forced_drops": pf.forced_drops if pf else 0,
            "buffer_reuses": pf.buffer_reuses if pf else 0,
            "bytes_read": self.bytes_read,
            "bytes_written": self.bytes_written + (
                self._writer.bytes_landed if self._writer else 0),
            "peak_resident_bytes": self.peak_resident_bytes,
            "store_bytes": self.store.total_bytes,
            "t_read_block_s": self.t_read_block_s,
            "t_write_block_s": self.t_write_block_s,
            "writeback_busy_s": self._writer.busy_s if self._writer else 0.0,
            "async_writeback": 1 if self._writer is not None else 0,
            # raw-reader counters (io_* all-zero under mmap) + COW cost;
            # every value stays numeric — consumers aggregate this dict
            **self.store.io_stats(),
        }
