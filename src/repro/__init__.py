"""JAX reproduction of MobileFineTuner (fine-tuning LLMs on mobile phones).

Subpackages: models, core (C1-C6 runtime), offload (C1 phone realization),
checkpoint, data, optim, launch, runtime, kernels, configs.
"""

__version__ = "0.1.0"
