"""Gemma3-270M [Gemma Team 2025] — paper PEFT model; qk-norm, geglu,
interleaved sliding/global attention, huge 262k vocab."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-270m", family="dense",
    n_layers=18, d_model=640, n_heads=4, n_kv_heads=1, d_ff=2048,
    vocab_size=262144, head_dim=256,
    mlp_variant="geglu", norm_variant="rmsnorm", pos_variant="rope",
    qk_norm=True, tie_embeddings=True, sliding_window=512,
    global_layer_every=6, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=1, d_ff=128,
    vocab_size=512, head_dim=32, mlp_variant="geglu", qk_norm=True,
    tie_embeddings=True, sliding_window=16, global_layer_every=2,
    max_seq_len=128,
)
