"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality), attention-free.
d_inner = 2*768 = 1536; 24 heads of dim 64; state 128."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280, head_dim=64,
    norm_variant="rmsnorm", pos_variant="none", tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, max_seq_len=1048576,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=512, head_dim=8, pos_variant="none", tie_embeddings=True,
    ssm_state=16, ssm_head_dim=8, ssm_expand=2, ssm_chunk=8, max_seq_len=256,
)
