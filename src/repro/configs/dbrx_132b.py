"""DBRX-132B [hf:databricks/dbrx-base] — 16 experts top-4 fine-grained MoE,
GQA kv=8."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, n_experts=16, top_k=4,
    mlp_variant="swiglu", norm_variant="rmsnorm", pos_variant="rope",
    rope_theta=500_000.0, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="dbrx-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, n_experts=4, top_k=4, max_seq_len=128,
)
