"""Gemma3-1B [Gemma Team 2025] — paper PEFT model."""
from repro.config import ModelConfig
from repro.configs.gemma3_270m import SMOKE as _S

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab_size=262144, head_dim=256,
    mlp_variant="geglu", norm_variant="rmsnorm", pos_variant="rope",
    qk_norm=True, tie_embeddings=True, sliding_window=512,
    global_layer_every=6, max_seq_len=32768,
)
SMOKE = _S
