"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution.

Vision frontend is a STUB (harness rule): input_specs provides patch
embeddings merged at the sequence front; M-RoPE (t/h/w sections 16/24/24 of
head_dim/2=64) positions both streams.
"""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, head_dim=128,
    mlp_variant="swiglu", norm_variant="rmsnorm",
    qkv_bias=True, pos_variant="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, n_vision_tokens=1024, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16,
    mlp_variant="swiglu", qkv_bias=True, pos_variant="mrope",
    mrope_sections=(2, 3, 3), n_vision_tokens=8, max_seq_len=128,
)
