"""Granite-34B-Code [arXiv:2405.04324; hf] — 88 deep layers, MQA (kv=1),
llama-style attention (rope + rmsnorm, no biases) with the 4x GELU MLP
that d_ff=24576 implies (2-matrix MLP reproduces the 34B total; a SwiGLU
reading would give 47B)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, d_ff=24576,
    vocab_size=49152,
    mlp_variant="gelu", norm_variant="rmsnorm", pos_variant="rope",
    tie_embeddings=True, rope_theta=10_000_000.0, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="granite-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=512, tie_embeddings=True, max_seq_len=128,
)
