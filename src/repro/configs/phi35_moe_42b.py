"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct] —
16 experts, top-2 routing, GQA kv=8."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab_size=32064, n_experts=16, top_k=2,
    mlp_variant="swiglu", norm_variant="rmsnorm", pos_variant="rope",
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=512, n_experts=4, top_k=2, max_seq_len=128,
)
