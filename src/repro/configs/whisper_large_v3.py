"""Whisper-large-v3 [arXiv:2212.04356] — enc-dec, conv frontend STUB
(input_specs provides precomputed frame embeddings at seq/4).  Position
tables extended to the harness shapes (real whisper: 1500 enc / 448 dec)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    mlp_variant="gelu", norm_variant="layernorm", pos_variant="learned",
    qkv_bias=True, attn_out_bias=True, mlp_bias=True, tie_embeddings=True,
    enc_seq_ratio=4, max_seq_len=32776,
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, mlp_variant="gelu", norm_variant="layernorm",
    pos_variant="learned", qkv_bias=True, attn_out_bias=True, mlp_bias=True,
    tie_embeddings=True, enc_seq_ratio=4, max_seq_len=128,
)
