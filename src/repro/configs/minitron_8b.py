"""Minitron-8B [arXiv:2407.14679; hf] — width-pruned Nemotron-4;
squared-ReLU MLP, GQA kv=8, huge 256k vocab."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=16384,
    vocab_size=256000,
    mlp_variant="relu2", norm_variant="layernorm", pos_variant="rope",
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="minitron-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, mlp_variant="relu2", norm_variant="layernorm",
    max_seq_len=128,
)
