"""GPT2-small-124M [Radford et al. 2019] — paper correctness model (Fig 9)."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gpt2-124m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=50257,
    mlp_variant="gelu", norm_variant="layernorm", pos_variant="learned",
    qkv_bias=True, attn_out_bias=True, mlp_bias=True, tie_embeddings=True,
    max_seq_len=1024,
)

SMOKE = ModelConfig(
    name="gpt2-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, mlp_variant="gelu", norm_variant="layernorm",
    pos_variant="learned", qkv_bias=True, attn_out_bias=True, mlp_bias=True,
    tie_embeddings=True, max_seq_len=128,
)
