"""Hymba-1.5B [arXiv:2411.13676; hf] — parallel attention+mamba heads,
SWA with 3 global layers (first/middle/last), 128 meta tokens.
25 attn heads x 64 = 1600; SSM d_inner = 3200 (50 heads x 64), state 16."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64,
    mlp_variant="swiglu", norm_variant="rmsnorm", pos_variant="rope",
    sliding_window=1024, global_layer_every=16, n_meta_tokens=128,
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_conv_width=4,
    ssm_chunk=256, max_seq_len=1048576,
)

SMOKE = ModelConfig(
    name="hymba-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, head_dim=16, sliding_window=16, global_layer_every=2,
    n_meta_tokens=4, ssm_state=8, ssm_head_dim=16, ssm_expand=2,
    ssm_chunk=8, max_seq_len=256,
)
