"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B] — MHA (kv=16), QKV bias, tied."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab_size=151936,
    mlp_variant="swiglu", norm_variant="rmsnorm", pos_variant="rope",
    qkv_bias=True, tie_embeddings=True, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="qwen1.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=512, qkv_bias=True, tie_embeddings=True, max_seq_len=128,
)
