"""Qwen2.5-0.5B [Qwen Team 2024] — the paper's case-study base model."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936,
    mlp_variant="swiglu", norm_variant="rmsnorm", pos_variant="rope",
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
    max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=512, qkv_bias=True, tie_embeddings=True, max_seq_len=128,
)
