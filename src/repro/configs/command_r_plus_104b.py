"""Command-R+ 104B [hf:CohereForAI/c4ai-command-r-plus] — GQA kv=8, no-bias,
256k vocab."""
from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab_size=256000,
    mlp_variant="swiglu", norm_variant="layernorm", pos_variant="rope",
    rope_theta=75_000_000.0, max_seq_len=32768,
)

SMOKE = ModelConfig(
    name="command-r-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab_size=512, norm_variant="layernorm", max_seq_len=128,
)
