"""Architecture registry: the 10 harness-assigned archs + the paper's own
models.  ``get(name)`` -> full ModelConfig; ``get_smoke(name)`` -> reduced
same-family config for CPU smoke tests."""
from __future__ import annotations

import importlib

ASSIGNED = (
    "qwen2_vl_7b", "phi35_moe_42b", "dbrx_132b", "granite_34b",
    "minitron_8b", "command_r_plus_104b", "qwen15_05b", "mamba2_130m",
    "whisper_large_v3", "hymba_15b",
)
PAPER_MODELS = ("gpt2_124m", "gpt2_355m", "qwen25_05b", "gemma3_270m",
                "gemma3_1b")
ALL = ASSIGNED + PAPER_MODELS

_ALIAS = {n.replace("_", "-"): n for n in ALL}


def _module(name: str):
    name = _ALIAS.get(name, name)
    return importlib.import_module(f"repro.configs.{name}")


def get(name: str):
    return _module(name).CONFIG


def get_smoke(name: str):
    return _module(name).SMOKE
