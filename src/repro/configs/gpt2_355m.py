"""GPT2-medium-355M [Radford et al. 2019] — paper PEFT model."""
from repro.config import ModelConfig
from repro.configs.gpt2_124m import SMOKE as _S

CONFIG = ModelConfig(
    name="gpt2-355m", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=50257,
    mlp_variant="gelu", norm_variant="layernorm", pos_variant="learned",
    qkv_bias=True, attn_out_bias=True, mlp_bias=True, tie_embeddings=True,
    max_seq_len=1024,
)
SMOKE = _S
