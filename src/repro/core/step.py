"""Composed train / eval / serve steps (paper Application layer).

``make_train_step`` assembles the full resource-aware runtime:
  C1 parameter sharding   — in/out shardings from the rule preset
  C2 grad accumulation    — lax.scan micro-batching (+ optional bf16 grad compression)
  C3 activation ckpt      — remat policy inside the model scan
  C4 ME attention         — TrainConfig.attention_impl
  C6 Full-FT vs LoRA      — lora=True trains only the adapter tree

State pytrees:
  Full-FT: {"params", "opt", "step"}
  LoRA:    {"base", "lora", "opt", "step"}   (opt covers only the adapter)
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.core.accumulate import value_and_grad_accumulated
from repro.core.lora import lora_specs, merge_lora
from repro.models import registry
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import lr_schedule
from repro.param import init_params


# ----------------------------------------------------------------------------
# State construction
# ----------------------------------------------------------------------------
def _lora_specs_checked(specs, cfg: ModelConfig, tcfg: TrainConfig):
    lspecs = lora_specs(specs, tcfg.lora_targets, tcfg.lora_rank)
    if not lspecs:
        raise ValueError(
            f"lora_targets {tcfg.lora_targets!r} match no leaves of "
            f"{cfg.name} ({cfg.family} family) — the adapter would be "
            "empty and train nothing; pick >=2-D leaf names from the "
            "model's param specs (e.g. wq,wk,wv,wo for attention, "
            "w_x,w_out for the ssm family)")
    return lspecs


def init_adapter_state(rng, cfg: ModelConfig, tcfg: TrainConfig):
    """The adapter-only slice of ``init_state``'s LoRA tree — identical
    {"lora", "opt", "step"} leaves (same key folding) without materializing
    the base.  Used when the frozen base segments already exist on disk."""
    lspecs = _lora_specs_checked(registry.param_specs(cfg), cfg, tcfg)
    lora = init_params(jax.random.fold_in(rng, 1), lspecs,
                       dtype=jnp.float32)
    return {"lora": lora, "opt": adamw_init(lora),
            "step": jnp.zeros((), jnp.int32)}


def init_state(rng, cfg: ModelConfig, tcfg: TrainConfig):
    specs = registry.param_specs(cfg)
    pd = dtype_of(tcfg.param_dtype)
    params = init_params(rng, specs, dtype=pd)
    if tcfg.lora_rank > 0:
        return {"base": params, **init_adapter_state(rng, cfg, tcfg)}
    return {"params": params, "opt": adamw_init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_specs(cfg: ModelConfig, tcfg: TrainConfig):
    """ParamSpec pytree for the full state (for shardings / abstract AOT)."""
    from repro.param import ParamSpec, spec, tree_map_specs
    specs = registry.param_specs(cfg)
    pd = dtype_of(tcfg.param_dtype)
    pspecs = tree_map_specs(
        lambda s: ParamSpec(s.shape, pd, s.axes, s.init, s.scale), specs)

    def f32(s_tree):
        return tree_map_specs(
            lambda s: ParamSpec(s.shape, jnp.float32, s.axes, "zeros", 1.0),
            s_tree)

    scalar = spec((), (), init="zeros", dtype=jnp.int32)
    if tcfg.lora_rank > 0:
        lspecs = lora_specs(specs, tcfg.lora_targets, tcfg.lora_rank)
        lspecs = f32(lspecs)
        return {"base": pspecs, "lora": lspecs,
                "opt": {"m": f32(lspecs), "v": f32(lspecs), "count": scalar},
                "step": scalar}
    return {"params": pspecs,
            "opt": {"m": f32(pspecs), "v": f32(pspecs), "count": scalar},
            "step": scalar}


# ----------------------------------------------------------------------------
# Train step
# ----------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    model_loss = registry.loss_fn(cfg)
    reduce_dtype = (dtype_of(tcfg.grad_reduce_dtype)
                    if tcfg.grad_reduce_dtype else None)

    def train_step(state, batch):
        lora_mode = "lora" in state

        def loss_of(trainable, mb):
            if lora_mode:
                params = merge_lora(state["base"], trainable,
                                    rank=tcfg.lora_rank, alpha=tcfg.lora_alpha)
            else:
                params = trainable
            return model_loss(params, mb, cfg, tcfg)

        trainable = state["lora"] if lora_mode else state["params"]
        loss, metrics, grads = value_and_grad_accumulated(
            loss_of, trainable, batch, tcfg.microbatches, reduce_dtype)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        lr = lr_schedule(state["step"], base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        new_trainable, new_opt = adamw_update(
            grads, state["opt"], trainable, lr=lr, beta1=tcfg.beta1,
            beta2=tcfg.beta2, eps=tcfg.eps, weight_decay=tcfg.weight_decay)
        new_state = dict(state)
        if lora_mode:
            new_state["lora"] = new_trainable
        else:
            new_state["params"] = new_trainable
        new_state["opt"] = new_opt
        new_state["step"] = state["step"] + 1
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return new_state, metrics

    return train_step


def make_grad_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    """Forward/backward only — for the segment-wise offload path (C1 phone
    realization), where the optimizer update runs *outside* jit, streaming
    (p, m, v) segments through an LRU window (see repro/offload/).

    Returns ``grad_step(params, batch) -> (loss, metrics, grads)`` with
    gradients already clipped (same order as ``make_train_step``).
    Full-FT only: LoRA state is adapter-sized and never needs offload.
    """
    if tcfg.lora_rank > 0:
        raise ValueError(
            "byte-balanced optimizer offload supports Full-FT only (the "
            "adapter's optimizer state is tiny); for PEFT on a phone budget "
            "combine --lora-rank with --offload-stream-params (frozen "
            "streamed base + in-memory adapter)")
    model_loss = registry.loss_fn(cfg)
    reduce_dtype = (dtype_of(tcfg.grad_reduce_dtype)
                    if tcfg.grad_reduce_dtype else None)

    def grad_step(params, batch):
        def loss_of(p, mb):
            return model_loss(p, mb, cfg, tcfg)

        loss, metrics, grads = value_and_grad_accumulated(
            loss_of, params, batch, tcfg.microbatches, reduce_dtype)
        grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return loss, metrics, grads

    return grad_step


def make_stream_step(cfg: ModelConfig, tcfg: TrainConfig, lstate,
                     grad_dir: str, adapter=None) -> Callable:
    """Layer-streamed train step (C1 phone realization, full depth): fwd/bwd
    pages block params through the offload window (repro/core/stream.py)
    instead of materializing the whole tree, then streams the AdamW update.

    ``lstate`` is a ``LayerStreamedState``; ``grad_dir`` holds the gradient
    scratch segments.  Returns ``step_fn(batch, step) -> (loss, metrics)``.

    With ``tcfg.lora_rank > 0`` (C6 over the streamed base) ``lstate`` must
    be the frozen param-only layout and ``adapter`` the in-memory trainable
    state {"lora", "opt", "step"}; ``grad_dir`` is unused (adapter grads
    accumulate in memory).
    """
    from repro.core.stream import StreamedTrainStep
    return StreamedTrainStep(cfg, tcfg, lstate, grad_dir, adapter=adapter)


def make_eval_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    model_loss = registry.loss_fn(cfg)

    def eval_step(state, batch):
        if "lora" in state:
            params = merge_lora(state["base"], state["lora"],
                                rank=tcfg.lora_rank, alpha=tcfg.lora_alpha,
                                train=False)
        else:
            params = state["params"]
        loss, metrics = model_loss(params, batch, cfg, tcfg)
        return metrics

    return eval_step


def make_serve_step(cfg: ModelConfig, tcfg: TrainConfig) -> Callable:
    decode = registry.decode_fn(cfg)

    def serve_step(params, cache, tokens, index):
        return decode(params, cache, tokens, index, cfg, tcfg)

    return serve_step
