"""Layer-streamed forward/backward over the offload engine (paper §4.1.1).

PR 1 realized C1's segment-wise offload for the *optimizer* stream only —
fwd/bwd still materialized the full parameter tree, so peak RSS during
compute scaled with model size.  This module closes that gap: model
execution is an explicit two-sweep program over layer-aligned segments
(``LayerStreamedState``: one segment per block + one head segment), driven
by the per-stage jitted entry points of ``repro.models.lm.make_layer_program``.

Forward sweep   pull block ``i``'s params through the LRU window (prefetching
                ``i+1`` while ``i`` computes), save only the layer-boundary
                activation, carry the MoE aux sum.
Backward sweep  walk blocks in reverse, re-pull each block's segment, replay
                its forward inside ``jax.vjp`` (layer-granular recompute) and
                sink the resulting per-block gradient into a layer-aligned
                *gradient scratch store* — gradients never form a full tree
                in RAM either.  A running sum of squares yields the global
                grad norm for clipping without a second pass.
Update sweep    stream (p, m, v) + grad segments jointly through their
                windows and apply the very same ``adamw_update`` per segment
                (shared count, clip scale folded into the gradients), so the
                math matches the in-memory jit path to fp re-association
                noise (equivalence-tested at 1e-5).

Peak resident params during compute: the head segment plus about
``offload_resident + 1`` layer segments — independent of ``n_layers``
(``repro.core.zero.stream_resident_bytes`` gives the analytic bound; the
mem-chain benchmark reports the measured one).

Gradient accumulation (C2) composes: each micro-batch runs its own two
sweeps and accumulates into the gradient scratch segments; the update sweep
then applies the averaged, clipped gradient once.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.accumulate import split_batch
from repro.models import transformer as T
from repro.models.lm import make_layer_program
from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore
from repro.offload.state import LayerStreamedState, P
from repro.optim.schedule import lr_schedule


def make_grad_store(lstate: LayerStreamedState, directory: str
                    ) -> SegmentStore:
    """Gradient scratch segments mirroring the param store's layer-aligned
    geometry (same segment <-> block mapping, fp32, params only — no
    moments).  Rewritten every step, and the first micro-batch overwrites
    every leaf, so the files are laid out sparse (``write=False``): no
    parameter-sized burst of zero writes at startup — this path targets
    flash-wear-sensitive devices."""
    groups, labels = [], []
    for seg in range(lstate.store.num_segments):
        groups.append([
            (n, np.zeros(lstate.store.record(P + n).shape, np.float32))
            for n in lstate.seg_param_names(seg)])
        labels.append(lstate.store.labels[seg])
    return SegmentStore.create(directory, groups, len(groups),
                               meta={"kind": "grad_scratch_v1"},
                               group_labels=labels, write=False)


class StreamedTrainStep:
    """One optimizer step = forward sweep + backward sweep (grads into the
    scratch store) per micro-batch, then one streamed AdamW update sweep.

    ``step_fn(batch, step) -> (loss, metrics)`` — the streamed counterpart
    of ``make_train_step``'s jitted body, matching its schedule, clipping
    and AdamW semantics.
    """

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 lstate: LayerStreamedState, grad_dir: str):
        if tcfg.lora_rank > 0:
            raise ValueError("layer streaming supports Full-FT only "
                             "(lora_rank must be 0)")
        self.cfg, self.tcfg = cfg, tcfg
        self.lstate = lstate
        self.program = make_layer_program(cfg, tcfg)
        self.windows = np.asarray(T.layer_windows(cfg))
        os.makedirs(grad_dir, exist_ok=True)
        self.grad_engine = OffloadEngine(
            make_grad_store(lstate, grad_dir),
            max_resident=max(1, tcfg.offload_resident),
            prefetch=tcfg.offload_prefetch)

    # ------------------------------------------------------------------
    def _sink(self, seg: int, names: List[str], grads: List[Any],
              first: bool, last: bool, n_micro: int) -> float:
        """Accumulate one segment's gradient leaves into the scratch store;
        on the last micro-batch return this segment's contribution to
        ||g/n||^2 (the averaged-gradient global norm)."""
        gdata = self.grad_engine.acquire(seg)
        sq = 0.0
        for n, g in zip(names, grads):
            g = np.asarray(g, np.float32)
            if first:
                gdata[n][...] = g
            else:
                gdata[n] += g
            if last:
                avg = gdata[n] / n_micro if n_micro > 1 else gdata[n]
                sq += float(np.sum(np.square(avg, dtype=np.float32),
                                   dtype=np.float32))
        self.grad_engine.mark_dirty(seg)
        return sq

    def _forward_sweep(self, mb, keep_acts: bool):
        """Stream the blocks forward, prefetching ``i+1`` while ``i``
        computes.  Returns (head, acts, aux_sum, positions); ``acts`` holds
        the L+1 layer-boundary activations when ``keep_acts`` (for the
        backward sweep), else just the final one."""
        prog, lstate = self.program, self.lstate
        head = lstate.head_params()
        x = prog.embed(head, mb)
        positions = prog.positions(x.shape[0], x.shape[1])
        acts = [x]
        aux_sum = jnp.zeros((), jnp.float32)
        lstate.prefetch_layer(0)
        for i in range(lstate.n_layers):
            lstate.prefetch_layer(i + 1)   # i+1 pages in while i computes
            bp = lstate.layer_params(i)
            x, aux = prog.block(bp, x, jnp.asarray(self.windows[i]),
                                positions)
            if keep_acts:
                acts.append(x)
            else:
                acts[0] = x
            aux_sum = aux_sum + aux
        return head, acts, aux_sum, positions

    def _two_sweeps(self, mb, first: bool, last: bool, n_micro: int):
        """Forward + backward over one micro-batch.  Returns
        (loss, metrics, sq_norm_contribution)."""
        prog, lstate = self.program, self.lstate
        L = lstate.n_layers
        head, acts, aux_sum, positions = self._forward_sweep(
            mb, keep_acts=True)

        # ---- head loss + its VJP ----------------------------------------
        loss, metrics, dhead, dx, daux = prog.head_vjp(head, acts[L], mb,
                                                       aux_sum)

        # ---- backward sweep: re-pull each block, VJP, sink grads --------
        sq = 0.0
        lstate.prefetch_layer(L - 1)
        self.grad_engine.prefetch(L - 1)
        for i in reversed(range(L)):
            lstate.prefetch_layer(i - 1)
            self.grad_engine.prefetch(
                i - 1 if i > 0 else lstate.head_segment)
            bp = lstate.layer_params(i)
            dp, dx = prog.block_vjp(bp, acts[i],
                                    jnp.asarray(self.windows[i]), positions,
                                    dx, daux)
            acts[i + 1] = None             # free the boundary activation
            names = [f"blocks.{i}.{n}" for n in lstate.block_names]
            sq += self._sink(i, names, jax.tree.leaves(dp), first, last,
                             n_micro)

        # embed's contribution lands on the same head tree as the unembed's
        dhead_e = prog.embed_vjp(head, mb, dx)
        dhead = jax.tree.map(jnp.add, dhead, dhead_e)
        sq += self._sink(lstate.head_segment, lstate.head_names,
                         jax.tree.leaves(dhead), first, last, n_micro)
        return loss, metrics, sq

    def _update_sweep(self, lr, clip_scale: float, n_micro: int):
        """Stream (p, m, v) + grad segments and AdamW each in place."""
        lstate, tcfg = self.lstate, self.tcfg
        count = jnp.asarray(lstate.count, jnp.int32)
        lstate.engine.prefetch(0)
        self.grad_engine.prefetch(0)
        for seg in range(lstate.store.num_segments):
            lstate.engine.prefetch(seg + 1)
            self.grad_engine.prefetch(seg + 1)
            gdata = self.grad_engine.acquire(seg)
            gnamed = {}
            for n in lstate.seg_param_names(seg):
                g = jnp.asarray(gdata[n], jnp.float32)
                if n_micro > 1:
                    g = g / n_micro
                gnamed[n] = g * clip_scale
            lstate._update_segment(seg, gnamed, count, lr=lr,
                                   beta1=tcfg.beta1, beta2=tcfg.beta2,
                                   eps=tcfg.eps,
                                   weight_decay=tcfg.weight_decay)
        lstate.finish_step()

    # ------------------------------------------------------------------
    def __call__(self, batch, step: int):
        tcfg = self.tcfg
        n = max(1, tcfg.microbatches)
        micros = split_batch(batch, n) if n > 1 else None
        loss_sum, metrics, sq = 0.0, None, 0.0
        for j in range(n):
            mb = (jax.tree.map(lambda a: a[j], micros) if n > 1 else batch)
            loss, metrics, s = self._two_sweeps(mb, j == 0, j == n - 1, n)
            loss_sum += float(loss)
            sq += s
        gnorm = math.sqrt(sq)
        if tcfg.grad_clip > 0:
            clip_scale = min(1.0, tcfg.grad_clip / max(gnorm, 1e-9))
        else:
            clip_scale = 1.0
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        self._update_sweep(lr, clip_scale, n)
        metrics = dict(metrics)
        metrics["loss"] = loss_sum / n
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return metrics["loss"], metrics

    # ------------------------------------------------------------------
    def loss_only(self, batch):
        """Streamed forward pass (no grads, no update) — eval.  Returns
        (loss, metrics)."""
        head, acts, aux_sum, _ = self._forward_sweep(batch, keep_acts=False)
        return self.program.head_loss(head, acts[0], batch, aux_sum)

    def stats(self) -> Dict[str, Any]:
        s = {"param_" + k: v for k, v in self.lstate.stats().items()}
        s.update({"grad_" + k: v for k, v in self.grad_engine.stats().items()})
        return s

    def close(self):
        self.grad_engine.close()
