"""Layer-streamed forward/backward over the offload engine (paper §4.1.1).

PR 1 realized C1's segment-wise offload for the *optimizer* stream only —
fwd/bwd still materialized the full parameter tree, so peak RSS during
compute scaled with model size.  This module closes that gap: model
execution is an explicit two-sweep program over layer-aligned segments
(``LayerStreamedState``: one segment per block + one head segment), driven
by the per-stage jitted entry points of ``repro.models.lm.make_layer_program``.

Forward sweep   pull block ``i``'s params through the LRU window (prefetching
                ``i+1`` while ``i`` computes), save only the layer-boundary
                activation, carry the MoE aux sum.
Backward sweep  walk blocks in reverse, re-pull each block's segment, replay
                its forward inside ``jax.vjp`` (layer-granular recompute) and
                sink the resulting per-block gradient into a layer-aligned
                *gradient scratch store* — gradients never form a full tree
                in RAM either.  A running sum of squares yields the global
                grad norm for clipping without a second pass.
Update sweep    stream (p, m, v) + grad segments jointly through their
                windows and apply the very same ``adamw_update`` per segment
                (shared count, clip scale folded into the gradients), so the
                math matches the in-memory jit path to fp re-association
                noise (equivalence-tested at 1e-5).

Peak resident params during compute: the head segment plus about
``offload_resident + 1`` layer segments — independent of ``n_layers``
(``repro.core.zero.stream_resident_bytes`` gives the analytic bound; the
mem-chain benchmark reports the measured one).

Gradient accumulation (C2) composes: each micro-batch runs its own two
sweeps and accumulates into the gradient scratch segments; the update sweep
then applies the averaged, clipped gradient once.

PEFT (C6) composes too: with ``tcfg.lora_rank > 0`` the base segments are a
*frozen, param-only* layout (``LayerStreamedState.create_frozen``) served
through a read-only window — no m/v segments, no dirty write-back, no
gradient scratch store.  The (tiny) LoRA adapter tree stays memory-resident;
``merge_lora`` is applied per block inside the jitted apply/VJP entry
points, adapter cotangents accumulate in memory, and one in-memory AdamW
updates the adapter after the sweeps.  Resident state drops to roughly a
third of the Full-FT streamed bound (``repro.core.zero``).

QLoRA composes on top (``tcfg.base_quant == "int8"``): the frozen base
segments are per-channel quantized (repro/offload/codecs.py) and the window
keeps them *encoded* — ``layer_params``/``head_params`` hand the program
(codes, scales) tree pairs and the jitted entry points dequantize per
block, so fp32 base weights only ever exist as XLA transients.

The step is an *overlap pipeline*, not just a memory bound:

- **Device staging** (``tcfg.offload_staging``, default on): block
  ``i+1``'s window leaves convert to device
  arrays right after block ``i``'s compute is dispatched (JAX dispatch is
  asynchronous), so the flash read *and* the host->device transfer of the
  next block hide behind the current block's compute — classic double
  buffering, at most two staged blocks alive.  The head tree is staged
  once per step (once per run for a frozen base) and the per-layer
  attention-window constants are device-resident from construction.
- **Deferred syncs** (always on — not gated by any flag): ``loss``,
  ``aux_sum`` and the grad-norm square-sum
  stay device scalars until the end of the step — one ``float()`` sync per
  step instead of one per block boundary; per-segment square-sums come
  from one fused jitted reduction.
- **Async write-back** (``tcfg.offload_async_writeback``): dirty segment
  eviction hands bytes to the engine's background writer instead of
  encode+msync on the critical path (repro/offload/engine.py).
- **Activation-boundary offload** (``tcfg.offload_activations``): the
  forward sweep spills boundary ``i`` into a per-step activation scratch
  store (repro/offload/act_store.py) right after block ``i``'s compute is
  dispatched — only the running boundary plus ``acts[L]`` stay on device,
  so resident activations stop scaling with depth (the long-sequence
  wall).  The backward sweep pulls boundaries back in *reverse* order
  (``i-1`` prefetches while block ``i``'s VJP runs; a boundary still in
  the write queue is stolen straight back), optionally through a
  bf16/int8 activation codec (``tcfg.activation_codec``; fp32 is a
  bit-exact spill — loss trajectories match the device-resident path
  bitwise).

``pipeline_stats()`` reports the overlap breakdown (time blocked on reads
/ writes / host->device staging) that the stream-throughput benchmark
turns into a compute/IO overlap fraction.
"""
from __future__ import annotations

import math
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.accumulate import split_batch
from repro.models import transformer as T
from repro.models.lm import make_layer_program
from repro.offload.act_store import ActivationStore, act_store_for
from repro.offload.codecs import activation_codec
from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore
from repro.offload.state import (LayerStreamedState, P,
                                 ensure_base_quant_match)
from repro.optim.adamw import adamw_update
from repro.optim.schedule import lr_schedule


def make_grad_store(lstate: LayerStreamedState, directory: str,
                    io_backend: str = "") -> SegmentStore:
    """Gradient scratch segments mirroring the param store's layer-aligned
    geometry (same segment <-> block mapping, fp32, params only — no
    moments).  Rewritten every step, and the first micro-batch overwrites
    every leaf, so the files are laid out sparse (``write=False``): no
    parameter-sized burst of zero writes at startup — this path targets
    flash-wear-sensitive devices."""
    groups, labels = [], []
    for seg in range(lstate.store.num_segments):
        groups.append([
            (n, np.zeros(lstate.store.record(P + n).shape, np.float32))
            for n in lstate.seg_param_names(seg)])
        labels.append(lstate.store.labels[seg])
    return SegmentStore.create(directory, groups, len(groups),
                               meta={"kind": "grad_scratch_v1"},
                               group_labels=labels, write=False,
                               io_backend=io_backend)


class StreamedTrainStep:
    """One optimizer step = forward sweep + backward sweep (grads into the
    scratch store) per micro-batch, then one streamed AdamW update sweep.

    ``step_fn(batch, step) -> (loss, metrics)`` — the streamed counterpart
    of ``make_train_step``'s jitted body, matching its schedule, clipping
    and AdamW semantics.

    With ``tcfg.lora_rank > 0`` (PEFT over a frozen streamed base):
    ``lstate`` must be the frozen param-only layout and ``adapter`` supplies
    the memory-resident trainable state ``{"lora", "opt", "step"}``.  The
    backward sweep then returns adapter cotangents (stacked back into the
    adapter's layout in memory — no scratch segments), and the update is a
    single in-memory AdamW over the adapter tree.
    """

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 lstate: LayerStreamedState, grad_dir: str,
                 adapter: Optional[Dict[str, Any]] = None):
        if tcfg.microbatches < 1:
            raise ValueError(
                f"microbatches must be >= 1, got {tcfg.microbatches}; pass "
                "--microbatches 1 to disable gradient accumulation")
        self.cfg, self.tcfg = cfg, tcfg
        self.lstate = lstate
        self.lora_mode = tcfg.lora_rank > 0
        ensure_base_quant_match(lstate, tcfg.base_quant)
        self.program = make_layer_program(cfg, tcfg)
        self.windows = np.asarray(T.layer_windows(cfg))
        # per-layer attention-window constants live on device from day one
        # — re-uploading an identical scalar per block per sweep was pure
        # critical-path transfer
        self._windows_dev = [jnp.asarray(w) for w in self.windows]
        self.staging = bool(getattr(tcfg, "offload_staging", True))
        self._staged: "Dict[int, Any]" = {}   # block idx -> device tree
        self._head_dev = None                 # staged head tree (per step)
        self._pos_cache: Dict[Any, Any] = {}  # (b, s) -> device positions
        # one fused reduction per segment instead of a host square+sum per
        # leaf; returns a device scalar so the grad-norm sync defers to the
        # end of the step (two cache entries: block tree, head tree)
        self._sumsq = jax.jit(
            lambda gs, inv: sum(jnp.sum(jnp.square(g * inv)) for g in gs))
        self.t_h2d_s = 0.0                    # host->device staging time
        # --- activation-boundary offload (long-sequence memory wall) ---
        self.act_offload = bool(getattr(tcfg, "offload_activations", False))
        self._act_codec = activation_codec(
            getattr(tcfg, "activation_codec", "fp32"))
        self.act_store: Optional[ActivationStore] = None
        self._act_dtype = None                # device dtype of boundary acts
        self._act_tmp = False
        # measured boundary-activation residency (device boundaries held +
        # the spill store's bounded host buffers) — the mem-chain bench's
        # seq-len sweep reads this
        self.act_resident_peak_bytes = 0
        if self.act_offload:
            if grad_dir:
                self._act_dir = grad_dir.rstrip("/") + "-acts"
            else:
                self._act_dir = tempfile.mkdtemp(prefix="repro_acts_")
                self._act_tmp = True
        else:
            self._act_dir = ""
        self.grad_engine: Optional[OffloadEngine] = None
        if self.lora_mode:
            if adapter is None:
                raise ValueError(
                    "streamed LoRA needs the in-memory adapter state "
                    '{"lora", "opt", "step"} (see launch.train.'
                    "stream_lora_train_loop)")
            if not lstate.frozen:
                raise ValueError(
                    "streamed LoRA drives a frozen (param-only) base layout; "
                    "create it with LayerStreamedState.create_frozen")
            self.adapter = adapter
            self._upd = jax.jit(adamw_update)
            self._acc = None          # adapter-grad accumulator (in memory)
        else:
            if lstate.frozen:
                raise ValueError(
                    "frozen (param-only) layout carries no optimizer state; "
                    "Full-FT streaming needs the (p, m, v) layout")
            os.makedirs(grad_dir, exist_ok=True)
            self.grad_engine = OffloadEngine(
                make_grad_store(lstate, grad_dir,
                                io_backend=getattr(tcfg, "offload_io", "")),
                max_resident=max(1, tcfg.offload_resident),
                prefetch=tcfg.offload_prefetch,
                async_writeback=getattr(tcfg, "offload_async_writeback",
                                        True))

    # ------------------------------------------------------------------
    # adapter plumbing (PEFT mode)
    # ------------------------------------------------------------------
    def adapter_state(self) -> Dict[str, Any]:
        """The trainable state {"lora", "opt", "step"} — what adapter-only
        checkpoints persist (the frozen base is re-derived from the seed)."""
        return self.adapter

    def _lora_split(self):
        """(stacked block adapter tree, head adapter tree)."""
        lora = self.adapter["lora"]
        blocks = lora.get("blocks", {})
        head = {k: v for k, v in lora.items() if k != "blocks"}
        return blocks, head

    @staticmethod
    def _block_lora(lblocks, i: int):
        """Slice block ``i``'s adapter factors off the stacked tree."""
        return jax.tree.map(lambda a: a[i], lblocks)

    # ------------------------------------------------------------------
    # device staging (double-buffered host->device pipeline)
    # ------------------------------------------------------------------
    def _timed_pull(self, fn):
        """Run a window pull + device conversion, billing only the
        *conversion* share to ``t_h2d_s`` — the engine already bills its
        own acquire wait to ``t_read_block_s``/``t_write_block_s``, and
        the breakdown's components must not double-count."""
        eng = self.lstate.engine
        t0 = time.perf_counter()
        b0 = eng.t_read_block_s + eng.t_write_block_s
        out = fn()
        blocked = (eng.t_read_block_s + eng.t_write_block_s) - b0
        self.t_h2d_s += max(0.0, (time.perf_counter() - t0) - blocked)
        return out

    def _stage_layer(self, i: int):
        """Convert block ``i``'s window leaves to device arrays *now* —
        called right after the previous block's compute is dispatched, so
        the window pull + host->device copy overlap that compute.  Bounded
        to two staged blocks (the one consumed next and this one)."""
        if not self.staging or not (0 <= i < self.lstate.n_layers):
            return
        if i in self._staged:
            return
        self._staged[i] = self._timed_pull(
            lambda: self.lstate.layer_params(i))
        while len(self._staged) > 2:
            self._staged.pop(next(iter(self._staged)))

    def _block_params(self, i: int):
        """Block ``i``'s device param tree: the staged copy when the
        pipeline ran ahead, else a synchronous pull + convert."""
        bp = self._staged.pop(i, None)
        if bp is not None:
            return bp
        return self._timed_pull(lambda: self.lstate.layer_params(i))

    def _head_params(self):
        """The head device tree, staged once per step (once per run for a
        frozen base — its bytes never change): re-converting embed/ln_f per
        micro-batch was repeated host->device traffic.  Full-FT mode drops
        the cache after each update sweep (the head segment mutates)."""
        if not self.staging:
            return self.lstate.head_params()
        if self._head_dev is None:
            self._head_dev = self._timed_pull(self.lstate.head_params)
        return self._head_dev

    def _positions(self, b: int, s: int):
        if (b, s) not in self._pos_cache:
            self._pos_cache[(b, s)] = self.program.positions(b, s)
        return self._pos_cache[(b, s)]

    # ------------------------------------------------------------------
    # activation-boundary offload (repro/offload/act_store.py)
    # ------------------------------------------------------------------
    def _ensure_act_store(self, x):
        """Lazily (re)build the per-step activation scratch store once the
        boundary geometry (B, S, D) is known — at the first forward sweep,
        or when the batch shape changes (train -> eval geometry)."""
        self.act_store = act_store_for(
            self._act_dir, self.lstate.n_layers, x.shape, self._act_codec,
            existing=self.act_store,
            io_backend=getattr(self.tcfg, "offload_io", ""))
        self._act_dtype = x.dtype

    def _act_sink(self, i: int, x):  # hot-path
        """Spill boundary ``i`` (block ``i``'s device input) to the store —
        called right after block ``i``'s compute is dispatched, so the
        device->host pull and the background write ride behind it."""
        a = np.asarray(x)  # sync-point: the boundary spill is a D2H pull
        #                    by design (waits on block i-1's output only —
        #                    block i's in-flight compute keeps overlapping)
        self.act_store.sink(i, a)

    def _act_take(self, i: int):  # hot-path
        """Boundary ``i`` back on device for block ``i``'s VJP: write-queue
        steal / reverse-order prefetch hit / sync read, then one
        host->device conversion; the host buffer recycles into the
        prefetcher's pool."""
        arr = self.act_store.take(i)
        a = jnp.asarray(arr, self._act_dtype)
        self.act_store.recycle(i, arr)
        return a

    def _act_note(self, acts, live: int = 0):  # hot-path
        """Sample the measured boundary-activation residency: device
        boundaries still held (non-None ``acts`` entries + ``live`` working
        bytes) plus the spill store's bounded host buffers."""
        held = live + sum(a.nbytes for a in acts if a is not None)
        if self.act_store is not None:
            held += self.act_store.inflight_bytes()
        if held > self.act_resident_peak_bytes:
            self.act_resident_peak_bytes = held

    # ------------------------------------------------------------------
    # hot-path
    def _sink(self, seg: int, names: List[str], grads: List[Any],
              first: bool, last: bool, n_micro: int):
        """Accumulate one segment's gradient leaves into the scratch store;
        on the last micro-batch return this segment's contribution to
        ||g/n||^2 (the averaged-gradient global norm) as a *device scalar*
        — the sync defers to the end of the step."""
        gdata = self.grad_engine.acquire(seg)
        for n, g in zip(names, grads):
            g = np.asarray(g, np.float32)  # sync-point: grads land in the
            #                                host scratch store by design
            if first:
                gdata[n][...] = g
            else:
                gdata[n] += g
        self.grad_engine.mark_dirty(seg)
        if not last:
            return 0.0
        if n_micro == 1:
            # the device gradients ARE the average: reduce them where they
            # already live, no host round trip
            return self._sumsq(list(grads), jnp.float32(1.0))
        return self._sumsq([gdata[n] for n in names],
                           jnp.float32(1.0 / n_micro))

    def _forward_sweep(self, mb, keep_acts: bool):  # hot-path
        """Stream the blocks forward as a three-deep pipeline: while block
        ``i`` computes (dispatch is asynchronous), block ``i+1`` converts
        host->device and block ``i+2`` pages in from flash.  Returns
        (head, acts, aux_sum, positions); ``acts`` holds the L+1
        layer-boundary activations when ``keep_acts`` (for the backward
        sweep), else just the final one."""
        prog, lstate = self.program, self.lstate
        head = self._head_params()
        if self.lora_mode:
            lblocks, lhead = self._lora_split()
            x = prog.embed(head, lhead, mb)
        else:
            x = prog.embed(head, mb)
        positions = self._positions(x.shape[0], x.shape[1])
        spill = keep_acts and self.act_offload
        if spill:
            self._ensure_act_store(x)
        acts = [x]
        aux_sum = jnp.zeros((), jnp.float32)
        lstate.prefetch_layer(0)
        for i in range(lstate.n_layers):
            if i + 1 < lstate.n_layers:
                lstate.prefetch_layer(i + 1)   # pages in while i computes
            elif not self.staging:
                # pre-staging path re-acquires the head every micro-batch,
                # so warm it; the staged path holds the head device tree for
                # the whole step and never re-acquires — prefetching it
                # would strand an unclaimed buffer in the pipeline
                lstate.prefetch_layer(lstate.head_segment)
            bp = self._block_params(i)
            win = self._windows_dev[i]
            x_in = x
            if self.lora_mode:
                x, aux = prog.block(bp, self._block_lora(lblocks, i), x_in,
                                    win, positions)
            else:
                x, aux = prog.block(bp, x_in, win, positions)
            # block i's compute is in flight: stage i+1's device copy now
            self._stage_layer(i + 1)
            if spill:
                # ... and spill boundary i behind it: only the running
                # boundary (and the final acts[L] the head VJP consumes)
                # stay device-resident — resident acts stop scaling with L
                self._act_sink(i, x_in)
                acts[0] = None
                acts.append(x if i + 1 == lstate.n_layers else None)
            elif keep_acts:
                acts.append(x)
            else:
                acts[0] = x
            if keep_acts:
                self._act_note(acts, live=x_in.nbytes if spill else 0)
            aux_sum = aux_sum + aux
        return head, acts, aux_sum, positions

    def _two_sweeps(self, mb, first: bool, last: bool, n_micro: int):  # hot-path
        """Forward + backward over one micro-batch.  Returns
        (loss, metrics, sq_norm_contribution)."""
        if self.lora_mode:
            return self._two_sweeps_lora(mb, first, last, n_micro)
        prog, lstate = self.program, self.lstate
        L = lstate.n_layers
        head, acts, aux_sum, positions = self._forward_sweep(
            mb, keep_acts=True)

        # ---- head loss + its VJP ----------------------------------------
        loss, metrics, dhead, dx, daux = prog.head_vjp(head, acts[L], mb,
                                                       aux_sum)

        # ---- backward sweep: re-pull each block, VJP, sink grads --------
        sq = 0.0
        lstate.prefetch_layer(L - 1)
        self.grad_engine.prefetch(L - 1)
        if self.act_offload:
            self.act_store.prefetch(L - 1)
        for i in reversed(range(L)):
            lstate.prefetch_layer(i - 1)
            self.grad_engine.prefetch(
                i - 1 if i > 0 else lstate.head_segment)
            if self.act_offload and i > 0:
                # boundary i-1 pages back in while block i's VJP runs
                self.act_store.prefetch(i - 1)
            bp = self._block_params(i)
            a_in = acts[i] if acts[i] is not None else self._act_take(i)
            self._act_note(acts, live=a_in.nbytes)
            dp, dx = prog.block_vjp(bp, a_in, self._windows_dev[i],
                                    positions, dx, daux)
            # the VJP is in flight: stage block i-1 while it computes
            self._stage_layer(i - 1)
            acts[i + 1] = None             # free the boundary activation
            names = [f"blocks.{i}.{n}" for n in lstate.block_names]
            sq = sq + self._sink(i, names, jax.tree.leaves(dp), first, last,
                                 n_micro)

        # embed's contribution lands on the same head tree as the unembed's
        dhead_e = prog.embed_vjp(head, mb, dx)
        dhead = jax.tree.map(jnp.add, dhead, dhead_e)
        sq = sq + self._sink(lstate.head_segment, lstate.head_names,
                             jax.tree.leaves(dhead), first, last, n_micro)
        return loss, metrics, sq

    def _two_sweeps_lora(self, mb, first: bool, last: bool, n_micro: int):  # hot-path
        """PEFT variant: base segments are read-only; the backward sweep
        returns adapter cotangents which accumulate in memory (the adapter
        is tiny — no scratch segments needed)."""
        prog, lstate = self.program, self.lstate
        L = lstate.n_layers
        lblocks, lhead = self._lora_split()
        head, acts, aux_sum, positions = self._forward_sweep(
            mb, keep_acts=True)

        # ---- head loss + its VJP (adapter cotangent only) ---------------
        loss, metrics, dhl, dx, daux = prog.head_vjp(head, lhead, acts[L],
                                                     mb, aux_sum)

        # ---- backward sweep: re-pull frozen blocks, collect adapter grads
        block_grads: List[Any] = [None] * L
        lstate.prefetch_layer(L - 1)
        if self.act_offload:
            self.act_store.prefetch(L - 1)
        for i in reversed(range(L)):
            lstate.prefetch_layer(i - 1)
            if self.act_offload and i > 0:
                # boundary i-1 pages back in while block i's VJP runs
                self.act_store.prefetch(i - 1)
            bp = self._block_params(i)
            a_in = acts[i] if acts[i] is not None else self._act_take(i)
            self._act_note(acts, live=a_in.nbytes)
            dlp, dx = prog.block_vjp(bp, self._block_lora(lblocks, i),
                                     a_in, self._windows_dev[i],
                                     positions, dx, daux)
            self._stage_layer(i - 1)       # overlap the VJP in flight
            acts[i + 1] = None             # free the boundary activation
            block_grads[i] = dlp

        # embed's adapter contribution joins the unembed's
        dhl_e = prog.embed_vjp(head, lhead, mb, dx)
        dhl = jax.tree.map(jnp.add, dhl, dhl_e)

        # re-stack per-block adapter grads into the adapter's stacked layout
        g = dict(dhl)
        if "blocks" in self.adapter["lora"]:
            g["blocks"] = jax.tree.map(lambda *gs: jnp.stack(gs),
                                       *block_grads)
        self._acc = (g if first else
                     jax.tree.map(jnp.add, self._acc, g))

        sq = 0.0
        if last:
            # device-side reduction; the only sync is the end-of-step float
            sq = self._sumsq(jax.tree.leaves(self._acc),
                             jnp.float32(1.0 / n_micro))
        return loss, metrics, sq

    def _update_sweep(self, lr, clip_scale: float, n_micro: int):  # hot-path
        """Stream (p, m, v) + grad segments and AdamW each in place.  The
        sweep is software-pipelined one segment deep (window permitting):
        segment ``i``'s dispatched AdamW computes while segment ``i+1``'s
        (p, m, v) + grads pull in and convert, and only then is ``i``
        forced and stored back — the same overlap discipline as the
        forward/backward sweeps."""
        lstate, tcfg = self.lstate, self.tcfg
        count = jnp.asarray(lstate.count, jnp.int32)
        # the pending segment must still be resident when its results are
        # stored, so pipelining needs two window slots
        pipelined = lstate.engine.max_resident >= 2
        lstate.engine.prefetch(0)
        self.grad_engine.prefetch(0)
        pending = None
        for seg in range(lstate.store.num_segments):
            lstate.engine.prefetch(seg + 1)
            self.grad_engine.prefetch(seg + 1)
            gdata = self.grad_engine.acquire(seg)
            gnamed = {}
            for n in lstate.seg_param_names(seg):
                g = jnp.asarray(gdata[n], jnp.float32)
                if n_micro > 1:
                    g = g / n_micro
                gnamed[n] = g * clip_scale
            nxt = lstate._update_segment_dispatch(
                seg, gnamed, count, lr=lr, beta1=tcfg.beta1,
                beta2=tcfg.beta2, eps=tcfg.eps,
                weight_decay=tcfg.weight_decay)
            if pending is not None:
                lstate._update_segment_store(pending)
            if pipelined:
                pending = nxt
            else:
                lstate._update_segment_store(nxt)
        if pending is not None:
            lstate._update_segment_store(pending)
        lstate.finish_step()
        # every param segment just mutated: staged device copies (and the
        # head tree) are one step stale now
        self._staged.clear()
        self._head_dev = None

    def _update_adapter(self, lr, clip_scale: float, n_micro: int):
        """One in-memory AdamW over the accumulated adapter gradients —
        the very update ``make_train_step`` applies in LoRA mode."""
        tcfg = self.tcfg
        grads = jax.tree.map(
            lambda a: (a / n_micro if n_micro > 1 else a) * clip_scale,
            self._acc)
        new_lora, new_opt = self._upd(
            grads, self.adapter["opt"], self.adapter["lora"], lr=lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay)
        self.adapter["lora"] = new_lora
        self.adapter["opt"] = new_opt
        self.adapter["step"] = self.adapter["step"] + 1
        self._acc = None

    # ------------------------------------------------------------------
    def __call__(self, batch, step: int):  # hot-path
        tcfg = self.tcfg
        n = tcfg.microbatches
        micros = split_batch(batch, n) if n > 1 else None
        loss_sum, metrics, sq = 0.0, None, 0.0
        for j in range(n):
            mb = (jax.tree.map(lambda a: a[j], micros) if n > 1 else batch)
            loss, metrics, s = self._two_sweeps(mb, j == 0, j == n - 1, n)
            loss_sum = loss_sum + loss     # device scalar until step end
            sq = sq + s
        # the one host sync of the step: clipping needs the global norm
        gnorm = math.sqrt(float(sq))  # sync-point: the step's one sync
        if tcfg.grad_clip > 0:
            clip_scale = min(1.0, tcfg.grad_clip / max(gnorm, 1e-9))
        else:
            clip_scale = 1.0
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        if self.lora_mode:
            self._update_adapter(lr, clip_scale, n)
        else:
            self._update_sweep(lr, clip_scale, n)
        metrics = dict(metrics)
        metrics["loss"] = float(loss_sum) / n  # sync-point: post-update,
        #                                        nothing left to overlap
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return metrics["loss"], metrics

    # ------------------------------------------------------------------
    def loss_only(self, batch):
        """Streamed forward pass (no grads, no update) — eval.  Returns
        (loss, metrics)."""
        head, acts, aux_sum, _ = self._forward_sweep(batch, keep_acts=False)
        if self.lora_mode:
            _, lhead = self._lora_split()
            return self.program.head_loss(head, lhead, acts[0], batch,
                                          aux_sum)
        return self.program.head_loss(head, acts[0], batch, aux_sum)

    def stats(self) -> Dict[str, Any]:
        s = {"param_" + k: v for k, v in self.lstate.stats().items()}
        if self.grad_engine is not None:
            s.update({"grad_" + k: v
                      for k, v in self.grad_engine.stats().items()})
        if self.act_store is not None:
            s.update({"act_" + k: v
                      for k, v in self.act_store.stats().items()})
        s["act_resident_peak_bytes"] = self.act_resident_peak_bytes
        s["stage_h2d_s"] = self.t_h2d_s
        return s

    def pipeline_stats(self) -> Dict[str, float]:
        """The overlap breakdown the throughput benchmark reports: seconds
        spent *blocked* on segment reads / write-backs plus the staging
        (host->device) time — everything else is compute the pipeline
        successfully hid I/O behind."""
        s = self.stats()
        out = {
            "read_block_s": float(s.get("param_t_read_block_s", 0.0))
            + float(s.get("grad_t_read_block_s", 0.0))
            + float(s.get("act_t_read_block_s", 0.0)),
            "write_block_s": float(s.get("param_t_write_block_s", 0.0))
            + float(s.get("grad_t_write_block_s", 0.0))
            + float(s.get("act_t_write_block_s", 0.0)),
            "stage_h2d_s": float(self.t_h2d_s),
            "writeback_busy_s": float(s.get("param_writeback_busy_s", 0.0))
            + float(s.get("grad_writeback_busy_s", 0.0))
            + float(s.get("act_writeback_busy_s", 0.0)),
        }
        hits = s.get("param_prefetch_hits", 0)
        loads = s.get("param_sync_loads", 0)
        out["prefetch_hit_rate"] = (hits / (hits + loads)
                                    if (hits + loads) else 1.0)
        if self.act_store is not None:
            out["act_hit_rate"] = self.act_store.hit_rate()
        return out

    def close(self):
        if self.grad_engine is not None:
            self.grad_engine.close()
        if self.act_store is not None:
            self.act_store.close()
            self.act_store = None
        if self._act_tmp and self._act_dir:
            shutil.rmtree(self._act_dir, ignore_errors=True)
