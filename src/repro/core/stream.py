"""Layer-streamed forward/backward over the offload engine (paper §4.1.1).

PR 1 realized C1's segment-wise offload for the *optimizer* stream only —
fwd/bwd still materialized the full parameter tree, so peak RSS during
compute scaled with model size.  This module closes that gap: model
execution is an explicit two-sweep program over layer-aligned segments
(``LayerStreamedState``: one segment per block + one head segment), driven
by the per-stage jitted entry points of ``repro.models.lm.make_layer_program``.

Forward sweep   pull block ``i``'s params through the LRU window (prefetching
                ``i+1`` while ``i`` computes), save only the layer-boundary
                activation, carry the MoE aux sum.
Backward sweep  walk blocks in reverse, re-pull each block's segment, replay
                its forward inside ``jax.vjp`` (layer-granular recompute) and
                sink the resulting per-block gradient into a layer-aligned
                *gradient scratch store* — gradients never form a full tree
                in RAM either.  A running sum of squares yields the global
                grad norm for clipping without a second pass.
Update sweep    stream (p, m, v) + grad segments jointly through their
                windows and apply the very same ``adamw_update`` per segment
                (shared count, clip scale folded into the gradients), so the
                math matches the in-memory jit path to fp re-association
                noise (equivalence-tested at 1e-5).

Peak resident params during compute: the head segment plus about
``offload_resident + 1`` layer segments — independent of ``n_layers``
(``repro.core.zero.stream_resident_bytes`` gives the analytic bound; the
mem-chain benchmark reports the measured one).

Gradient accumulation (C2) composes: each micro-batch runs its own two
sweeps and accumulates into the gradient scratch segments; the update sweep
then applies the averaged, clipped gradient once.

PEFT (C6) composes too: with ``tcfg.lora_rank > 0`` the base segments are a
*frozen, param-only* layout (``LayerStreamedState.create_frozen``) served
through a read-only window — no m/v segments, no dirty write-back, no
gradient scratch store.  The (tiny) LoRA adapter tree stays memory-resident;
``merge_lora`` is applied per block inside the jitted apply/VJP entry
points, adapter cotangents accumulate in memory, and one in-memory AdamW
updates the adapter after the sweeps.  Resident state drops to roughly a
third of the Full-FT streamed bound (``repro.core.zero``).

QLoRA composes on top (``tcfg.base_quant == "int8"``): the frozen base
segments are per-channel quantized (repro/offload/codecs.py) and the window
keeps them *encoded* — ``layer_params``/``head_params`` hand the program
(codes, scales) tree pairs and the jitted entry points dequantize per
block, so fp32 base weights only ever exist as XLA transients.
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.core.accumulate import split_batch
from repro.models import transformer as T
from repro.models.lm import make_layer_program
from repro.offload.engine import OffloadEngine
from repro.offload.segments import SegmentStore
from repro.offload.state import (LayerStreamedState, P,
                                 ensure_base_quant_match)
from repro.optim.adamw import adamw_update
from repro.optim.schedule import lr_schedule


def make_grad_store(lstate: LayerStreamedState, directory: str
                    ) -> SegmentStore:
    """Gradient scratch segments mirroring the param store's layer-aligned
    geometry (same segment <-> block mapping, fp32, params only — no
    moments).  Rewritten every step, and the first micro-batch overwrites
    every leaf, so the files are laid out sparse (``write=False``): no
    parameter-sized burst of zero writes at startup — this path targets
    flash-wear-sensitive devices."""
    groups, labels = [], []
    for seg in range(lstate.store.num_segments):
        groups.append([
            (n, np.zeros(lstate.store.record(P + n).shape, np.float32))
            for n in lstate.seg_param_names(seg)])
        labels.append(lstate.store.labels[seg])
    return SegmentStore.create(directory, groups, len(groups),
                               meta={"kind": "grad_scratch_v1"},
                               group_labels=labels, write=False)


class StreamedTrainStep:
    """One optimizer step = forward sweep + backward sweep (grads into the
    scratch store) per micro-batch, then one streamed AdamW update sweep.

    ``step_fn(batch, step) -> (loss, metrics)`` — the streamed counterpart
    of ``make_train_step``'s jitted body, matching its schedule, clipping
    and AdamW semantics.

    With ``tcfg.lora_rank > 0`` (PEFT over a frozen streamed base):
    ``lstate`` must be the frozen param-only layout and ``adapter`` supplies
    the memory-resident trainable state ``{"lora", "opt", "step"}``.  The
    backward sweep then returns adapter cotangents (stacked back into the
    adapter's layout in memory — no scratch segments), and the update is a
    single in-memory AdamW over the adapter tree.
    """

    def __init__(self, cfg: ModelConfig, tcfg: TrainConfig,
                 lstate: LayerStreamedState, grad_dir: str,
                 adapter: Optional[Dict[str, Any]] = None):
        if tcfg.microbatches < 1:
            raise ValueError(
                f"microbatches must be >= 1, got {tcfg.microbatches}; pass "
                "--microbatches 1 to disable gradient accumulation")
        self.cfg, self.tcfg = cfg, tcfg
        self.lstate = lstate
        self.lora_mode = tcfg.lora_rank > 0
        ensure_base_quant_match(lstate, tcfg.base_quant)
        self.program = make_layer_program(cfg, tcfg)
        self.windows = np.asarray(T.layer_windows(cfg))
        self.grad_engine: Optional[OffloadEngine] = None
        if self.lora_mode:
            if adapter is None:
                raise ValueError(
                    "streamed LoRA needs the in-memory adapter state "
                    '{"lora", "opt", "step"} (see launch.train.'
                    "stream_lora_train_loop)")
            if not lstate.frozen:
                raise ValueError(
                    "streamed LoRA drives a frozen (param-only) base layout; "
                    "create it with LayerStreamedState.create_frozen")
            self.adapter = adapter
            self._upd = jax.jit(adamw_update)
            self._acc = None          # adapter-grad accumulator (in memory)
        else:
            if lstate.frozen:
                raise ValueError(
                    "frozen (param-only) layout carries no optimizer state; "
                    "Full-FT streaming needs the (p, m, v) layout")
            os.makedirs(grad_dir, exist_ok=True)
            self.grad_engine = OffloadEngine(
                make_grad_store(lstate, grad_dir),
                max_resident=max(1, tcfg.offload_resident),
                prefetch=tcfg.offload_prefetch)

    # ------------------------------------------------------------------
    # adapter plumbing (PEFT mode)
    # ------------------------------------------------------------------
    def adapter_state(self) -> Dict[str, Any]:
        """The trainable state {"lora", "opt", "step"} — what adapter-only
        checkpoints persist (the frozen base is re-derived from the seed)."""
        return self.adapter

    def _lora_split(self):
        """(stacked block adapter tree, head adapter tree)."""
        lora = self.adapter["lora"]
        blocks = lora.get("blocks", {})
        head = {k: v for k, v in lora.items() if k != "blocks"}
        return blocks, head

    @staticmethod
    def _block_lora(lblocks, i: int):
        """Slice block ``i``'s adapter factors off the stacked tree."""
        return jax.tree.map(lambda a: a[i], lblocks)

    # ------------------------------------------------------------------
    def _sink(self, seg: int, names: List[str], grads: List[Any],
              first: bool, last: bool, n_micro: int) -> float:
        """Accumulate one segment's gradient leaves into the scratch store;
        on the last micro-batch return this segment's contribution to
        ||g/n||^2 (the averaged-gradient global norm)."""
        gdata = self.grad_engine.acquire(seg)
        sq = 0.0
        for n, g in zip(names, grads):
            g = np.asarray(g, np.float32)
            if first:
                gdata[n][...] = g
            else:
                gdata[n] += g
            if last:
                avg = gdata[n] / n_micro if n_micro > 1 else gdata[n]
                sq += float(np.sum(np.square(avg, dtype=np.float32),
                                   dtype=np.float32))
        self.grad_engine.mark_dirty(seg)
        return sq

    def _forward_sweep(self, mb, keep_acts: bool):
        """Stream the blocks forward, prefetching ``i+1`` while ``i``
        computes.  Returns (head, acts, aux_sum, positions); ``acts`` holds
        the L+1 layer-boundary activations when ``keep_acts`` (for the
        backward sweep), else just the final one."""
        prog, lstate = self.program, self.lstate
        head = lstate.head_params()
        if self.lora_mode:
            lblocks, lhead = self._lora_split()
            x = prog.embed(head, lhead, mb)
        else:
            x = prog.embed(head, mb)
        positions = prog.positions(x.shape[0], x.shape[1])
        acts = [x]
        aux_sum = jnp.zeros((), jnp.float32)
        lstate.prefetch_layer(0)
        for i in range(lstate.n_layers):
            lstate.prefetch_layer(i + 1)   # i+1 pages in while i computes
            bp = lstate.layer_params(i)
            win = jnp.asarray(self.windows[i])
            if self.lora_mode:
                x, aux = prog.block(bp, self._block_lora(lblocks, i), x, win,
                                    positions)
            else:
                x, aux = prog.block(bp, x, win, positions)
            if keep_acts:
                acts.append(x)
            else:
                acts[0] = x
            aux_sum = aux_sum + aux
        return head, acts, aux_sum, positions

    def _two_sweeps(self, mb, first: bool, last: bool, n_micro: int):
        """Forward + backward over one micro-batch.  Returns
        (loss, metrics, sq_norm_contribution)."""
        if self.lora_mode:
            return self._two_sweeps_lora(mb, first, last, n_micro)
        prog, lstate = self.program, self.lstate
        L = lstate.n_layers
        head, acts, aux_sum, positions = self._forward_sweep(
            mb, keep_acts=True)

        # ---- head loss + its VJP ----------------------------------------
        loss, metrics, dhead, dx, daux = prog.head_vjp(head, acts[L], mb,
                                                       aux_sum)

        # ---- backward sweep: re-pull each block, VJP, sink grads --------
        sq = 0.0
        lstate.prefetch_layer(L - 1)
        self.grad_engine.prefetch(L - 1)
        for i in reversed(range(L)):
            lstate.prefetch_layer(i - 1)
            self.grad_engine.prefetch(
                i - 1 if i > 0 else lstate.head_segment)
            bp = lstate.layer_params(i)
            dp, dx = prog.block_vjp(bp, acts[i],
                                    jnp.asarray(self.windows[i]), positions,
                                    dx, daux)
            acts[i + 1] = None             # free the boundary activation
            names = [f"blocks.{i}.{n}" for n in lstate.block_names]
            sq += self._sink(i, names, jax.tree.leaves(dp), first, last,
                             n_micro)

        # embed's contribution lands on the same head tree as the unembed's
        dhead_e = prog.embed_vjp(head, mb, dx)
        dhead = jax.tree.map(jnp.add, dhead, dhead_e)
        sq += self._sink(lstate.head_segment, lstate.head_names,
                         jax.tree.leaves(dhead), first, last, n_micro)
        return loss, metrics, sq

    def _two_sweeps_lora(self, mb, first: bool, last: bool, n_micro: int):
        """PEFT variant: base segments are read-only; the backward sweep
        returns adapter cotangents which accumulate in memory (the adapter
        is tiny — no scratch segments needed)."""
        prog, lstate = self.program, self.lstate
        L = lstate.n_layers
        lblocks, lhead = self._lora_split()
        head, acts, aux_sum, positions = self._forward_sweep(
            mb, keep_acts=True)

        # ---- head loss + its VJP (adapter cotangent only) ---------------
        loss, metrics, dhl, dx, daux = prog.head_vjp(head, lhead, acts[L],
                                                     mb, aux_sum)

        # ---- backward sweep: re-pull frozen blocks, collect adapter grads
        block_grads: List[Any] = [None] * L
        lstate.prefetch_layer(L - 1)
        for i in reversed(range(L)):
            lstate.prefetch_layer(i - 1)
            bp = lstate.layer_params(i)
            dlp, dx = prog.block_vjp(bp, self._block_lora(lblocks, i),
                                     acts[i], jnp.asarray(self.windows[i]),
                                     positions, dx, daux)
            acts[i + 1] = None             # free the boundary activation
            block_grads[i] = dlp

        # embed's adapter contribution joins the unembed's
        dhl_e = prog.embed_vjp(head, lhead, mb, dx)
        dhl = jax.tree.map(jnp.add, dhl, dhl_e)

        # re-stack per-block adapter grads into the adapter's stacked layout
        g = dict(dhl)
        if "blocks" in self.adapter["lora"]:
            g["blocks"] = jax.tree.map(lambda *gs: jnp.stack(gs),
                                       *block_grads)
        self._acc = (g if first else
                     jax.tree.map(jnp.add, self._acc, g))

        sq = 0.0
        if last:
            for leaf in jax.tree.leaves(self._acc):
                avg = np.asarray(leaf, np.float32)
                if n_micro > 1:
                    avg = avg / n_micro
                sq += float(np.sum(np.square(avg, dtype=np.float32),
                                   dtype=np.float32))
        return loss, metrics, sq

    def _update_sweep(self, lr, clip_scale: float, n_micro: int):
        """Stream (p, m, v) + grad segments and AdamW each in place."""
        lstate, tcfg = self.lstate, self.tcfg
        count = jnp.asarray(lstate.count, jnp.int32)
        lstate.engine.prefetch(0)
        self.grad_engine.prefetch(0)
        for seg in range(lstate.store.num_segments):
            lstate.engine.prefetch(seg + 1)
            self.grad_engine.prefetch(seg + 1)
            gdata = self.grad_engine.acquire(seg)
            gnamed = {}
            for n in lstate.seg_param_names(seg):
                g = jnp.asarray(gdata[n], jnp.float32)
                if n_micro > 1:
                    g = g / n_micro
                gnamed[n] = g * clip_scale
            lstate._update_segment(seg, gnamed, count, lr=lr,
                                   beta1=tcfg.beta1, beta2=tcfg.beta2,
                                   eps=tcfg.eps,
                                   weight_decay=tcfg.weight_decay)
        lstate.finish_step()

    def _update_adapter(self, lr, clip_scale: float, n_micro: int):
        """One in-memory AdamW over the accumulated adapter gradients —
        the very update ``make_train_step`` applies in LoRA mode."""
        tcfg = self.tcfg
        grads = jax.tree.map(
            lambda a: (a / n_micro if n_micro > 1 else a) * clip_scale,
            self._acc)
        new_lora, new_opt = self._upd(
            grads, self.adapter["opt"], self.adapter["lora"], lr=lr,
            beta1=tcfg.beta1, beta2=tcfg.beta2, eps=tcfg.eps,
            weight_decay=tcfg.weight_decay)
        self.adapter["lora"] = new_lora
        self.adapter["opt"] = new_opt
        self.adapter["step"] = self.adapter["step"] + 1
        self._acc = None

    # ------------------------------------------------------------------
    def __call__(self, batch, step: int):
        tcfg = self.tcfg
        n = tcfg.microbatches
        micros = split_batch(batch, n) if n > 1 else None
        loss_sum, metrics, sq = 0.0, None, 0.0
        for j in range(n):
            mb = (jax.tree.map(lambda a: a[j], micros) if n > 1 else batch)
            loss, metrics, s = self._two_sweeps(mb, j == 0, j == n - 1, n)
            loss_sum += float(loss)
            sq += s
        gnorm = math.sqrt(sq)
        if tcfg.grad_clip > 0:
            clip_scale = min(1.0, tcfg.grad_clip / max(gnorm, 1e-9))
        else:
            clip_scale = 1.0
        lr = lr_schedule(jnp.asarray(step, jnp.int32),
                         base_lr=tcfg.learning_rate,
                         warmup_steps=tcfg.warmup_steps,
                         total_steps=tcfg.total_steps, kind=tcfg.schedule)
        if self.lora_mode:
            self._update_adapter(lr, clip_scale, n)
        else:
            self._update_sweep(lr, clip_scale, n)
        metrics = dict(metrics)
        metrics["loss"] = loss_sum / n
        metrics["grad_norm"] = gnorm
        metrics["lr"] = lr
        return metrics["loss"], metrics

    # ------------------------------------------------------------------
    def loss_only(self, batch):
        """Streamed forward pass (no grads, no update) — eval.  Returns
        (loss, metrics)."""
        head, acts, aux_sum, _ = self._forward_sweep(batch, keep_acts=False)
        if self.lora_mode:
            _, lhead = self._lora_split()
            return self.program.head_loss(head, lhead, acts[0], batch,
                                          aux_sum)
        return self.program.head_loss(head, acts[0], batch, aux_sum)

    def stats(self) -> Dict[str, Any]:
        s = {"param_" + k: v for k, v in self.lstate.stats().items()}
        if self.grad_engine is not None:
            s.update({"grad_" + k: v
                      for k, v in self.grad_engine.stats().items()})
        return s

    def close(self):
        if self.grad_engine is not None:
            self.grad_engine.close()
