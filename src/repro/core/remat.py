"""Activation checkpointing policies (paper §4.1.3, C3).

The paper stores a subset of activations at strategic points and recomputes
the rest during backprop.  Here the "strategic point" is the scanned layer
boundary: with policy ``full`` only each layer's input survives the forward
pass; ``dots`` additionally saves matmul outputs (XLA's dots_saveable) —
cheaper recompute at higher memory; ``none`` disables checkpointing (the
paper's ②-off baseline).
"""
from __future__ import annotations

import jax


POLICIES = ("none", "dots", "full", "offload")


def maybe_remat(fn, policy: str):
    if policy in (None, "", "none"):
        return fn
    if policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if policy == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    if policy == "offload":
        # save-nothing + rely on scheduler; placeholder for host-offload tier
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(f"unknown remat policy {policy!r}")
