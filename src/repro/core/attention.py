"""Memory-efficient exact attention (paper §4.1.4, C4).

Three interchangeable implementations of *exact* softmax attention:

  naive      materializes the [B, H, Sq, Skv] score matrix — the paper's
             "unoptimized" baseline (①-off in the optimization-chain study).
  streaming  chunked online-softmax over KV blocks via ``lax.scan`` — the
             paper's row-streaming C++ operator re-blocked for vector units.
             Never materializes more than [B, Sq, H, chunk] scores.  Used for
             CPU tests and for the AOT dry-run lowering.
  flash      Pallas TPU kernel (kernels/flash_attention) — the TPU-native
             adaptation: 128-aligned query-block x key-block tiles staged
             through VMEM for the MXU, same online-softmax algorithm, and a
             recompute backward exactly as §4.1.4 prescribes.

All support GQA/MQA (grouped KV heads), causal masking, sliding windows,
padding masks via position sentinels, and decode (Sq=1 against a long cache).

Shapes: q (B, Sq, H, D); k, v (B, Skv, KVH, D); H % KVH == 0.
Positions: q_pos (B, Sq) int32 absolute positions; kv_pos (B, Skv).  A kv
position >= SENTINEL marks padding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SENTINEL = jnp.iinfo(jnp.int32).max // 2
NEG_INF = -1e30


def default_positions(batch: int, seq: int, offset=0):
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq)).astype(jnp.int32)


def _mask(q_pos, kv_pos, causal: bool, window):
    """(B, Sq, Skv) bool — True = attend.

    ``window`` may be a python int or a traced scalar (hybrid models select
    full-vs-sliding per scanned layer); window <= 0 means no windowing.
    """
    valid = (kv_pos < SENTINEL)[:, None, :]
    m = valid
    if causal:
        m = m & (q_pos[:, :, None] >= kv_pos[:, None, :])
    if isinstance(window, int):
        if window > 0:
            m = m & (q_pos[:, :, None] - kv_pos[:, None, :] < window)
    else:
        wm = (q_pos[:, :, None] - kv_pos[:, None, :] < jnp.maximum(window, 1))
        m = m & (wm | (window <= 0))
    return m


def _group(q, kvh: int):
    b, sq, h, d = q.shape
    return q.reshape(b, sq, kvh, h // kvh, d)


def attention(q, k, v, *, q_pos=None, kv_pos=None, causal=True, window=0,
              impl="streaming", chunk=512, interpret=False):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    assert h % kvh == 0, (h, kvh)
    if q_pos is None:
        q_pos = default_positions(b, sq, offset=skv - sq)
    if kv_pos is None:
        kv_pos = default_positions(b, skv)

    if impl == "naive":
        return _naive(q, k, v, q_pos, kv_pos, causal, window)
    if impl in ("streaming", "ref"):
        # "ref" aliases the streaming path: it is the numerics oracle the
        # flash Pallas kernel is validated against (tests + benches)
        return _streaming(q, k, v, q_pos, kv_pos, causal, window, chunk)
    if impl == "flash":
        if not isinstance(window, int):
            # scanned-layer drivers carry the per-layer sliding window as a
            # traced scalar, but the Pallas grid/skip structure specializes
            # on it — those layers ride the exact streaming oracle instead
            # (full-attention configs pass a static 0 and hit the kernel)
            return _streaming(q, k, v, q_pos, kv_pos, causal, window, chunk)
        from repro.kernels.flash_attention import ops as flash_ops
        # the Pallas kernel has no CPU lowering — interpret mode is the
        # correct (and only) execution path on the CPU backend, so gate it
        # on the backend instead of making every caller thread the flag
        interpret = interpret or jax.default_backend() == "cpu"
        return flash_ops.flash_attention(
            q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=causal,
            window=window, interpret=interpret)
    raise ValueError(f"unknown attention impl {impl!r}")


# ----------------------------------------------------------------------------
# naive — the paper's unoptimized baseline (materializes S x S)
# ----------------------------------------------------------------------------
def _naive(q, k, v, q_pos, kv_pos, causal, window):
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    scale = d ** -0.5
    qg = _group(q, kvh)                                   # (B,Sq,KVH,G,D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale     # (B,KVH,G,Sq,Skv)
    m = _mask(q_pos, kv_pos, causal, window)              # (B,Sq,Skv)
    scores = jnp.where(m[:, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, d).astype(q.dtype)


# ----------------------------------------------------------------------------
# streaming — chunked online softmax (paper C4).  Double-blocked: an outer
# map over query blocks bounds intermediates at O(q_chunk * kv_chunk) scores
# (the TPU re-blocking of the paper's row-at-a-time streaming).
# ----------------------------------------------------------------------------
def _streaming(q, k, v, q_pos, kv_pos, causal, window, chunk):
    b, sq, h, d = q.shape
    q_chunk = max(chunk // 2, 1)
    if sq <= q_chunk:
        return _streaming_qblock(q, k, v, q_pos, kv_pos, causal, window, chunk)
    nq = -(-sq // q_chunk)
    pad = nq * q_chunk - sq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, ((0, 0), (0, pad)), constant_values=0)
    qs = q.reshape(b, nq, q_chunk, h, d).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(b, nq, q_chunk).transpose(1, 0, 2)

    def one(args):
        qc, pc = args
        return _streaming_qblock(qc, k, v, pc, kv_pos, causal, window, chunk)

    out = jax.lax.map(one, (qs, ps))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_chunk, h, d)
    return out[:, :sq]


def _streaming_qblock(q, k, v, q_pos, kv_pos, causal, window, chunk):
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = d ** -0.5
    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=SENTINEL)

    qg = _group(q, kvh).astype(jnp.float32) * scale       # (B,Sq,KVH,G,D)
    ks = k.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, n_chunks, chunk, kvh, d).transpose(1, 0, 2, 3, 4)
    ps = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, inputs):
        acc, mx, denom = carry
        kc, vc, pc = inputs                               # (B,C,KVH,D),(B,C)
        s = jnp.einsum("bqkgd,bckd->bqkgc", qg, kc.astype(jnp.float32))
        m = _mask(q_pos, pc, causal, window)              # (B,Sq,C)
        s = jnp.where(m[:, :, None, None], s, NEG_INF)
        new_mx = jnp.maximum(mx, jnp.max(s, axis=-1))
        corr = jnp.exp(mx - new_mx)
        p = jnp.exp(s - new_mx[..., None])
        denom = denom * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vc.astype(jnp.float32))
        return (acc, new_mx, denom), None

    acc0 = jnp.zeros((b, sq, kvh, g, d), jnp.float32)
    mx0 = jnp.full((b, sq, kvh, g), NEG_INF, jnp.float32)
    dn0 = jnp.zeros((b, sq, kvh, g), jnp.float32)
    (acc, _, denom), _ = jax.lax.scan(body, (acc0, mx0, dn0), (ks, vs, ps))
    out = acc / jnp.maximum(denom[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)
