"""Energy-aware computation scheduling (paper §4.2, C5).

Controller reproduced verbatim: a PowerMonitor checks the energy budget every
K steps; when the level drops below threshold mu, computation frequency is
reduced by rho — implemented, exactly as in the paper, by injecting a sleep
delay so the per-step interval stretches from t to t / (1 - rho).

Hardware adaptation: phones read a battery percentage; a TPU pod host reads a
power/thermal budget (or a preemption signal on spot reservations).  The
signal source is pluggable — ``SimulatedBattery`` models the paper's battery
drain (used by the Fig-11 benchmark); ``HostBudget`` binds to a host metric.
The governor also doubles as a pacing device for straggler mitigation: a
host that throttles still advances in lockstep, just at lower frequency.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List


@dataclass
class SimulatedBattery:
    """Battery drains proportionally to energy consumed per step (kJ-ish
    arbitrary units); mirrors the paper's Huawei Nova 9 Pro trace shape."""
    capacity: float = 100.0
    level: float = 100.0
    drain_per_unit: float = 1.0

    def consume(self, energy_units: float):
        self.level = max(0.0, self.level - self.drain_per_unit * energy_units)

    def fraction(self) -> float:
        return self.level / self.capacity


@dataclass
class HostBudget:
    """Pluggable host power/thermal signal (returns fraction in [0, 1])."""
    read: Callable[[], float] = lambda: 1.0

    def fraction(self) -> float:
        return float(self.read())

    def consume(self, energy_units: float):
        pass


@dataclass
class EnergyGovernor:
    """The K / mu / rho controller from §4.2."""
    check_every: int = 1          # K
    threshold: float = 0.60       # mu
    reduction: float = 0.50       # rho
    monitor: object = field(default_factory=SimulatedBattery)
    sleep_fn: Callable[[float], None] = time.sleep
    throttled: bool = False
    history: List[dict] = field(default_factory=list)

    # clamp ceiling for a rho mutated out of range after construction: the
    # stretch t -> t/(1-rho) diverges (ZeroDivisionError) at rho = 1
    MAX_RHO = 0.99

    def __post_init__(self):
        if not 0.0 <= self.reduction < 1.0:
            raise ValueError(
                f"reduction (rho) must satisfy 0 <= rho < 1, got "
                f"{self.reduction}: the governor stretches the step "
                "interval t -> t/(1-rho), which diverges at rho = 1")

    def after_step(self, step: int, step_time_s: float,
                   step_energy: float = 1.0) -> float:
        """Call after each optimizer step.  Returns injected delay (s)."""
        self.monitor.consume(step_energy)
        delay = 0.0
        if step % max(self.check_every, 1) == 0:
            self.throttled = self.monitor.fraction() < self.threshold
        # the dataclass is mutable: re-clamp rho only if a caller wrote an
        # out-of-range value after __post_init__ validated it (legal values
        # pass through untouched, including those above MAX_RHO)
        rho = self.reduction
        if not 0.0 <= rho < 1.0:
            rho = min(max(rho, 0.0), self.MAX_RHO)
        if self.throttled and rho > 0:
            # stretch interval t -> t / (1 - rho)
            delay = step_time_s * rho / (1.0 - rho)
            if delay > 0:
                self.sleep_fn(delay)
        self.history.append({
            "step": step, "battery": self.monitor.fraction(),
            "throttled": self.throttled, "step_time": step_time_s,
            "delay": delay, "interval": step_time_s + delay,
        })
        return delay
