"""LoRA / PEFT workflow (paper §3.2, C6).

The paper's LoRALinear/LoRAAttention stack is realized functionally: a LoRA
param pytree mirrors the base tree at every targeted 2-D (or stacked 3-D)
linear; ``merge_lora`` produces effective weights W' = sg(W) + (alpha/r) A@B
per layer.  Only the LoRA leaves receive gradients; exporting a merged model
or the bare adapter both fall out of the same tree (checkpoint/safetensors).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.param import is_spec, spec


def _targeted(path_leaf: str, targets: Tuple[str, ...]) -> bool:
    return path_leaf in targets


def lora_specs(base_specs, targets: Tuple[str, ...], rank: int):
    """Build the adapter spec tree: for each targeted leaf named in
    ``targets`` with shape (..., in, out), create a/b factors.  Leading
    (layers,) stacking dims are preserved."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if is_spec(v) and _targeted(k, targets) and len(v.shape) >= 2:
                    lead = v.shape[:-2]
                    lead_axes = v.axes[:-2]
                    d_in, d_out = v.shape[-2], v.shape[-1]
                    out[k] = {
                        "a": spec(lead + (d_in, rank),
                                  lead_axes + (v.axes[-2], "lora_rank"),
                                  init="fanin"),
                        "b": spec(lead + (rank, d_out),
                                  lead_axes + ("lora_rank", v.axes[-1]),
                                  init="zeros"),
                    }
                elif isinstance(v, dict):
                    sub = walk(v)
                    if sub:
                        out[k] = sub
            return out
        return {}
    return walk(base_specs)


def merge_lora(base_params, lora_params, *, rank: int, alpha: float,
               train: bool = True):
    """Effective params: W' = stop_grad(W) + (alpha/rank) * A @ B at every
    adapted leaf; all other leaves pass through (stop_grad'd when training so
    gradients flow only into the adapter)."""
    scaling = alpha / max(rank, 1)

    def walk(base, lora):
        if isinstance(base, dict):
            out = {}
            for k, v in base.items():
                if isinstance(lora, dict) and k in lora and \
                        isinstance(lora[k], dict) and "a" in lora[k] and \
                        not isinstance(v, dict):
                    w = jax.lax.stop_gradient(v) if train else v
                    a, b = lora[k]["a"], lora[k]["b"]
                    delta = jnp.einsum("...ir,...ro->...io",
                                       a.astype(jnp.float32),
                                       b.astype(jnp.float32)) * scaling
                    out[k] = (w.astype(jnp.float32) + delta).astype(v.dtype)
                elif isinstance(v, dict):
                    out[k] = walk(v, lora.get(k, {}) if isinstance(lora, dict)
                                  else {})
                else:
                    out[k] = jax.lax.stop_gradient(v) if train else v
            return out
        return jax.lax.stop_gradient(base) if train else base

    return walk(base_params, lora_params)


def export_merged(base_params, lora_params, *, rank: int, alpha: float):
    """Merged weights for deployment (no stop_gradient)."""
    return merge_lora(base_params, lora_params, rank=rank, alpha=alpha,
                      train=False)


def zero_adapter(base_specs, targets: Tuple[str, ...], rank: int):
    """An all-zero adapter tree matching ``lora_specs``'s structure.  Since
    ``b`` is zero, W' = W exactly — the serving tier uses this for batch rows
    with no adapter, so adapterless and adapted requests share one decode
    program (the zero rows are bitwise base-only)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                        lora_specs(base_specs, targets, rank), is_leaf=is_spec)


def stack_adapters(adapters):
    """Stack N same-structure adapter trees on a new leading axis — the
    per-slot adapter batch the serving decode step vmaps over (rows with
    different adapters decode together in one dispatch)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *adapters)
