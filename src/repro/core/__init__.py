"""The paper's primary contribution: the resource-aware training runtime.

- attention.py   memory-efficient exact attention (C4)
- accumulate.py  gradient accumulation (C2)
- remat.py       activation checkpointing (C3)
- zero.py        ZeRO-inspired parameter sharding (C1)
- energy.py      energy-aware computation scheduling (C5)
- lora.py        PEFT / LoRA workflow (C6)
- step.py        composed train/eval/serve steps (Application layer)
"""
