"""ZeRO-inspired parameter sharding (paper §4.1.1, C1) — TPU-native.

Phone realization: parameters partitioned into contiguous segments; only the
active segment is in RAM, the rest offloaded to disk, tracked by a mapping
table.  TPU realization: GSPMD FSDP — every weight sharded over the ``data``
mesh axis, all-gathered just-in-time per scanned layer; gradients
reduce-scatter back into the sharded layout (ZeRO-2); optimizer state and
fp32 masters shard identically (ZeRO-1).  The "mapping table" is the
ParamSpec logical-axes + rule preset (repro/sharding.py).

This module provides the placement helpers the training loop uses.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.param import is_spec
from repro.sharding import PRESETS, resolve_spec, shardings_for_specs


def param_shardings(specs, mesh: Mesh, preset: str):
    return shardings_for_specs(specs, mesh, preset)


def opt_state_shardings(specs, mesh: Mesh, preset: str):
    """Adam m/v shard exactly like their parameters (ZeRO-1)."""
    ps = shardings_for_specs(specs, mesh, preset)
    return {"m": ps, "v": ps,
            "count": NamedSharding(mesh, P())}


def place_params(params, specs, mesh: Mesh, preset: str):
    """device_put a real param tree into its sharded layout."""
    sh = shardings_for_specs(specs, mesh, preset)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), params,
                        jax.tree.unflatten(jax.tree.structure(params),
                                           jax.tree.leaves(sh)))


def offload_resident_bytes(specs, num_segments: int, window: int = 2,
                           param_bytes: int = 4, moment_bytes: int = 8):
    """Analytic peak resident state bytes of the *phone* realization of C1
    (segment-wise offload, repro/offload/): full params stay resident for
    fwd/bwd, but the (p, m, v) optimizer stream only keeps ``window`` of
    ``num_segments`` segments in RAM.  Returns (full_state, resident) bytes —
    the pair the mem-chain benchmark reports next to the GSPMD accounting."""
    n = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        n += int(np.prod(s.shape))
    full_state = n * (param_bytes + moment_bytes)
    seg = full_state / max(num_segments, 1)
    resident = n * param_bytes + min(window, num_segments) * seg
    return full_state, int(resident)


def _stream_geometry(specs):
    """(block leaf count, head leaf count, n_layers) of a stacked spec tree."""
    block_n = sum(int(np.prod(s.shape))
                  for s in jax.tree.leaves(specs["blocks"], is_leaf=is_spec))
    head_n = sum(int(np.prod(s.shape))
                 for k, sub in specs.items() if k != "blocks"
                 for s in jax.tree.leaves(sub, is_leaf=is_spec))
    n_layers = next(int(s.shape[0]) for s in
                    jax.tree.leaves(specs["blocks"], is_leaf=is_spec))
    return block_n, head_n, n_layers


def stream_resident_bytes(specs, window: int = 2, param_bytes: int = 4,
                          moment_bytes: int = 8, write_queue: int = 0,
                          batch: int = 0, seq_len: int = 0,
                          d_model: int = 0, act_offload: bool = False,
                          act_bytes: int = 4, act_window: int = 2,
                          act_queue: int = 2):
    """Analytic peak resident state bytes of the *layer-streamed* path
    (repro/core/stream.py): fwd/bwd pulls layer-aligned (p, m, v) segments
    through the offload window, so compute holds the head segment (embed /
    ln_f / wpe / meta) plus at most ``window + 1`` block segments (the LRU
    window and the jnp working copy / prefetch slot) — independent of
    ``n_layers``.  ``write_queue`` adds the async pipeline's share
    (``offload_async_writeback``): up to ``window - 1`` evicted dirty
    segments queued plus one mid-write, plus the prefetcher's bounded
    recycle pool (up to ``window`` free buffer sets) — pass
    ``write_queue=2*window`` to bound the fully pipelined engine honestly
    (deferring a write defers its memory too, and pooled free buffers are
    still resident bytes).

    With ``batch * seq_len * d_model > 0`` the bound becomes seq-len-aware,
    adding the boundary-activation term the two-sweep driver actually
    holds: device-resident acts pin all ``n_layers + 1`` fp32 boundaries
    (``O(L * B * S * D)`` — the long-seq memory wall), while
    ``act_offload=True`` models the activation spill
    (repro/offload/act_store.py): one live boundary on device plus the
    act prefetcher's ``act_window + 1`` pooled buffers and the act
    writer's ``act_queue`` queued spills, each ``act_bytes`` per element
    in storage form (4 fp32 / 2 bf16 / ~1 int8) — depth-independent.

    Returns (full_state, resident) bytes like ``offload_resident_bytes``;
    ``moment_bytes=4`` models bf16 moments."""
    per_leaf = param_bytes + moment_bytes
    block_n, head_n, n_layers = _stream_geometry(specs)
    layer_seg = block_n // max(n_layers, 1) * per_leaf
    full_state = (block_n + head_n) * per_leaf
    resident = head_n * per_leaf + (window + 1 + write_queue) * layer_seg
    act_elems = batch * seq_len * d_model
    if act_elems > 0:
        if act_offload:
            resident += (1 * act_elems * 4                       # live x
                         + (act_window + 1 + act_queue)
                         * act_elems * act_bytes)                # spill share
        else:
            resident += (n_layers + 1) * act_elems * 4
    return full_state, int(resident)


def _quant_leaf_bytes(shape, param_bytes: int, base_quant: str) -> int:
    """Stored bytes of one frozen-base leaf under a base quantization:
    int8 quantizes matrix leaves (ndim >= 2) to 1 byte/element + one fp32
    scale per last-axis channel; vector/scalar leaves stay full precision
    (mirrors ``LayerStreamedState.create_frozen``'s codec assignment)."""
    n = int(np.prod(shape)) if len(shape) else 1
    if base_quant == "int8" and len(shape) >= 2:
        return n + int(shape[-1]) * 4
    return n * param_bytes


def frozen_base_bytes(specs, param_bytes: int = 4, base_quant: str = ""):
    """(per-layer segment bytes, head segment bytes, n_layers) of the frozen
    param-only layout — the on-flash accounting of the streamed-LoRA base.
    Stacked block leaves are sliced per layer before the quantization rule
    applies, matching the stored layout."""
    _, _, n_layers = _stream_geometry(specs)
    layer_seg = sum(
        _quant_leaf_bytes(s.shape[1:], param_bytes, base_quant)
        for s in jax.tree.leaves(specs["blocks"], is_leaf=is_spec))
    head = sum(_quant_leaf_bytes(s.shape, param_bytes, base_quant)
               for k, sub in specs.items() if k != "blocks"
               for s in jax.tree.leaves(sub, is_leaf=is_spec))
    return layer_seg, head, n_layers


def lora_stream_resident_bytes(specs, adapter_specs, window: int = 2,
                               param_bytes: int = 4, base_quant: str = ""):
    """Analytic peak resident state bytes of *streamed LoRA* (frozen base):
    the base segments hold params only — no m/v, so the streamed share is
    roughly 1/3 of the Full-FT streamed bound — and the whole trainable
    state (fp32 adapter + its AdamW m/v) stays memory-resident on top.
    ``base_quant="int8"`` models the quantized frozen base: the window holds
    the *encoded* segments, so its share shrinks ~4x along with the flash
    bytes.  Returns (full_state, resident) bytes; ``adapter_specs`` is the
    LoRA spec tree from ``repro.core.lora.lora_specs``."""
    layer_seg, head_b, n_layers = frozen_base_bytes(specs, param_bytes,
                                                    base_quant)
    adapter_n = sum(int(np.prod(s.shape))
                    for s in jax.tree.leaves(adapter_specs, is_leaf=is_spec))
    adapter_state = adapter_n * (4 + 8)     # fp32 adapter + fp32 m + v
    full_state = layer_seg * n_layers + head_b + adapter_state
    resident = head_b + (window + 1) * layer_seg + adapter_state
    return full_state, int(resident)


def bytes_per_device(specs, mesh: Mesh, preset: str, dtype_bytes: int = 4):
    """Analytic per-device parameter bytes under a rule preset — the ZeRO
    'memory liberated' accounting used by the mem-chain benchmark."""
    rules = PRESETS[preset]
    mesh_axes = tuple(mesh.axis_names)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 0
    for s in jax.tree.leaves(specs, is_leaf=is_spec):
        pspec = resolve_spec(s.axes, rules, mesh_axes)
        denom = 1
        for entry in pspec:
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                denom *= axis_sizes[a]
        total += int(np.prod(s.shape)) * dtype_bytes / denom
    return total
