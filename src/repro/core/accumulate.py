"""Gradient accumulation (paper §4.1.2, C2).

Breaks one large-batch update into micro-batch forward/backward passes via
``lax.scan``; gradients accumulate in the parameters' (sharded) layout, so
under FSDP the accumulator lives reduce-scattered exactly like ZeRO-2
gradients.  Optional gradient compression: micro-grads are cast to
``reduce_dtype`` before accumulation, shrinking the collective bytes the
optimizer update pays (visible in the roofline collective term).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def split_batch(batch, n_micro: int):
    """(B, ...) leaves -> (n_micro, B/n_micro, ...).

    Raises ``ValueError`` (not a bare assert — asserts vanish under
    ``python -O`` and report an opaque tuple) when the batch does not split
    into equal micro-batches."""
    if n_micro < 1:
        raise ValueError(f"microbatches must be >= 1, got {n_micro}")

    def f(x):
        b = x.shape[0]
        if b % n_micro != 0:
            raise ValueError(
                f"global batch {b} is not divisible by "
                f"microbatches={n_micro}; gradient accumulation needs "
                "equal-sized micro-batches — adjust --batch or "
                "--microbatches")
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])
    return jax.tree.map(f, batch)


def value_and_grad_accumulated(loss_fn: Callable, params, batch,
                               n_micro: int, reduce_dtype=None):
    """Mean loss/grads over n_micro micro-batches.

    loss_fn(params, micro_batch) -> (loss, metrics).  Returns
    (loss, metrics, grads) — identical (up to dtype) to one full-batch
    backward because the per-token loss is a mean and micro-batches are
    equally sized (property-tested).
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    if n_micro <= 1:
        (loss, metrics), grads = grad_fn(params, batch)
        if reduce_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(reduce_dtype), grads)
        return loss, metrics, grads

    micro = split_batch(batch, n_micro)

    def body(carry, mb):
        acc, loss_acc = carry
        (loss, metrics), grads = grad_fn(params, mb)
        if reduce_dtype is not None:
            grads = jax.tree.map(lambda g: g.astype(reduce_dtype), grads)
        acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype), acc, grads)
        return (acc, loss_acc + loss), metrics

    acc0 = jax.tree.map(
        lambda p: jnp.zeros(p.shape, reduce_dtype or jnp.float32), params)
    (grads, loss_sum), metrics = jax.lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)), micro)
    grads = jax.tree.map(lambda g: g / n_micro, grads)
    metrics = jax.tree.map(lambda m: m[-1], metrics)
    metrics["loss"] = loss_sum / n_micro
    return loss_sum / n_micro, metrics, grads
