"""Logical-axis -> mesh-axis sharding rules (the paper's C1, TPU-native).

MobileFineTuner's ZeRO-inspired parameter sharding keeps only the *active*
parameter segment in RAM and offloads the rest to disk.  The TPU-native
realization is GSPMD FSDP: each weight is sharded over the ``data`` axis and
all-gathered just-in-time per layer.  The rule table below is the "mapping
table" of §4.1.1 — it fully determines where every parameter segment lives.

Presets (perf levers; selected by TrainConfig.shard_preset):
  dp       params replicated, batch over data              (paper's *unoptimized* baseline)
  fsdp     params sharded over data (ZeRO-3), no TP        (paper-faithful C1)
  tp       tensor parallel over model, params replicated over data
  fsdp_tp  FSDP over data x TP over model                  (beyond-paper default)
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.param import ParamSpec, tree_map_specs

# Logical axis vocabulary used by every model module:
#   layers       scanned layer dim (never sharded)
#   vocab        embedding/unembedding vocab dim
#   embed        d_model dim (FSDP axis for most weights)
#   heads        q-head dim of attention projections
#   kv_heads     kv-head dim
#   qkv / out    fused projection output dims
#   mlp          ffn hidden dim
#   experts      MoE expert dim
#   ssm_inner    mamba inner dim
#   ssm_state    mamba state dim
#   batch / seq / act_embed / act_heads   activation axes

Rules = Dict[str, Optional[Tuple[str, ...]]]


def _rules(fsdp: bool, tp: bool) -> Rules:
    d = ("data",) if fsdp else None
    m = ("model",) if tp else None
    return {
        "layers": None,
        "conv_width": None,
        # weights: shard the contraction/embed dim over data (FSDP) and the
        # parallel dim over model (TP), MaxText-style.
        "vocab": m,
        "embed": d,
        "heads": m,
        "kv_heads": m,
        "mlp": m,
        "mlp_in": d,
        "experts": m,
        "expert_mlp": d,
        "ssm_inner": m,
        "ssm_state": None,
        "ssm_heads": m,
        "norm": None,
        "lora_rank": None,
        # activations
        "batch": ("pod", "data"),
        "seq": None,
        "act_embed": None,
        "act_heads": ("model",),
        "act_kv_heads": ("model",),
        "act_experts": ("model",),
        # decode caches: batch over (pod, data); sequence over model (kv-head
        # counts are not mesh-divisible across the arch pool, seq always is)
        "cache_heads": None,
        "cache_seq": m,
        "cache_batch": ("pod", "data"),
    }


def _long_rules() -> Rules:
    """long_500k (global_batch=1): nothing can shard on batch; the KV cache
    sequence shards over (data, model) instead."""
    r = dict(_rules(fsdp=True, tp=True))
    r["batch"] = None
    r["cache_batch"] = None
    r["cache_seq"] = ("data", "model")
    return r


def _fsdp_dp_rules() -> Rules:
    """Beyond-paper preset for small models: the ``model`` axis joins data
    parallelism (batch shards over pod x data x model); weights shard over
    ``data`` only (ZeRO-3), killing the TP activation all-reduces that
    dominate small-model cells.  Gradients all-reduce over model + pod and
    reduce-scatter over data."""
    r = dict(_rules(fsdp=True, tp=False))
    # batch over the in-pod axes; the pod axis does context parallelism
    # (sequence sharding — train_4k's 256 sequences cannot split 512 ways)
    r["batch"] = ("data", "model")
    r["seq"] = ("pod",)
    r["cache_batch"] = ("data", "model")
    r["cache_seq"] = None
    return r


PRESETS: Dict[str, Rules] = {
    "dp": _rules(fsdp=False, tp=False),
    "fsdp": _rules(fsdp=True, tp=False),
    "tp": _rules(fsdp=False, tp=True),
    "fsdp_tp": _rules(fsdp=True, tp=True),
    "fsdp_tp_long": _long_rules(),
    "fsdp_dp": _fsdp_dp_rules(),
}


def constrain_params(params, specs, preset: str):
    """Pin (sliced) layer parameters to their sharded layout inside a scan
    body, so GSPMD gathers ONE layer's weights just-in-time instead of
    hoisting the all-gather of the whole stacked tree out of the loop
    (which would materialize every layer gathered at once).  This is the
    TPU-native form of the paper's 'only the active segment is resident'
    rule (§4.1.1)."""
    from repro.param import is_spec

    def one(s, arr):
        # drop the leading 'layers' axis if the array was sliced out of the
        # stacked tree
        axes = s.axes[1:] if (s.axes and s.axes[0] == "layers"
                              and arr.ndim == len(s.axes) - 1) else s.axes
        return constrain(arr, axes, preset=preset)

    return jax.tree.map(one, specs, params, is_leaf=is_spec)


def resolve_spec(axes: Tuple[Optional[str], ...], rules: Rules,
                 mesh_axes: Tuple[str, ...]) -> P:
    """Map logical axes to a PartitionSpec, dropping mesh axes that do not
    exist in the current mesh (e.g. 'pod' on the single-pod mesh) and making
    sure no mesh axis is used twice (first logical axis wins)."""
    used = set()
    parts = []
    for ax in axes:
        if ax is None:
            parts.append(None)
            continue
        target = rules.get(ax, None)
        if target is None:
            parts.append(None)
            continue
        take = tuple(t for t in target if t in mesh_axes and t not in used)
        used.update(take)
        if not take:
            parts.append(None)
        elif len(take) == 1:
            parts.append(take[0])
        else:
            parts.append(take)
    # strip trailing Nones (canonical form)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shardings_for_specs(specs, mesh: Mesh, preset: str):
    """NamedSharding pytree for a ParamSpec pytree."""
    rules = PRESETS[preset]
    mesh_axes = tuple(mesh.axis_names)

    def one(s: ParamSpec):
        return NamedSharding(mesh, resolve_spec(s.axes, rules, mesh_axes))

    return tree_map_specs(one, specs)


def sharding_for_axes(axes, mesh: Mesh, preset: str) -> NamedSharding:
    rules = PRESETS[preset]
    return NamedSharding(mesh, resolve_spec(tuple(axes), rules,
                                            tuple(mesh.axis_names)))


def constrain(x, axes, mesh: Mesh = None, preset: str = "fsdp_tp"):
    """with_sharding_constraint by logical activation axes.  Inside jit the
    mesh comes from the surrounding context (mesh context manager)."""
    if mesh is None:
        try:
            mesh = _current_mesh()
        except Exception:
            return x
    if mesh is None or mesh.empty:
        return x
    return jax.lax.with_sharding_constraint(
        x, sharding_for_axes(axes, mesh, preset))


def _current_mesh():
    from jax._src import mesh as mesh_lib
    m = mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


def batch_sharding(mesh: Mesh, ndim: int, preset: str = "fsdp_tp"):
    """Sharding for a [batch, ...] input: batch over (pod,data)."""
    axes = ["batch"] + [None] * (ndim - 1)
    return sharding_for_axes(axes, mesh, preset)
