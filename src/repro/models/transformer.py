"""Decoder-only GQA transformer LM — the dense workhorse.

Covers: granite-34b, minitron-8b, command-r-plus-104b, qwen1.5-0.5b, and the
paper's own models (gpt2-*, qwen2.5-0.5b, gemma3-*).  MoE / hybrid / enc-dec /
vlm families reuse the attention block defined here.

Layers are stacked on a leading ``layers`` dim and executed with ``lax.scan``
(+ optional remat per paper C3).  Decode runs against a donated KV cache.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.core import attention as attn_mod
from repro.core.attention import attention
from repro.models import layers as L
from repro.param import spec, tree_map_specs
from repro.sharding import constrain


# ----------------------------------------------------------------------------
# Specs
# ----------------------------------------------------------------------------
def attn_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = {
        "wq": spec((d, qd), ("embed", "heads")),
        "wk": spec((d, kvd), ("embed", "kv_heads")),
        "wv": spec((d, kvd), ("embed", "kv_heads")),
        "wo": spec((qd, d), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = spec((qd,), ("heads",), init="zeros")
        s["bk"] = spec((kvd,), ("kv_heads",), init="zeros")
        s["bv"] = spec((kvd,), ("kv_heads",), init="zeros")
    if cfg.attn_out_bias:
        s["bo"] = spec((d,), ("norm",), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = spec((cfg.head_dim,), ("norm",), init="ones")
        s["k_norm"] = spec((cfg.head_dim,), ("norm",), init="ones")
    return s


def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "attn": attn_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_variant, cfg.mlp_bias),
    }


def stack_specs(specs, n: int):
    """Add a leading scanned ``layers`` dim to every leaf spec."""
    return tree_map_specs(
        lambda s: spec((n,) + s.shape, ("layers",) + s.axes, init=s.init,
                       dtype=s.dtype, scale=s.scale), specs)


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s = {
        "embed": L.embed_specs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                               cfg.padded_vocab),
        "blocks": stack_specs(block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg.d_model, cfg.norm_variant),
    }
    if cfg.pos_variant == "learned":
        s["wpe"] = spec((cfg.max_seq_len, cfg.d_model), (None, "embed"),
                        init="embed")
    return s


# ----------------------------------------------------------------------------
# Per-layer sliding-window pattern (hybrid full/SWA schedules)
# ----------------------------------------------------------------------------
def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32 — per-layer window size; 0 = full attention."""
    if cfg.sliding_window <= 0:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    idx = jnp.arange(cfg.n_layers)
    if cfg.global_layer_every > 0:
        is_global = (idx % cfg.global_layer_every) == (cfg.global_layer_every - 1)
    else:
        is_global = jnp.zeros((cfg.n_layers,), bool)
    # first and last layers global for hybrid stability (hymba-style)
    if cfg.family == "hybrid":
        is_global = is_global | (idx == 0) | (idx == cfg.n_layers - 1)
    return jnp.where(is_global, 0, cfg.sliding_window).astype(jnp.int32)


# ----------------------------------------------------------------------------
# Attention sub-block (shared by every family with attention)
# ----------------------------------------------------------------------------
def apply_attention(p, x, cfg: ModelConfig, tcfg: TrainConfig, *,
                    positions, window, kv_cache=None, cache_index=None,
                    kv_positions=None, cross_kv=None, cache_mode="update"):
    """x: (B, S, d).  positions: (B, S) (rope/learned) or (B, 3, S) (mrope).

    kv_cache: optional (ck, cv) with shape (B, Smax, KVH, D) — decode mode;
    the new k/v are written at ``cache_index`` and attention runs against the
    full cache.  With ``cache_mode="append"`` the cache is instead a
    *read-only* gathered view (the paged-KV serving path: each row's pages
    gathered into a contiguous strip): positions at or past ``cache_index``
    in the view are stale page contents and are masked out, the fresh k/v
    are appended after the view with their true positions, and
    ``new_kv_cache`` is just ``(k, v)`` — the caller scatters them into its
    page pool (the view is never written).  cross_kv: cross-attention source
    (whisper): either an encoder-output array (B, S_enc, d) to project k/v
    from, or a precomputed (k, v) tuple (decode).  Returns
    (out, new_kv_cache).
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = x.dtype

    q = (x @ p["wq"].astype(cd)).reshape(b, s, h, hd)
    project_kv = cross_kv is None or not isinstance(cross_kv, tuple)
    if cross_kv is None:
        kv_src, skv = x, s
    elif isinstance(cross_kv, tuple):
        k, v = cross_kv
    else:
        kv_src, skv = cross_kv.astype(cd), cross_kv.shape[1]
    if project_kv:
        k = (kv_src @ p["wk"].astype(cd)).reshape(b, skv, kvh, hd)
        v = (kv_src @ p["wv"].astype(cd)).reshape(b, skv, kvh, hd)
    if "bq" in p:
        q = q + p["bq"].astype(cd).reshape(h, hd)
        if project_kv:
            k = k + p["bk"].astype(cd).reshape(kvh, hd)
            v = v + p["bv"].astype(cd).reshape(kvh, hd)
    if cfg.qk_norm:
        q = L.apply_norm({"scale": p["q_norm"]}, q, "rmsnorm")
        if cross_kv is None:
            k = L.apply_norm({"scale": p["k_norm"]}, k, "rmsnorm")

    if cross_kv is None and cfg.pos_variant == "rope":
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
    elif cross_kv is None and cfg.pos_variant == "mrope":
        q = L.apply_mrope(q, positions, cfg.mrope_sections, cfg.rope_theta)
        k = L.apply_mrope(k, positions, cfg.mrope_sections, cfg.rope_theta)

    if kv_cache is not None and cache_mode == "append":
        ck, cv = kv_cache
        cap = ck.shape[1]
        # stale view entries (>= the write head) mask to SENTINEL -> their
        # scores are NEG_INF -> exactly zero weight in fp32, so garbage in
        # unwritten page tail bytes can never perturb the output
        view_pos = jnp.arange(cap, dtype=jnp.int32)[None]
        view_pos = jnp.where(view_pos < cache_index, view_pos,
                             attn_mod.SENTINEL)
        fresh_pos = jnp.arange(s, dtype=jnp.int32)[None] + cache_index
        kv_pos = jnp.broadcast_to(
            jnp.concatenate([view_pos, fresh_pos], axis=1), (b, cap + s))
        q_pos = jnp.broadcast_to(fresh_pos, (b, s))
        out = attention(q, jnp.concatenate([ck.astype(cd), k], axis=1),
                        jnp.concatenate([cv.astype(cd), v], axis=1),
                        q_pos=q_pos, kv_pos=kv_pos, causal=True,
                        window=window, impl=tcfg.attention_impl,
                        chunk=tcfg.attn_chunk)
        new_cache = (k, v)
    elif kv_cache is not None:
        ck, cv = kv_cache
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype),
                                          (0, cache_index, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype),
                                          (0, cache_index, 0, 0))
        q_pos = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None] + cache_index, (b, s))
        smax = ck.shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(smax, dtype=jnp.int32)[None],
                                  (b, smax))
        # positions beyond the write head are padding
        kv_pos = jnp.where(kv_pos <= cache_index + s - 1, kv_pos,
                           attn_mod.SENTINEL)
        out = attention(q, ck.astype(cd), cv.astype(cd), q_pos=q_pos,
                        kv_pos=kv_pos, causal=True, window=window,
                        impl=tcfg.attention_impl, chunk=tcfg.attn_chunk)
        new_cache = (ck, cv)
    else:
        if cross_kv is not None:
            out = attention(q, k, v, causal=False, window=0,
                            impl=tcfg.attention_impl, chunk=tcfg.attn_chunk)
        else:
            pos1d = positions if positions.ndim == 2 else positions[:, 0]
            out = attention(q, k, v, q_pos=pos1d, kv_pos=pos1d, causal=True,
                            window=window, impl=tcfg.attention_impl,
                            chunk=tcfg.attn_chunk)
        new_cache = None

    out = out.reshape(b, s, h * hd)
    y = out @ p["wo"].astype(cd)
    if "bo" in p:
        y = y + p["bo"].astype(cd)
    return y, new_cache


def apply_block(p, x, cfg, tcfg, *, positions, window, kv_cache=None,
                cache_index=None, cache_mode="update"):
    h, cache = apply_attention(
        p["attn"], L.apply_norm(p["ln1"], x, cfg.norm_variant), cfg, tcfg,
        positions=positions, window=window, kv_cache=kv_cache,
        cache_index=cache_index, cache_mode=cache_mode)
    x = x + h
    x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg.norm_variant),
                        cfg.mlp_variant)
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    return x, cache


def cross_entropy(logits, labels):
    """Mean token NLL over labels >= 0; returns (loss, metrics)."""
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = nll.sum() / denom
    acc = (jnp.argmax(logits, -1) == labels).astype(jnp.float32) * mask
    return loss, {"loss": loss, "ppl_log": loss,
                  "accuracy": acc.sum() / denom, "tokens": mask.sum()}


# ----------------------------------------------------------------------------
# KV-cache specs (decode / serve_step) — used by the unified lm.py driver
# ----------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    kv = spec((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim),
              ("layers", "cache_batch", "cache_seq", "cache_heads", None),
              init="zeros", dtype=dtype)
    return {"k": kv, "v": kv}
