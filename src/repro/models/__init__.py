"""Model zoo: composable JAX definitions for every assigned architecture."""
from repro.models import registry  # noqa: F401
