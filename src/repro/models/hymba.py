"""Hymba hybrid-head block [arXiv:2411.13676].

Each layer runs attention heads and Mamba(SSD) heads *in parallel* on the same
normalized input; their outputs are per-channel RMS-normalized, scaled by
learnable gates, and averaged, then a shared MLP follows.  Most layers use
sliding-window attention; first/middle/last are global (see layer_windows).
Learnable meta tokens are prepended to the sequence.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.models.transformer import apply_attention, attn_specs
from repro.param import spec
from repro.sharding import constrain


def hymba_block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    return {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "attn": attn_specs(cfg),
        "mamba": mamba2.mamba_specs(cfg),
        "attn_gate": spec((cfg.d_model,), ("norm",), init="ones"),
        "ssm_gate": spec((cfg.d_model,), ("norm",), init="ones"),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_variant,
                           cfg.mlp_bias),
    }


def _rms(x):
    ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-6)).astype(x.dtype)


def apply_hymba_block(p, x, cfg: ModelConfig, tcfg: TrainConfig, *,
                      positions, window, kv_cache=None, cache_index=None,
                      ssm_state=None, cache_mode="update"):
    xn = L.apply_norm(p["ln1"], x, cfg.norm_variant)
    a, new_kv = apply_attention(p["attn"], xn, cfg, tcfg, positions=positions,
                                window=window, kv_cache=kv_cache,
                                cache_index=cache_index,
                                cache_mode=cache_mode)
    m, new_ssm = mamba2.apply_mamba(p["mamba"], xn, cfg, tcfg, state=ssm_state)
    fused = 0.5 * (_rms(a) * p["attn_gate"].astype(a.dtype)
                   + _rms(m) * p["ssm_gate"].astype(a.dtype))
    x = x + fused
    x = x + L.apply_mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg.norm_variant),
                        cfg.mlp_variant)
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    return x, new_kv, new_ssm
