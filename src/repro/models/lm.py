"""Unified decoder-only LM driver for dense / moe / ssm / hybrid / vlm.

One scan-over-layers driver (with the paper's remat C3 applied to the scanned
body) serving:

  dense   granite-34b, minitron-8b, command-r-plus-104b, qwen1.5-0.5b, paper models
  moe     phi3.5-moe-42b (top-2), dbrx-132b (top-4)
  ssm     mamba2-130m (attention-free SSD)
  hybrid  hymba-1.5b (parallel attention+SSM heads, meta tokens)
  vlm     qwen2-vl-7b backbone (vision-embedding stub + M-RoPE)

``forward`` returns (logits, aux); ``decode_step`` runs one token against a
donated cache pytree whose content depends on the family (kv and/or ssm).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.core.remat import maybe_remat
from repro.models import layers as L
from repro.models import mamba2, moe as moe_mod
from repro.models import transformer as T
from repro.models.hymba import apply_hymba_block, hymba_block_specs
from repro.param import spec
from repro.sharding import constrain


# ----------------------------------------------------------------------------
# Specs
# ----------------------------------------------------------------------------
def block_specs(cfg: ModelConfig) -> Dict[str, Any]:
    if cfg.family in ("dense", "vlm"):
        return T.block_specs(cfg)
    if cfg.family == "moe":
        return {
            "ln1": L.norm_specs(cfg.d_model, cfg.norm_variant),
            "attn": T.attn_specs(cfg),
            "ln2": L.norm_specs(cfg.d_model, cfg.norm_variant),
            "moe": moe_mod.moe_specs(cfg),
        }
    if cfg.family == "ssm":
        return {
            "ln1": L.norm_specs(cfg.d_model, cfg.norm_variant),
            "mamba": mamba2.mamba_specs(cfg),
        }
    if cfg.family == "hybrid":
        return hymba_block_specs(cfg)
    raise ValueError(f"lm.py does not drive family {cfg.family!r}")


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    s = {
        "embed": L.embed_specs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                               cfg.padded_vocab),
        "blocks": T.stack_specs(block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg.d_model, cfg.norm_variant),
    }
    if cfg.pos_variant == "learned":
        s["wpe"] = spec((cfg.max_seq_len, cfg.d_model), (None, "embed"),
                        init="embed")
    if cfg.n_meta_tokens > 0:
        s["meta"] = spec((cfg.n_meta_tokens, cfg.d_model), (None, "embed"),
                         init="embed")
    return s


# ----------------------------------------------------------------------------
# Input embedding (+ vision stub merge, + meta tokens)
# ----------------------------------------------------------------------------
def embed_input(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    cd = dtype_of(tcfg.compute_dtype)
    x = L.embed_tokens(params["embed"], batch["tokens"], cd)
    if cfg.family == "vlm" and "vision" in batch:
        nv = min(batch["vision"].shape[1], x.shape[1])
        x = jnp.concatenate([batch["vision"].astype(cd)[:, :nv], x[:, nv:]],
                            axis=1)
    if cfg.n_meta_tokens > 0:
        meta = jnp.broadcast_to(params["meta"].astype(cd)[None],
                                (x.shape[0],) + params["meta"].shape)
        x = jnp.concatenate([meta, x], axis=1)
    if cfg.pos_variant == "learned":
        x = x + params["wpe"].astype(cd)[None, :x.shape[1]]
    return x


def _positions(cfg: ModelConfig, b: int, s: int):
    if cfg.pos_variant == "mrope":
        return L.mrope_positions(b, s, cfg.n_vision_tokens)
    from repro.core.attention import default_positions
    return default_positions(b, s)


# ----------------------------------------------------------------------------
# Forward (teacher-forced)
# ----------------------------------------------------------------------------
def forward(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    x = embed_input(params, batch, cfg, tcfg)
    b, s_total, _ = x.shape
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    positions = _positions(cfg, b, s_total)
    windows = T.layer_windows(cfg)
    # full-attention configs carry an all-zero per-layer windows array; the
    # scanned entry arrives as a traced scalar, so pass the zero statically
    # instead — kernel impls (flash) specialize their grid on the window
    full_attn = cfg.sliding_window <= 0
    fam = cfg.family
    bspecs = block_specs(cfg)
    from repro.sharding import constrain_params

    def body(carry, layer):
        x, aux = carry
        if fam == "ssm":
            layer = constrain_params(layer, bspecs, tcfg.shard_preset)
        else:
            layer = (constrain_params(layer[0], bspecs, tcfg.shard_preset),
                     ) + tuple(layer[1:])
        if fam in ("dense", "vlm"):
            lp, win = layer
            x, _ = T.apply_block(lp, x, cfg, tcfg, positions=positions,
                                 window=0 if full_attn else win)
        elif fam == "moe":
            lp, win = layer
            x, _, a = moe_mod.apply_moe_block(
                lp, x, cfg, tcfg, positions=positions,
                window=0 if full_attn else win)
            aux = aux + a
        elif fam == "ssm":
            lp = layer
            h, _ = mamba2.apply_mamba(
                lp["mamba"], L.apply_norm(lp["ln1"], x, cfg.norm_variant),
                cfg, tcfg)
            x = x + h
            x = constrain(x, ("batch", "seq", "act_embed"),
                          preset=tcfg.shard_preset)
        elif fam == "hybrid":
            lp, win = layer
            x, _, _ = apply_hymba_block(lp, x, cfg, tcfg, positions=positions,
                                        window=0 if full_attn else win)
        return (x, aux), None

    body = maybe_remat(body, tcfg.remat_policy)
    xs = params["blocks"] if fam == "ssm" else (params["blocks"], windows)
    aux0 = jnp.zeros((), jnp.float32)
    if tcfg.scan_layers:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), xs)
    else:
        aux = aux0
        for i in range(cfg.n_layers):
            layer = jax.tree.map(lambda a: a[i], xs)
            (x, aux), _ = body((x, aux), layer)

    if cfg.n_meta_tokens > 0:
        x = x[:, cfg.n_meta_tokens:]
    x = L.apply_norm(params["ln_f"], x, cfg.norm_variant)
    logits = L.unembed(params["embed"], x.astype(jnp.float32),
                       cfg.tie_embeddings, cfg.logit_softcap,
                       cfg.vocab_size)
    return logits, aux / max(cfg.n_layers, 1)


def loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    logits, aux = forward(params, batch, cfg, tcfg)
    loss, metrics = T.cross_entropy(logits, batch["labels"])
    metrics["aux_loss"] = aux
    return loss + aux, metrics


# ----------------------------------------------------------------------------
# Layer program (layer-streamed fwd/bwd; repro/core/stream.py)
# ----------------------------------------------------------------------------
class LayerProgram(NamedTuple):
    """Jitted per-stage entry points for the two-sweep streamed driver.

    The monolithic ``loss_fn`` above is re-expressed as an explicit program
    over a head tree (embed/ln_f/wpe/meta) and L single-block trees, so the
    driver can pull one block's params through the offload window at a time:

      embed(head, batch) -> x0
      block(bp, x, window, positions) -> (x, aux)        one transformer block
      block_vjp(bp, x, window, positions, dy, daux)
          -> (dblock, dx)                                recomputes the block
      head_vjp(head, xL, batch, aux_sum)
          -> (loss, metrics, dhead, dxL, daux)           loss + its VJP
      embed_vjp(head, batch, dx0) -> dhead               embed contribution
      head_loss(head, xL, batch, aux_sum)
          -> (loss, metrics)                             eval / loss-only
      positions(b, s) -> position ids for block calls

    When ``tcfg.lora_rank > 0`` the program is built in PEFT mode: every
    entry point takes the (tiny, memory-resident) adapter sub-tree alongside
    the frozen base tree, ``merge_lora`` is applied per block *inside* the
    jit, and the VJPs differentiate with respect to the adapter only — the
    cotangents returned alongside the activation cotangent are adapter
    cotangents, and the base segments are never written.  With
    ``tcfg.base_quant`` the base arguments arrive *encoded* — a
    (codes_tree, scales_tree) pair of int8 codes + per-channel scales — and
    are dequantized as the first op inside each jitted entry point, so fp32
    base weights exist one block at a time, only as XLA transients:

      embed(head, hlora, batch) -> x0
      block(bp, blora, x, window, positions) -> (x, aux)
      block_vjp(bp, blora, x, window, positions, dy, daux) -> (dblora, dx)
      head_vjp(head, hlora, xL, batch, aux_sum)
          -> (loss, metrics, dhlora, dxL, daux)
      embed_vjp(head, hlora, batch, dx0) -> dhlora
      head_loss(head, hlora, xL, batch, aux_sum) -> (loss, metrics)

    Per-step loss/grads match the in-memory jit path up to re-association
    noise (equivalence-tested at 1e-5 on the smoke configs).
    """
    embed: Any
    block: Any
    block_vjp: Any
    head_vjp: Any
    embed_vjp: Any
    head_loss: Any
    positions: Any
    lora: bool = False


def make_layer_program(cfg: ModelConfig, tcfg: TrainConfig) -> LayerProgram:
    """Build the per-layer apply/VJP entry points (all jitted once; every
    block shares shapes, so the whole program compiles L-independently)."""
    if cfg.family == "encdec":
        raise ValueError("layer streaming drives decoder-only families; "
                         "encdec (whisper) keeps the in-memory path")
    fam = cfg.family
    bspecs = block_specs(cfg)
    from repro.sharding import constrain_params

    def embed_fn(head, batch):
        x = embed_input(head, batch, cfg, tcfg)
        return constrain(x, ("batch", "seq", "act_embed"),
                         preset=tcfg.shard_preset)

    def block_fn(bp, x, window, positions):
        bp = constrain_params(bp, bspecs, tcfg.shard_preset)
        if cfg.sliding_window <= 0:
            # the driver feeds the per-layer window as a jit argument, so it
            # is traced here; full-attention configs only ever carry zeros —
            # pin the zero statically so the flash kernel can specialize
            window = 0
        aux = jnp.zeros((), jnp.float32)
        if fam in ("dense", "vlm"):
            x, _ = T.apply_block(bp, x, cfg, tcfg, positions=positions,
                                 window=window)
        elif fam == "moe":
            x, _, aux = moe_mod.apply_moe_block(bp, x, cfg, tcfg,
                                                positions=positions,
                                                window=window)
        elif fam == "ssm":
            h, _ = mamba2.apply_mamba(
                bp["mamba"], L.apply_norm(bp["ln1"], x, cfg.norm_variant),
                cfg, tcfg)
            x = x + h
            x = constrain(x, ("batch", "seq", "act_embed"),
                          preset=tcfg.shard_preset)
        else:  # hybrid
            x, _, _ = apply_hymba_block(bp, x, cfg, tcfg, positions=positions,
                                        window=window)
        return x, aux

    # paper C3 on the streamed path too: the per-block VJPs below close over
    # the remat-wrapped body, so a ``dots``/``full`` policy trades block-
    # internal activation residency for recompute exactly as the in-memory
    # scan body does (validated at parse time in launch/train.py)
    block_fn = maybe_remat(block_fn, tcfg.remat_policy)

    def head_fn(head, x, batch, aux_sum):
        if cfg.n_meta_tokens > 0:
            x = x[:, cfg.n_meta_tokens:]
        x = L.apply_norm(head["ln_f"], x, cfg.norm_variant)
        logits = L.unembed(head["embed"], x.astype(jnp.float32),
                           cfg.tie_embeddings, cfg.logit_softcap,
                           cfg.vocab_size)
        loss, metrics = T.cross_entropy(logits, batch["labels"])
        aux = aux_sum / max(cfg.n_layers, 1)
        metrics["aux_loss"] = aux
        return loss + aux, metrics

    def positions(b, s):
        return _positions(cfg, b, s)

    if tcfg.base_quant and tcfg.lora_rank <= 0:
        raise ValueError(
            "--base-quant applies to the frozen base of streamed LoRA "
            "(--lora-rank N with --offload-stream-params); quantized "
            "Full-FT training would fold quantization error back into the "
            "updated weights every step")

    if tcfg.lora_rank > 0:
        from repro.core.lora import merge_lora
        from repro.offload.codecs import dequant_tree
        rank, alpha = tcfg.lora_rank, tcfg.lora_alpha
        # quantized frozen base: the segments stay int8 in the window and
        # arrive here as (codes, scales) pairs; dequant_tree decodes them
        # inside the jit (a no-op on plain trees), so the fp32 base exists
        # per block only, fused into the merge below
        base_of = dequant_tree if tcfg.base_quant else (lambda t: t)

        # merge_lora(train=True) stop-gradients every base leaf, so even
        # though the VJPs below only differentiate the adapter args, the
        # merged weights W' = sg(W) + (alpha/r) A@B are formed inside the
        # jit — one block's merged copy at a time, never a full tree.
        def lora_block_fn(bp, blp, x, window, positions):
            return block_fn(merge_lora(base_of(bp), blp, rank=rank,
                                       alpha=alpha),
                            x, window, positions)

        def lora_embed_fn(head, hlp, batch):
            return embed_fn(merge_lora(base_of(head), hlp, rank=rank,
                                       alpha=alpha),
                            batch)

        def lora_head_fn(head, hlp, x, batch, aux_sum):
            return head_fn(merge_lora(base_of(head), hlp, rank=rank,
                                      alpha=alpha),
                           x, batch, aux_sum)

        # dy is each block's incoming activation cotangent — produced by the
        # previous VJP and never read again, so its buffer is donated to the
        # call (the backward sweep recycles one cotangent-sized buffer
        # instead of allocating L of them)
        @functools.partial(jax.jit, donate_argnums=(5,))
        def lora_block_vjp(bp, blp, x, window, positions, dy, daux):
            _, f_vjp = jax.vjp(
                lambda lp, xx: lora_block_fn(bp, lp, xx, window, positions),
                blp, x)
            dlp, dx = f_vjp((dy, daux))
            return dlp, dx

        @jax.jit
        def lora_head_vjp(head, hlp, x, batch, aux_sum):
            loss, f_vjp, metrics = jax.vjp(
                lambda lp, xx, a: lora_head_fn(head, lp, xx, batch, a),
                hlp, x, aux_sum, has_aux=True)
            dhlp, dx, daux = f_vjp(jnp.ones((), loss.dtype))
            return loss, metrics, dhlp, dx, daux

        @jax.jit
        def lora_embed_vjp(head, hlp, batch, dx):
            _, f_vjp = jax.vjp(lambda lp: lora_embed_fn(head, lp, batch),
                               hlp)
            (dhlp,) = f_vjp(dx)
            return dhlp

        return LayerProgram(embed=jax.jit(lora_embed_fn),
                            block=jax.jit(lora_block_fn),
                            block_vjp=lora_block_vjp,
                            head_vjp=lora_head_vjp,
                            embed_vjp=lora_embed_vjp,
                            head_loss=jax.jit(lora_head_fn),
                            positions=positions, lora=True)

    # dy (the incoming activation cotangent) is consumed exactly once per
    # block — donate its buffer so the backward sweep reuses one
    # cotangent-sized allocation across all L blocks
    @functools.partial(jax.jit, donate_argnums=(4,))
    def block_vjp(bp, x, window, positions, dy, daux):
        _, f_vjp = jax.vjp(
            lambda p, xx: block_fn(p, xx, window, positions), bp, x)
        dp, dx = f_vjp((dy, daux))
        return dp, dx

    @jax.jit
    def head_vjp(head, x, batch, aux_sum):
        loss, f_vjp, metrics = jax.vjp(
            lambda h, xx, a: head_fn(h, xx, batch, a), head, x, aux_sum,
            has_aux=True)
        dhead, dx, daux = f_vjp(jnp.ones((), loss.dtype))
        return loss, metrics, dhead, dx, daux

    @jax.jit
    def embed_vjp(head, batch, dx):
        _, f_vjp = jax.vjp(lambda h: embed_fn(h, batch), head)
        (dhead,) = f_vjp(dx)
        return dhead

    return LayerProgram(embed=jax.jit(embed_fn), block=jax.jit(block_fn),
                        block_vjp=block_vjp, head_vjp=head_vjp,
                        embed_vjp=embed_vjp, head_loss=jax.jit(head_fn),
                        positions=positions)


# ----------------------------------------------------------------------------
# Decode (serve_step)
# ----------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    c: Dict[str, Any] = {}
    if cfg.family != "ssm":
        c["kv"] = T.cache_specs(cfg, batch, max_len, dtype)
    if cfg.family in ("ssm", "hybrid"):
        c["ssm"] = mamba2.mamba_state_specs(cfg, batch, jnp.float32)
    return c


# ----------------------------------------------------------------------------
# Paged KV cache primitives (serving tier)
# ----------------------------------------------------------------------------
# The serving engine (repro/serve) replaces the dense per-slot
# (slots, max_len, ...) cache with a shared pool of fixed-size pages plus a
# per-slot page table (repro/serve/paged.py holds the host-side accounting).
# These two primitives are the device half, called *inside* the jitted
# serving block: gather turns one row's table into a contiguous cache view
# for attention (cache_mode="append" in transformer.apply_attention), and
# scatter writes the fresh k/v of every row through the tables in one
# batched indexed update on the (donated) pool.

def paged_gather(pool, table):
    """Gather one row's pages into a contiguous cache strip.

    pool: (n_pages, page_size, ...); table: (W,) int32 page ids.
    Returns (W * page_size, ...) — position p of the row lives at strip
    offset p (page p // page_size, slot p % page_size).  Table entries that
    point at the sentinel page 0 yield garbage rows; the caller masks them
    by position.
    """
    g = pool[table]                                   # (W, page_size, ...)
    return g.reshape((g.shape[0] * g.shape[1],) + g.shape[2:])


def paged_scatter(pool, tables, index, vals):
    """Write every row's fresh k/v slab into its pages.

    pool: (n_pages, page_size, ...) (donated by the caller's jit);
    tables: (R, W) int32; index: (R,) write heads; vals: (R, S, ...).
    Row r position index[r] + t routes to page tables[r, pos // page_size]
    offset pos % page_size.  Rows the caller masked out (table row all
    sentinel) land in page 0, which no request owns.
    """
    psz = pool.shape[1]
    s = vals.shape[1]
    pos = index[:, None] + jnp.arange(s, dtype=jnp.int32)[None]    # (R, S)
    pid = jnp.take_along_axis(tables, pos // psz, axis=1)          # (R, S)
    return pool.at[pid, pos % psz].set(vals.astype(pool.dtype))


def decode_step(params, cache, tokens, index, cfg: ModelConfig,
                tcfg: TrainConfig):
    """tokens: (B, S); index: scalar int32 tokens already cached.

    S == 1 is one autoregressive decode step.  S > 1 is the chunked-prefill
    entry point: one jitted call pushes a slab of S prompt tokens through the
    cache (the attention mask already hides kv positions past the write head,
    and the SSM state path scans the slab token-by-token inside the jit), so
    filling a P-token prompt costs ceil(P/S) dispatches instead of P while
    matching step-wise decode numerics exactly.

    Returns (logits (B, vocab) at the *last* slab position, new_cache)."""
    cd = dtype_of(tcfg.compute_dtype)
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cd)
    if cfg.pos_variant == "learned":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["wpe"].astype(cd),
            jnp.minimum(index, cfg.max_seq_len - s), s, axis=0)[None]
    pos = index + jnp.arange(s, dtype=jnp.int32)
    if cfg.pos_variant == "mrope":
        positions = jnp.broadcast_to(pos[None, None], (b, 3, s))
    else:
        positions = jnp.broadcast_to(pos[None], (b, s))
    windows = T.layer_windows(cfg)
    full_attn = cfg.sliding_window <= 0  # see forward(): pin the zero window
    fam = cfg.family
    bspecs = block_specs(cfg)
    from repro.sharding import constrain_params

    def body(x, layer):
        layer = (constrain_params(layer[0], bspecs, tcfg.shard_preset),
                 ) + tuple(layer[1:])
        if fam in ("dense", "vlm", "moe"):
            lp, ck, cv, win = layer
            win = 0 if full_attn else win
            if fam == "moe":
                y, (ck, cv), _ = moe_mod.apply_moe_block(
                    lp, x, cfg, tcfg, positions=positions, window=win,
                    kv_cache=(ck, cv), cache_index=index)
            else:
                y, (ck, cv) = T.apply_block(
                    lp, x, cfg, tcfg, positions=positions, window=win,
                    kv_cache=(ck, cv), cache_index=index)
            return y, (ck, cv)
        if fam == "ssm":
            lp, conv, ssm = layer
            h, st = mamba2.apply_mamba(
                lp["mamba"], L.apply_norm(lp["ln1"], x, cfg.norm_variant),
                cfg, tcfg, state={"conv": conv, "ssm": ssm})
            return x + h, (st["conv"], st["ssm"])
        # hybrid
        lp, ck, cv, conv, ssm, win = layer
        win = 0 if full_attn else win
        y, (ck, cv), st = apply_hymba_block(
            lp, x, cfg, tcfg, positions=positions, window=win,
            kv_cache=(ck, cv), cache_index=index,
            ssm_state={"conv": conv, "ssm": ssm})
        return y, (ck, cv, st["conv"], st["ssm"])

    new_cache = dict(cache)
    if fam in ("dense", "vlm", "moe"):
        xs = (params["blocks"], cache["kv"]["k"], cache["kv"]["v"], windows)
        x, (nk, nv) = jax.lax.scan(body, x, xs)
        new_cache["kv"] = {"k": nk, "v": nv}
    elif fam == "ssm":
        xs = (params["blocks"], cache["ssm"]["conv"], cache["ssm"]["ssm"])
        x, (nconv, nssm) = jax.lax.scan(body, x, xs)
        new_cache["ssm"] = {"conv": nconv, "ssm": nssm}
    else:
        xs = (params["blocks"], cache["kv"]["k"], cache["kv"]["v"],
              cache["ssm"]["conv"], cache["ssm"]["ssm"], windows)
        x, (nk, nv, nconv, nssm) = jax.lax.scan(body, x, xs)
        new_cache["kv"] = {"k": nk, "v": nv}
        new_cache["ssm"] = {"conv": nconv, "ssm": nssm}

    x = L.apply_norm(params["ln_f"], x, cfg.norm_variant)
    logits = L.unembed(params["embed"], x.astype(jnp.float32),
                       cfg.tie_embeddings, cfg.logit_softcap,
                       cfg.vocab_size)
    return logits[:, -1], new_cache
