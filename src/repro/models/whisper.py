"""Whisper-large-v3 encoder-decoder backbone [arXiv:2212.04356].

The conv/mel frontend is a STUB per the harness: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model) with S_enc = seq //
enc_seq_ratio.  Encoder = bidirectional transformer; decoder = causal
self-attention + cross-attention.  LayerNorm + GELU + learned positions
(tables sized to the harness shapes — real whisper uses 1500/448; noted in
DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig, dtype_of
from repro.core.remat import maybe_remat
from repro.models import layers as L
from repro.models import transformer as T
from repro.param import spec
from repro.sharding import constrain


def enc_len(cfg: ModelConfig, seq: int) -> int:
    return max(seq // cfg.enc_seq_ratio, 8)


def _enc_block_specs(cfg):
    return {
        "ln1": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "attn": T.attn_specs(cfg),
        "ln2": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff, cfg.mlp_variant,
                           cfg.mlp_bias),
    }


def _dec_block_specs(cfg):
    s = _enc_block_specs(cfg)
    s["lnx"] = L.norm_specs(cfg.d_model, cfg.norm_variant)
    s["xattn"] = T.attn_specs(cfg)
    return s


def param_specs(cfg: ModelConfig) -> Dict[str, Any]:
    max_enc = max(enc_len(cfg, cfg.max_seq_len), 8)
    return {
        "embed": L.embed_specs(cfg.vocab_size, cfg.d_model, cfg.tie_embeddings,
                               cfg.padded_vocab),
        "wpe": spec((cfg.max_seq_len, cfg.d_model), (None, "embed"),
                    init="embed"),
        "wpe_enc": spec((max_enc, cfg.d_model), (None, "embed"),
                        init="embed"),
        "enc_blocks": T.stack_specs(_enc_block_specs(cfg), cfg.n_enc_layers),
        "ln_enc": L.norm_specs(cfg.d_model, cfg.norm_variant),
        "dec_blocks": T.stack_specs(_dec_block_specs(cfg), cfg.n_layers),
        "ln_f": L.norm_specs(cfg.d_model, cfg.norm_variant),
    }


def encode(params, frames, cfg: ModelConfig, tcfg: TrainConfig):
    """frames: (B, S_enc, d_model) stub embeddings -> encoder output."""
    cd = dtype_of(tcfg.compute_dtype)
    x = frames.astype(cd) + params["wpe_enc"].astype(cd)[None, :frames.shape[1]]
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)

    from repro.sharding import constrain_params
    espec = _enc_block_specs(cfg)

    def body(x, lp):
        lp = constrain_params(lp, espec, tcfg.shard_preset)
        xn = L.apply_norm(lp["ln1"], x, cfg.norm_variant)
        # bidirectional self-attention: project k/v from the same input
        h, _ = T.apply_attention(lp["attn"], xn, cfg, tcfg, positions=None,
                                 window=0, cross_kv=xn)
        x = x + h
        x = x + L.apply_mlp(lp["mlp"],
                            L.apply_norm(lp["ln2"], x, cfg.norm_variant),
                            cfg.mlp_variant)
        x = constrain(x, ("batch", "seq", "act_embed"),
                      preset=tcfg.shard_preset)
        return x, None

    body = maybe_remat(body, tcfg.remat_policy)
    if tcfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for i in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_blocks"])
            x, _ = body(x, lp)
    return L.apply_norm(params["ln_enc"], x, cfg.norm_variant)


def _dec_block(lp, x, enc_out, cfg, tcfg, *, positions, kv_cache=None,
               cache_index=None, cross_kv=None):
    h, new_kv = T.apply_attention(
        lp["attn"], L.apply_norm(lp["ln1"], x, cfg.norm_variant), cfg, tcfg,
        positions=positions, window=0, kv_cache=kv_cache,
        cache_index=cache_index)
    x = x + h
    h, _ = T.apply_attention(
        lp["xattn"], L.apply_norm(lp["lnx"], x, cfg.norm_variant), cfg, tcfg,
        positions=None, window=0,
        cross_kv=cross_kv if cross_kv is not None else enc_out)
    x = x + h
    x = x + L.apply_mlp(lp["mlp"], L.apply_norm(lp["ln2"], x, cfg.norm_variant),
                        cfg.mlp_variant)
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    return x, new_kv


def forward(params, batch, cfg: ModelConfig, tcfg: TrainConfig):
    """batch: {frames (B,S_enc,d), tokens (B,S), labels (B,S)}."""
    enc_out = encode(params, batch["frames"], cfg, tcfg)
    cd = dtype_of(tcfg.compute_dtype)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = L.embed_tokens(params["embed"], tokens, cd)
    x = x + params["wpe"].astype(cd)[None, :s]
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    from repro.core.attention import default_positions
    positions = default_positions(b, s)

    from repro.sharding import constrain_params
    dspec = _dec_block_specs(cfg)

    def body(x, lp):
        lp = constrain_params(lp, dspec, tcfg.shard_preset)
        x, _ = _dec_block(lp, x, enc_out, cfg, tcfg, positions=positions)
        return x, None

    body = maybe_remat(body, tcfg.remat_policy)
    if tcfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    else:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["dec_blocks"])
            x, _ = body(x, lp)
    x = L.apply_norm(params["ln_f"], x, cfg.norm_variant)
    logits = L.unembed(params["embed"], x.astype(jnp.float32),
                       cfg.tie_embeddings, cfg.logit_softcap,
                       cfg.vocab_size)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg, tcfg):
    logits, aux = forward(params, batch, cfg, tcfg)
    loss, metrics = T.cross_entropy(logits, batch["labels"])
    return loss, metrics


# ----------------------------------------------------------------------------
# Decode: self-attn cache + precomputed per-layer cross k/v
# ----------------------------------------------------------------------------
def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    s_enc = enc_len(cfg, max_len)
    kvshape = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
    return {
        "kv": T.cache_specs(cfg, batch, max_len, dtype),
        "cross_k": spec((cfg.n_layers, batch, s_enc, cfg.n_kv_heads,
                         cfg.head_dim), kvshape, init="zeros", dtype=dtype),
        "cross_v": spec((cfg.n_layers, batch, s_enc, cfg.n_kv_heads,
                         cfg.head_dim), kvshape, init="zeros", dtype=dtype),
    }


def decode_step(params, cache, tokens, index, cfg: ModelConfig,
                tcfg: TrainConfig):
    cd = dtype_of(tcfg.compute_dtype)
    b = tokens.shape[0]
    x = L.embed_tokens(params["embed"], tokens, cd)
    x = x + jax.lax.dynamic_slice_in_dim(
        params["wpe"].astype(cd), jnp.minimum(index, cfg.max_seq_len - 1),
        1, axis=0)[None]
    positions = jnp.broadcast_to(jnp.zeros((1, 1), jnp.int32) + index, (b, 1))

    from repro.sharding import constrain_params
    dspec = _dec_block_specs(cfg)

    def body(x, layer):
        lp, ck, cv, xk, xv = layer
        lp = constrain_params(lp, dspec, tcfg.shard_preset)
        y, (ck, cv) = _dec_block(lp, x, None, cfg, tcfg, positions=positions,
                                 kv_cache=(ck, cv), cache_index=index,
                                 cross_kv=(xk.astype(cd), xv.astype(cd)))
        return y, (ck, cv)

    xs = (params["dec_blocks"], cache["kv"]["k"], cache["kv"]["v"],
          cache["cross_k"], cache["cross_v"])
    x, (nk, nv) = jax.lax.scan(body, x, xs)
    new_cache = dict(cache)
    new_cache["kv"] = {"k": nk, "v": nv}
    x = L.apply_norm(params["ln_f"], x, cfg.norm_variant)
    logits = L.unembed(params["embed"], x.astype(jnp.float32),
                       cfg.tie_embeddings, cfg.logit_softcap,
                       cfg.vocab_size)
    return logits[:, 0], new_cache
