"""Mixture-of-Experts FFN (phi3.5-moe 16e top-2, dbrx 16e top-4).

Capacity-based gather dispatch (TPU-native, static shapes):

  1. router logits -> top-k experts + renormalized gates per token
  2. position-in-expert by cumulative count; tokens past capacity drop
  3. scatter token ids into an (E, C) slot table (collision-free by
     construction), gather token activations -> (E, C, d)
  4. batched expert matmuls (E sharded over the ``model`` mesh axis)
  5. gather-combine: each token reads back its k slots, weighted by gate

Under fsdp_tp the slot gather/scatter across the token (data) and expert
(model) axes lowers to the all-to-all pattern GShard describes.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.param import spec
from repro.sharding import constrain


def moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": spec((d, e), ("embed", "experts")),
        "wi": spec((e, d, 2 * f), ("experts", "expert_mlp", "mlp")),
        "wo": spec((e, f, d), ("experts", "mlp", "expert_mlp")),
    }


def capacity(tokens: int, cfg: ModelConfig) -> int:
    c = int(math.ceil(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8


def apply_moe(p, x, cfg: ModelConfig, tcfg: TrainConfig):
    """x: (B, S, d) -> (y, aux_loss).

    With tcfg.moe_seq_chunks > 1 the sequence is processed in chunks through
    the experts (routing + capacity become chunk-local), bounding the expert
    hidden / dispatch buffers at long sequence lengths — required to fit
    prefill_32k for the 132B MoE in HBM."""
    ch = max(tcfg.moe_seq_chunks, 1)
    if ch > 1 and x.shape[1] % ch == 0 and x.shape[1] >= 2 * ch:
        b, s, d = x.shape
        xs = x.reshape(b, ch, s // ch, d).transpose(1, 0, 2, 3)
        ys, auxs = jax.lax.map(
            lambda xc: _apply_moe_dense(p, xc, cfg, tcfg), xs)
        return (ys.transpose(1, 0, 2, 3).reshape(b, s, d),
                jnp.mean(auxs))
    return _apply_moe_dense(p, x, cfg, tcfg)


def _apply_moe_dense(p, x, cfg: ModelConfig, tcfg: TrainConfig):
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    c = capacity(t, cfg)
    cd = x.dtype
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate, expert = jax.lax.top_k(probs, k)                       # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # position-in-expert: for flattened (T*k) assignments in order
    onehot = jax.nn.one_hot(expert, e, dtype=jnp.int32)          # (T, k, E)
    flat_oh = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat_oh, axis=0) * flat_oh                   # 1-indexed
    pos = (pos.sum(-1) - 1).reshape(t, k)                        # (T, k)
    keep = pos < c
    slot = expert * c + pos                                       # (T, k)
    slot = jnp.where(keep, slot, e * c)                           # overflow slot

    # slot -> token id table (E*C + 1 overflow)
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, k)).reshape(-1)
    slot_token = jnp.zeros((e * c + 1,), jnp.int32).at[slot.reshape(-1)] \
        .set(token_ids, mode="drop")
    slot_fill = jnp.zeros((e * c + 1,), jnp.bool_).at[slot.reshape(-1)] \
        .set(keep.reshape(-1), mode="drop")

    # dispatch: optionally compress the token activations crossing the
    # expert (model) axis to fp8 — halves the all-to-all wire bytes
    # (DeepSeek-V3-style low-precision dispatch; beyond-paper lever)
    if tcfg.moe_dispatch_dtype:
        from repro.config import dtype_of
        dd = dtype_of(tcfg.moe_dispatch_dtype)
        xd = xf.astype(dd)
    else:
        xd = xf
    gathered = xd[slot_token[:e * c]].astype(cd) * \
        slot_fill[:e * c, None].astype(cd)
    gathered = gathered.reshape(e, c, d)
    gathered = constrain(gathered, ("act_experts", None, None),
                         preset=tcfg.shard_preset)

    # expert SwiGLU
    h = jnp.einsum("ecd,edf->ecf", gathered, p["wi"].astype(cd))
    u, g = jnp.split(h, 2, axis=-1)
    h = u * jax.nn.silu(g)
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cd))        # (E, C, d)
    y = constrain(y, ("act_experts", None, None), preset=tcfg.shard_preset)
    y_flat = y.reshape(e * c, d)
    y_flat = jnp.concatenate([y_flat, jnp.zeros((1, d), cd)], axis=0)

    # combine: token t reads its k slots
    picked = y_flat[slot]                                        # (T, k, d)
    w = (gate * keep.astype(jnp.float32)).astype(cd)
    out = jnp.einsum("tkd,tk->td", picked, w).reshape(b, s, d)

    # load-balancing aux loss (Switch): E * sum_e f_e * P_e
    frac = jnp.mean(onehot.astype(jnp.float32).sum(1), axis=0)   # tokens/expert
    mean_p = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(frac * mean_p) / k
    return out, aux


def apply_moe_block(p, x, cfg, tcfg, *, positions, window, kv_cache=None,
                    cache_index=None, cache_mode="update"):
    """Transformer block with MoE FFN; mirrors transformer.apply_block."""
    from repro.models import layers as L
    from repro.models.transformer import apply_attention
    h, cache = apply_attention(
        p["attn"], L.apply_norm(p["ln1"], x, cfg.norm_variant), cfg, tcfg,
        positions=positions, window=window, kv_cache=kv_cache,
        cache_index=cache_index, cache_mode=cache_mode)
    x = x + h
    y, aux = apply_moe(p["moe"], L.apply_norm(p["ln2"], x, cfg.norm_variant),
                       cfg, tcfg)
    x = x + y
    x = constrain(x, ("batch", "seq", "act_embed"), preset=tcfg.shard_preset)
    return x, cache, aux
