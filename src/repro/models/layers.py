"""Intermediate-layer building blocks (paper §3.1 "Intermediate Layer").

Norms, MLP variants, embeddings, RoPE / M-RoPE.  All functions are pure; all
parameters come in as pytrees declared via ParamSpec.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.param import spec


# ----------------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------------
def norm_specs(d_model: int, variant: str):
    if variant == "rmsnorm":
        return {"scale": spec((d_model,), ("norm",), init="ones")}
    return {"scale": spec((d_model,), ("norm",), init="ones"),
            "bias": spec((d_model,), ("norm",), init="zeros")}


def apply_norm(p, x, variant: str, eps: float = 1e-6):
    """Statistics accumulate in fp32 WITHOUT materializing an fp32 copy of x
    (an x.astype(f32) at the scanned-layer entry lets XLA convert the whole
    stacked activation checkpoint to f32 — measured 2x activation memory on
    command-r-104b; see EXPERIMENTS.md §Perf)."""
    d = x.shape[-1]
    if variant == "rmsnorm":
        ms = jnp.einsum("...d,...d->...", x, x,
                        preferred_element_type=jnp.float32) / d
        inv = jax.lax.rsqrt(ms + eps)[..., None].astype(x.dtype)
        return x * inv * p["scale"].astype(x.dtype)
    mu = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
    ms = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)[..., None] / d
    var = ms - jnp.square(mu)
    inv = jax.lax.rsqrt(jnp.maximum(var, 0.0) + eps)
    y = (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    return y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)


# ----------------------------------------------------------------------------
# MLP
# ----------------------------------------------------------------------------
def mlp_specs(d_model: int, d_ff: int, variant: str, bias: bool = False):
    s = {}
    if variant in ("swiglu", "geglu"):
        s["wi"] = spec((d_model, 2 * d_ff), ("embed", "mlp"))
        s["wo"] = spec((d_ff, d_model), ("mlp", "embed"))
        if bias:
            s["bi"] = spec((2 * d_ff,), ("mlp",), init="zeros")
            s["bo"] = spec((d_model,), ("norm",), init="zeros")
    else:  # gelu | relu2
        s["wi"] = spec((d_model, d_ff), ("embed", "mlp"))
        s["wo"] = spec((d_ff, d_model), ("mlp", "embed"))
        if bias:
            s["bi"] = spec((d_ff,), ("mlp",), init="zeros")
            s["bo"] = spec((d_model,), ("norm",), init="zeros")
    return s


def apply_mlp(p, x, variant: str):
    h = x @ p["wi"].astype(x.dtype)
    if "bi" in p:
        h = h + p["bi"].astype(x.dtype)
    if variant in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if variant == "swiglu" else jax.nn.gelu(g)
        h = u * act
    elif variant == "relu2":  # minitron/nemotron squared-ReLU
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    y = h @ p["wo"].astype(x.dtype)
    if "bo" in p:
        y = y + p["bo"].astype(x.dtype)
    return y


# ----------------------------------------------------------------------------
# Embeddings (tables padded to padded_vocab for TP divisibility; pad logits
# are masked to -inf so they can never win argmax / affect the softmax)
# ----------------------------------------------------------------------------
def embed_specs(vocab: int, d_model: int, tie: bool, padded_vocab: int = 0):
    pv = padded_vocab or vocab
    s = {"tok": spec((pv, d_model), ("vocab", "embed"), init="embed")}
    if not tie:
        s["unembed"] = spec((d_model, pv), ("embed", "vocab"))
    return s


def embed_tokens(p, tokens, compute_dtype):
    return p["tok"].astype(compute_dtype)[tokens]


def unembed(p, x, tie: bool, softcap: float = 0.0, true_vocab: int = 0):
    if tie:
        logits = x @ p["tok"].astype(x.dtype).T
    else:
        logits = x @ p["unembed"].astype(x.dtype)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    pv = logits.shape[-1]
    if true_vocab and true_vocab < pv:
        mask = jnp.arange(pv) < true_vocab
        logits = jnp.where(mask, logits, -1e30)
    return logits


# ----------------------------------------------------------------------------
# RoPE / M-RoPE
# ----------------------------------------------------------------------------
def _rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S) int32.

    Half-split (GPT-NeoX) rotation: (x1, x2) -> (x1*cos - x2*sin, x2*cos + x1*sin)
    """
    d = x.shape[-1]
    freqs = _rope_freqs(d, theta)                       # (d/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., S, d/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, ...], theta: float = 10000.0):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions3: (B, 3, S) — (temporal, height, width) ids.
    The D/2 frequency dims are split into ``sections`` (t, h, w); each section
    takes its angle from the corresponding position stream.
    """
    d = x.shape[-1]
    half = d // 2
    assert sum(sections) == half, (sections, half)
    freqs = _rope_freqs(d, theta)                        # (half,)
    # angle per stream: (B, 3, S, half)
    ang_all = positions3.astype(jnp.float32)[..., None] * freqs
    # select stream per frequency-section via one-hot contraction
    sec_id = jnp.repeat(jnp.arange(len(sections)),
                        jnp.array(sections), total_repeat_length=half)  # (half,)
    onehot = jax.nn.one_hot(sec_id, len(sections), dtype=jnp.float32)   # (half, 3)
    ang = jnp.einsum("bksf,fk->bsf", ang_all, onehot)    # (B, S, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_positions(batch: int, seq: int, n_vision: int):
    """Synthetic (t,h,w) position ids: a vision block of n_vision patches laid
    out on a sqrt grid followed by text tokens (all three ids equal)."""
    import math
    side = max(int(math.sqrt(max(n_vision, 1))), 1)
    idx = jnp.arange(seq)
    is_vis = idx < n_vision
    t = jnp.where(is_vis, 0, idx - n_vision + (n_vision > 0) * (side - 1) + 1)
    h = jnp.where(is_vis, idx // side, t)
    w = jnp.where(is_vis, idx % side, t)
    pos = jnp.stack([t, h, w], axis=0).astype(jnp.int32)   # (3, S)
    return jnp.broadcast_to(pos[None], (batch, 3, seq))
