"""Model registry: family -> (param_specs, loss, decode, cache, input_specs).

``input_specs(cfg, shape, preset, mesh)`` returns ShapeDtypeStruct stand-ins
for every model input — weak-type-correct, shardable, zero allocation — used
by the multi-pod dry-run and by real batch construction (same shapes).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeSpec
from repro.models import lm, whisper


def param_specs(cfg: ModelConfig):
    if cfg.family == "encdec":
        return whisper.param_specs(cfg)
    return lm.param_specs(cfg)


def loss_fn(cfg: ModelConfig):
    return whisper.loss_fn if cfg.family == "encdec" else lm.loss_fn


def forward_fn(cfg: ModelConfig):
    return whisper.forward if cfg.family == "encdec" else lm.forward


def decode_fn(cfg: ModelConfig):
    return whisper.decode_step if cfg.family == "encdec" else lm.decode_step


def cache_specs(cfg: ModelConfig, batch: int, max_len: int,
                dtype=jnp.bfloat16):
    if cfg.family == "encdec":
        return whisper.cache_specs(cfg, batch, max_len, dtype)
    return lm.cache_specs(cfg, batch, max_len, dtype)


def batch_shapes(cfg: ModelConfig, batch: int, seq: int,
                 kind: str = "train") -> Dict[str, Any]:
    """Logical input shapes+dtypes for one step.

    kind=train/prefill: full sequences; kind=decode: single token.
    """
    if kind == "decode":
        out = {"tokens": ((batch, 1), jnp.int32)}
        return out
    out = {"tokens": ((batch, seq), jnp.int32),
           "labels": ((batch, seq), jnp.int32)}
    if cfg.family == "encdec":
        out["frames"] = ((batch, whisper.enc_len(cfg, seq), cfg.d_model),
                         jnp.bfloat16)
    if cfg.family == "vlm":
        nv = min(cfg.n_vision_tokens, seq)
        out["vision"] = ((batch, nv, cfg.d_model), jnp.bfloat16)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec):
    """ShapeDtypeStructs (no sharding attached; dryrun attaches them)."""
    shapes = batch_shapes(cfg, shape.global_batch, shape.seq_len, shape.kind)
    return {k: jax.ShapeDtypeStruct(s, d) for k, (s, d) in shapes.items()}


def make_batch(rng, cfg: ModelConfig, batch: int, seq: int,
               kind: str = "train"):
    """A real random batch with the same shapes (smoke tests / examples)."""
    shapes = batch_shapes(cfg, batch, seq, kind)
    out = {}
    for k, (shp, dt) in shapes.items():
        rng, sub = jax.random.split(rng)
        if jnp.issubdtype(dt, jnp.integer):
            out[k] = jax.random.randint(sub, shp, 0, cfg.vocab_size, dt)
        else:
            out[k] = jax.random.normal(sub, shp).astype(dt) * 0.02
    return out
