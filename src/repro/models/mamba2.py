"""Mamba-2 (SSD — state-space duality) layer [arXiv:2405.21060].

Chunked SSD algorithm: the sequence is split into chunks of size Q; within a
chunk the output is a masked quadratic form (attention-like, runs on the MXU);
across chunks a tiny (nheads, headdim, dstate) state is carried by a scan.
This gives the paper's "memory-efficient" property for the attention-free
family: no S x S object is ever materialized and decode state is O(1) in S.

Single group (B/C shared across heads), scalar-per-head A — the mamba2-130m
configuration.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.param import spec


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg: ModelConfig) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def mamba_specs(cfg: ModelConfig) -> Dict[str, Any]:
    """Input projections kept separate (not fused) so each output dim TP-shards
    cleanly: d_inner is mesh-divisible; the tiny B/C/dt heads replicate."""
    d = cfg.d_model
    di = d_inner(cfg)
    ds = cfg.ssm_state
    nh = n_ssm_heads(cfg)
    conv_ch = di + 2 * ds
    return {
        "w_z": spec((d, di), ("embed", "ssm_inner")),
        "w_x": spec((d, di), ("embed", "ssm_inner")),
        "w_B": spec((d, ds), ("embed", None)),
        "w_C": spec((d, ds), ("embed", None)),
        "w_dt": spec((d, nh), ("embed", None)),
        "conv_w": spec((cfg.ssm_conv_width, conv_ch), ("conv_width", None)),
        "conv_b": spec((conv_ch,), (None,), init="zeros"),
        "A_log": spec((nh,), (None,), init="zeros"),
        "D": spec((nh,), (None,), init="ones"),
        "dt_bias": spec((nh,), (None,), init="zeros"),
        "norm": spec((di,), ("ssm_inner",), init="ones"),
        "w_out": spec((di, d), ("ssm_inner", "embed")),
    }


def _project(cfg, p, x):
    cd = x.dtype
    z = x @ p["w_z"].astype(cd)
    xi = x @ p["w_x"].astype(cd)
    B_ = x @ p["w_B"].astype(cd)
    C_ = x @ p["w_C"].astype(cd)
    dt = x @ p["w_dt"].astype(cd)
    return z, jnp.concatenate([xi, B_, C_], axis=-1), dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + pad[:, i:i + xbc.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def _segsum(a):
    """a: (..., Q) log-decays -> (..., Q, Q) lower-tri cumulative sums where
    out[i, j] = sum_{j < m <= i} a[m]  (and -inf above diagonal)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, B_, C_, chunk: int, initial_state=None,
                dot_dtype=None):
    """Chunked SSD scan.

    xh: (B, S, nh, hd); dt: (B, S, nh) — positive step sizes
    A: (nh,) negative decay rates; B_, C_: (B, S, ds)
    dot_dtype: optional low precision (bf16) for the quadratic einsum
    operands — decays/cumsums stay fp32 for stability.
    Returns y: (B, S, nh, hd), final_state: (B, nh, hd, ds).
    """
    b, s, nh, hd = xh.shape
    ds = B_.shape[-1]
    n_chunks = s // chunk
    assert n_chunks * chunk == s, (s, chunk)
    dd = dot_dtype or xh.dtype

    xc = xh.reshape(b, n_chunks, chunk, nh, hd)
    dtc = dt.reshape(b, n_chunks, chunk, nh)
    Bc = B_.reshape(b, n_chunks, chunk, ds)
    Cc = C_.reshape(b, n_chunks, chunk, ds)
    dA = dtc * A  # (b, n, q, nh) log-decay per step

    # ---- intra-chunk (quadratic within chunk) ----
    lmat = _segsum(dA.transpose(0, 1, 3, 2))            # (b,n,nh,q,q)
    lmat = jnp.exp(lmat)
    scores = jnp.einsum("bnqs,bnks->bnqk", Cc.astype(dd),
                        Bc.astype(dd)).astype(jnp.float32)
    ymat = scores[:, :, None] * lmat                     # (b,n,nh,q,k)
    ymat = jnp.where(jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, None],
                     ymat, 0.0)
    y_intra = jnp.einsum("bnhqk,bnkh,bnkhd->bnqhd", ymat.astype(dd),
                         dtc.astype(dd), xc.astype(dd)).astype(jnp.float32)

    # ---- chunk states ----
    decay_to_end = jnp.exp(jnp.cumsum(dA[..., ::-1, :], axis=2)[..., ::-1, :]
                           - dA)                          # sum_{m>q} dA_m
    states = jnp.einsum("bnqs,bnqh,bnqh,bnqhd->bnhds",
                        Bc, dtc, decay_to_end, xc)        # (b,n,nh,hd,ds)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))            # (b,n,nh)
    s0 = (initial_state if initial_state is not None
          else jnp.zeros((b, nh, hd, ds), xh.dtype))

    def scan_body(prev, inp):
        st, dec = inp
        new = prev * dec[:, :, None, None] + st
        return new, prev

    final, prev_states = jax.lax.scan(
        scan_body, s0.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (b,n,nh,hd,ds)

    # ---- inter contribution ----
    decay_from_start = jnp.exp(jnp.cumsum(dA, axis=2))     # (b,n,q,nh)
    y_inter = jnp.einsum("bnqs,bnqh,bnhds->bnqhd",
                         Cc, decay_from_start,
                         prev_states.astype(xh.dtype))
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    return y, final.astype(xh.dtype)


def apply_mamba(p, x, cfg: ModelConfig, tcfg: TrainConfig, state=None):
    """Full mamba2 mixer.  x: (B, S, d).

    state: None (training) or dict(conv=(B, W-1, C), ssm=(B, nh, hd, ds)) for
    stateful decode.  The stateful path accepts any S >= 1 (chunked prefill):
    the projections and the causal conv batch over the chunk, while the tiny
    recurrent state update scans token-by-token *inside* the jit — numerics
    identical to S single-token decode steps, at one dispatch per chunk.
    Returns (y, new_state).
    """
    b, s, d = x.shape
    di, ds_, nh, hd = d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg), cfg.ssm_head_dim
    cd = x.dtype
    z, xbc, dt = _project(cfg, p, x)

    if state is None:
        xbc = _causal_conv(xbc, p["conv_w"].astype(cd), p["conv_b"].astype(cd))
        xi, B_, C_ = xbc[..., :di], xbc[..., di:di + ds_], xbc[..., di + ds_:]
        xh = xi.reshape(b, s, nh, hd)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) +
                              p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        chunk = min(cfg.ssm_chunk, s)
        pad = (-s) % chunk  # right-pad: zero x contributes nothing causally
        if pad:
            xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp = jnp.pad(dtp, ((0, 0), (0, pad), (0, 0)))
            B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
            C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
        dot_dtype = cd if cd != jnp.float32 else None
        y, _ = ssd_chunked(xh.astype(jnp.float32), dtp, A,
                           B_.astype(jnp.float32), C_.astype(jnp.float32),
                           chunk, dot_dtype=dot_dtype)
        if pad:
            y = y[:, :s]
            xh = xh[:, :s]
        y = y + xh.astype(jnp.float32) * p["D"].astype(jnp.float32)[:, None]
        new_state = None
    else:
        # recurrent decode: s >= 1 (conv window carried across calls)
        conv_buf = state["conv"]                          # (B, W-1, C)
        window = jnp.concatenate([conv_buf, xbc], axis=1)  # (B, W-1+s, C)
        conv_w = p["conv_w"].astype(cd)
        width = conv_w.shape[0]
        conv_out = jnp.zeros_like(xbc)
        for i in range(width):
            conv_out = conv_out + window[:, i:i + s] * conv_w[i]
        xbc_c = jax.nn.silu(conv_out + p["conv_b"].astype(cd))  # (B, s, C)
        xi, B_, C_ = xbc_c[..., :di], xbc_c[..., di:di + ds_], xbc_c[..., di + ds_:]
        xh = xi.reshape(b, s, nh, hd).astype(jnp.float32)
        dtp = jax.nn.softplus(dt.astype(jnp.float32) +
                              p["dt_bias"].astype(jnp.float32))  # (B,s,nh)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        B32 = B_.astype(jnp.float32)
        C32 = C_.astype(jnp.float32)

        def step(ssm, inp):
            xh_t, dt_t, B_t, C_t = inp                    # per-token slices
            dec = jnp.exp(dt_t * A)                       # (B, nh)
            upd = jnp.einsum("bhp,bh,bs->bhps", xh_t, dt_t, B_t)
            ssm = ssm * dec[:, :, None, None] + upd
            y_t = jnp.einsum("bhps,bs->bhp", ssm, C_t)
            return ssm, y_t

        ssm_f, ys = jax.lax.scan(
            step, state["ssm"].astype(jnp.float32),
            (xh.transpose(1, 0, 2, 3), dtp.transpose(1, 0, 2),
             B32.transpose(1, 0, 2), C32.transpose(1, 0, 2)))
        y = ys.transpose(1, 0, 2, 3)                      # (B, s, nh, hd)
        y = y + xh * p["D"].astype(jnp.float32)[:, None]
        new_state = {"conv": window[:, s:].astype(conv_buf.dtype),
                     "ssm": ssm_f.astype(state["ssm"].dtype)}

    # gated RMSNorm + out projection
    y = y.reshape(b, s, di)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    y = y * jax.lax.rsqrt(ms + 1e-6) * p["norm"].astype(jnp.float32)
    y = y.astype(cd) @ p["w_out"].astype(cd)
    return y, new_state


def mamba_state_specs(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    di, ds_, nh, hd = (d_inner(cfg), cfg.ssm_state, n_ssm_heads(cfg),
                       cfg.ssm_head_dim)
    conv_ch = di + 2 * ds_
    return {
        "conv": spec((cfg.n_layers, batch, cfg.ssm_conv_width - 1, conv_ch),
                     ("layers", "cache_batch", None, None),
                     init="zeros", dtype=dtype),
        "ssm": spec((cfg.n_layers, batch, nh, hd, cfg.ssm_state),
                    ("layers", "cache_batch", None, None, None),
                    init="zeros", dtype=dtype),
    }
