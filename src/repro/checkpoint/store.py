"""Fault-tolerant checkpoint store.

- step-granular directories ``<dir>/step_<n>/`` with a JSON manifest + one
  safetensors payload (named leaves from the state pytree)
- atomic: written to ``.tmp-<n>`` then os.rename'd — a crash mid-write never
  corrupts the latest checkpoint (restart test covers this)
- async: ``CheckpointStore.save_async`` snapshots to host memory on the
  caller's thread, writes on a background thread (training continues)
- elastic: ``restore`` places leaves with *target* shardings — restoring onto
  a different mesh shape / preset / device count just works because the
  payload stores the full logical arrays (single-host container semantics;
  on a real pod each host writes its addressable shards — noted in DESIGN.md)
- retention: keep the newest ``keep`` checkpoints.
- offload-aware: under ``--offload-segments`` the state already lives in mmap
  segment files, so ``save_offload`` just hardlinks them (zero-copy) and
  ``restore_offload`` hardlinks them back (see repro/offload/).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint.safetensors import load_safetensors, save_safetensors
from repro.param import flatten_names


def _state_to_named(state) -> Dict[str, np.ndarray]:
    return {name: np.asarray(leaf) for name, leaf in flatten_names(state)}


def save(state, directory: str, step: int, keep: int = 3,
         extra_meta: Optional[Dict[str, Any]] = None):
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    named = _state_to_named(jax.device_get(state))
    save_safetensors(os.path.join(tmp, "state.safetensors"), named,
                     metadata={"step": str(step),
                               **{k: str(v) for k, v in
                                  (extra_meta or {}).items()}})
    manifest = {"step": step, "time": time.time(),
                "meta": dict(extra_meta or {}),
                "leaves": {k: [list(v.shape), str(v.dtype)]
                           for k, v in named.items()}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(_list_steps(directory))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(directory: str):
    out = []
    if not os.path.isdir(directory):
        return out
    for d in os.listdir(directory):
        if d.startswith("step_"):
            try:
                out.append(int(d[5:]))
            except ValueError:
                pass
    return out


def latest_step(directory: str) -> Optional[int]:
    steps = _list_steps(directory)
    return max(steps) if steps else None


def restore(directory: str, like_state, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like_state`` (values ignored).  If
    ``shardings`` (matching pytree of NamedSharding) is given, leaves are
    device_put into that layout — this is the elastic-rescale path."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", "state.safetensors")
    named, _ = load_safetensors(path)
    names = [n for n, _ in flatten_names(like_state)]
    leaves_like = jax.tree.leaves(like_state)
    treedef = jax.tree.structure(like_state)
    new_leaves = []
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(names))
    for name, like, sh in zip(names, leaves_like, sh_leaves):
        arr = np.asarray(named[name])
        if hasattr(like, "dtype") and str(arr.dtype) != str(like.dtype):
            arr = arr.astype(like.dtype)
        new_leaves.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), step


# ----------------------------------------------------------------------------
# Segment-offload checkpoints (paper C1 phone realization; repro/offload/)
# ----------------------------------------------------------------------------
# The offload engine already keeps the whole state in mmap segment files, so
# a checkpoint is just a hardlink snapshot of those files (zero-copy: no byte
# of state is staged through RAM).  The engine flips to copy-on-write, so
# later training steps never mutate the snapshot's inodes.
#
# ``ostate.snapshot`` runs behind the engine's flush barrier: with async
# write-back enabled, every dirty segment still in the background write
# queue lands on flash *before* the hardlinks are taken — a snapshot can
# never capture a segment file whose write-back is mid-flight.

def save_offload(ostate, directory: str, step: int, keep: int = 3) -> str:
    """Snapshot an ``OffloadedTrainState`` into ``<dir>/step_<n>/segments``.
    Atomic (tmp + rename) and subject to the same retention as ``save``."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f".tmp-{step}")
    final = os.path.join(directory, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    ostate.snapshot(os.path.join(tmp, "segments"))
    # the storage codecs travel with the checkpoint (the hardlinked mapping
    # table is authoritative; the manifest copy makes them greppable and
    # feeds the resume guards without opening the segment store)
    manifest = {"step": step, "time": time.time(), "offload": True,
                "state_bytes": int(ostate.state_bytes),
                "moment_dtype": ostate.moment_dtype,
                "base_quant": getattr(ostate, "base_quant", "")}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(directory, keep)
    return final


def is_offload_checkpoint(directory: str, step: int) -> bool:
    return os.path.isdir(os.path.join(directory, f"step_{step:08d}",
                                      "segments"))


def checkpoint_meta(directory: str, step: int) -> Dict[str, Any]:
    """Extra metadata stamped into a checkpoint's manifest at save time
    (e.g. the seed/LoRA hyperparameters an adapter-only checkpoint depends
    on).  Empty for checkpoints written before the field existed."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    if not os.path.isfile(path):
        return {}
    with open(path) as f:
        return json.load(f).get("meta", {})


def is_adapter_checkpoint(directory: str, step: int) -> bool:
    """True for adapter-only checkpoints (frozen-base streamed LoRA): the
    manifest lists ``lora.*`` leaves but no base/params tree — the frozen
    base is re-derived from the seed on resume, never persisted."""
    path = os.path.join(directory, f"step_{step:08d}", "manifest.json")
    if not os.path.isfile(path):
        return False
    with open(path) as f:
        leaves = json.load(f).get("leaves", {})
    return (any(k.startswith("lora.") for k in leaves)
            and not any(k.startswith(("base.", "params.")) for k in leaves))


def offload_checkpoint_layout(directory: str, step: int) -> str:
    """Segment layout of an offload checkpoint: "layer_v1" (layer-aligned,
    param-streaming) or "" (byte-balanced optimizer offload)."""
    table = os.path.join(directory, f"step_{step:08d}", "segments",
                         "table.json")
    with open(table) as f:
        return json.load(f).get("meta", {}).get("layout", "")


def restore_offload(directory: str, work_dir: str, like_params,
                    step: Optional[int] = None, *, max_resident: int = 2,
                    prefetch: bool = True, async_writeback: bool = True,
                    io_backend: str = ""):
    """Reattach to an offload checkpoint by hardlinking its segment files
    into ``work_dir`` (copy-on-write).  Dispatches on the stored segment
    layout: layer-aligned checkpoints come back as ``LayerStreamedState``,
    byte-balanced ones as ``OffloadedTrainState``.  Returns (state, step)."""
    from repro.offload.state import (LAYER_LAYOUT, LayerStreamedState,
                                     OffloadedTrainState)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    seg_dir = os.path.join(directory, f"step_{step:08d}", "segments")
    cls = (LayerStreamedState
           if offload_checkpoint_layout(directory, step) == LAYER_LAYOUT
           else OffloadedTrainState)
    ostate = cls.from_checkpoint(
        seg_dir, work_dir, like_params, max_resident=max_resident,
        prefetch=prefetch, async_writeback=async_writeback,
        io_backend=io_backend)
    return ostate, step


class CheckpointStore:
    """Async wrapper with SIGTERM-safe flush (preemption tolerance)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        # _thread is owned by the caller thread (save_*/wait are never
        # called concurrently); _error crosses the writer boundary
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._error: Optional[BaseException] = None  # guarded-by: _lock

    def wait(self):
        """Join the in-flight background write, then surface any exception
        it stored — a failed async save must fail the *next*
        synchronization point (mirrors ``AsyncWriter._error``), not vanish
        with its thread while training keeps overwriting the window."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        with self._lock:
            err, self._error = self._error, None
        if err is not None:
            raise RuntimeError(
                "async checkpoint write failed") from err

    def save_async(self, state, step: int, extra_meta=None):
        self.wait()
        host_state = jax.device_get(state)  # snapshot before returning

        def _write():
            try:
                save(host_state, self.directory, step, keep=self.keep,
                     extra_meta=extra_meta)
            except BaseException as e:  # surfaced on next wait()/save_*
                with self._lock:
                    self._error = e

        self._thread = threading.Thread(target=_write, daemon=False)
        self._thread.start()

    def save_sync(self, state, step: int, extra_meta=None):
        self.wait()
        return save(state, self.directory, step, keep=self.keep,
                    extra_meta=extra_meta)

    def save_offload(self, ostate, step: int):
        """Zero-copy (hardlink) snapshot of an OffloadedTrainState — cheap
        enough that no async thread is needed."""
        self.wait()
        return save_offload(ostate, self.directory, step, keep=self.keep)
