"""Minimal pure-python safetensors reader/writer (paper C7: model I/O).

Implements the format: 8-byte LE header length, JSON header mapping tensor
name -> {dtype, shape, data_offsets}, then the raw little-endian buffer.
Supports F32/F16/BF16/I32/I64 — enough for LLM weights + LoRA adapters and
round-trips with PyTorch/HF loaders.
"""
from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

_TO_ST = {"float32": "F32", "float16": "F16", "bfloat16": "BF16",
          "int32": "I32", "int64": "I64", "uint16": "U16", "int8": "I8",
          "uint8": "U8", "bool": "BOOL"}
_FROM_ST = {v: k for k, v in _TO_ST.items()}


def _np_view(arr: np.ndarray):
    if arr.dtype.name == "bfloat16":
        return arr.view(np.uint16), "BF16"
    return arr, _TO_ST[arr.dtype.name]


def save_safetensors(path: str, tensors: Dict[str, np.ndarray],
                     metadata: Dict[str, str] = None):
    header = {}
    offset = 0
    bufs = []
    for name, arr in tensors.items():
        arr = np.asarray(arr)
        if arr.ndim:  # ascontiguousarray promotes 0-d to 1-d; keep scalars 0-d
            arr = np.ascontiguousarray(arr)
        view, st_dtype = _np_view(arr)
        raw = view.tobytes()
        header[name] = {"dtype": st_dtype, "shape": list(arr.shape),
                        "data_offsets": [offset, offset + len(raw)]}
        bufs.append(raw)
        offset += len(raw)
    if metadata:
        header["__metadata__"] = {str(k): str(v) for k, v in metadata.items()}
    hj = json.dumps(header).encode("utf-8")
    pad = (-len(hj)) % 8
    hj += b" " * pad
    with open(path, "wb") as f:
        f.write(struct.pack("<Q", len(hj)))
        f.write(hj)
        for raw in bufs:
            f.write(raw)


def save_adapter(path: str, lora_params, *, rank: int, alpha: float,
                 targets=(), base_quant: str = "", base_tag: str = "") -> str:
    """Export the bare LoRA adapter: flat ``lora.<leaf>`` tensors plus the
    PEFT hyperparameters in the metadata, so a config is reproducible from
    the file alone.  ``base_quant`` records the frozen-base codec the
    adapter was trained against (an adapter learns around the quantization
    error, so "int8" vs fp32 matters at apply time); ``base_tag`` pins the
    exact frozen base (arch + seed + dtype + quant) so the serving tier can
    refuse an adapter trained against a different base.  Pairs with
    ``save_merged`` for deployment."""
    from repro.param import flatten_names
    named = {"lora." + n: np.asarray(v) for n, v in flatten_names(lora_params)}
    save_safetensors(path, named, metadata={
        "format": "lora_adapter", "lora_rank": rank, "lora_alpha": alpha,
        "lora_targets": ",".join(targets),
        "base_quant": base_quant or "fp32", "base_tag": base_tag})
    return path


def load_adapter(path: str):
    """Load an ``adapter.safetensors`` back into the nested LoRA tree that
    ``merge_lora`` consumes.  Returns (lora_tree, peft_meta) where peft_meta
    has parsed types: ``rank`` int, ``alpha`` float, ``targets`` tuple,
    ``base_quant`` normalized ("" = fp32), ``base_tag`` str."""
    tensors, meta = load_safetensors(path)
    if meta.get("format") != "lora_adapter":
        raise ValueError(f"{path} is not a LoRA adapter export "
                         f"(format={meta.get('format')!r})")
    lora: Dict[str, object] = {}
    for name, arr in tensors.items():
        if not name.startswith("lora."):
            continue
        parts = name[len("lora."):].split(".")
        node = lora
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = np.array(arr)
    bq = meta.get("base_quant", "fp32")
    peft_meta = {
        "rank": int(meta.get("lora_rank", 0)),
        "alpha": float(meta.get("lora_alpha", 0.0)),
        "targets": tuple(t for t in meta.get("lora_targets", "").split(",")
                         if t),
        "base_quant": "" if bq in ("", "fp32") else bq,
        "base_tag": meta.get("base_tag", ""),
    }
    return lora, peft_meta


def save_merged(path: str, base_params, lora_params, *, rank: int,
                alpha: float) -> str:
    """Export deployment weights W' = W + (alpha/rank) A@B at every adapted
    leaf (repro.core.lora.export_merged) — one self-contained model file,
    no adapter needed at load time."""
    from repro.core.lora import export_merged
    from repro.param import flatten_names
    merged = export_merged(base_params, lora_params, rank=rank, alpha=alpha)
    named = {n: np.asarray(v) for n, v in flatten_names(merged)}
    save_safetensors(path, named, metadata={
        "format": "merged_model", "lora_rank": rank, "lora_alpha": alpha})
    return path


def load_safetensors(path: str):
    """Returns (tensors dict, metadata dict).  BF16 loads as uint16 view with
    a ml_dtypes.bfloat16 reinterpretation when available."""
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<Q", f.read(8))
        header = json.loads(f.read(hlen))
        body = f.read()
    meta = header.pop("__metadata__", {})
    out = {}
    for name, info in header.items():
        a, b = info["data_offsets"]
        dtype = _FROM_ST[info["dtype"]]
        if info["dtype"] == "BF16":
            try:
                import ml_dtypes
                np_dt = np.dtype(ml_dtypes.bfloat16)
            except ImportError:
                np_dt = np.uint16
            arr = np.frombuffer(body[a:b], dtype=np.uint16).view(np_dt)
        else:
            arr = np.frombuffer(body[a:b], dtype=np.dtype(dtype))
        out[name] = arr.reshape(tuple(info["shape"]))
    return out, meta
