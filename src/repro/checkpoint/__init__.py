from repro.checkpoint.store import (CheckpointStore, latest_step,  # noqa: F401
                                    restore, save)
from repro.checkpoint.safetensors import (load_safetensors,  # noqa: F401
                                          save_adapter, save_merged,
                                          save_safetensors)
