"""Repo-specific concurrency lint (``python -m tools.repro_analysis.lint``).

Every concurrency bug shipped so far — the buffer-pool ``IndexError``, the
silently-dying ``AsyncWriter`` thread, the Prefetcher stale-read race —
was caught by human review only.  This pass machine-checks the conventions
those reviews established, over plain ``ast`` (zero new dependencies):

RA001  guarded-by        A field declared ``# guarded-by: _lock`` on its
                         ``__init__`` assignment line may only be touched
                         inside ``with self._lock:`` (or in a function
                         annotated ``# holds: _lock``, which documents the
                         AsyncWriter._raise_pending_error calling contract).
RA002  thread-lifecycle  Every ``threading.Thread`` / ``ThreadPoolExecutor``
                         construction needs a reachable ``join``/``shutdown``
                         in its owning scope, and a Thread's target must
                         contain an exception-surfacing ``try``/``except``
                         (the ``AsyncWriter._error`` pattern — an unhandled
                         exception kills the thread silently and turns the
                         next queue interaction into a deadlock).
RA003  host-sync-in-hot-path  Inside functions annotated ``# hot-path``
                         (the streamed sweep loops and the serving
                         step/decode paths), host synchronizations —
                         ``float()``/``int()`` on non-literals,
                         ``np.asarray``/``np.array``, ``jax.device_get``,
                         ``.item()``, ``.block_until_ready()`` — must sit on
                         a line whitelisted with ``# sync-point``.  The
                         functions in ``REQUIRED_HOT_PATH`` must carry the
                         annotation (so deleting the comment cannot silently
                         drop the rule).
RA004  donated-arg-reuse A variable passed at a donated position of a
                         ``jax.jit(..., donate_argnums=...)`` function
                         defined in the same module must not be read after
                         the call (its buffer may have been invalidated) —
                         including wraparound reuse in a loop when the
                         variable is never rebound.

Per-line waivers (each must carry a reason where the syntax allows one):
``# unguarded-ok: <why>`` (RA001), ``# thread-ok: <why>`` (RA002),
``# sync-point`` (RA003), ``# donate-ok`` (RA004).

Exit status 1 when any violation is found; 0 on a clean tree.
"""
from __future__ import annotations

import argparse
import ast
import io
import os
import re
import sys
import tokenize
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

# Functions that MUST be annotated ``# hot-path`` (keyed by path suffix):
# the streamed trainer's sweep loops and the serving engine's step/decode
# paths.  PR 5 and PR 7 each re-established the no-host-sync invariant in
# these by hand; the lint keeps it machine-checked.
REQUIRED_HOT_PATH: Dict[str, Tuple[str, ...]] = {
    "repro/core/stream.py": (
        "_forward_sweep", "_two_sweeps", "_two_sweeps_lora",
        "_update_sweep", "_sink", "__call__",
    ),
    "repro/serve/engine.py": (
        "step", "_decode_step", "_prefill_step", "_block_call",
        "_materialize",
    ),
}

RULES = {
    "RA001": "guarded-by: lock-guarded field touched outside its lock",
    "RA002": "thread-lifecycle: thread without join/shutdown or "
             "exception surfacing",
    "RA003": "host-sync-in-hot-path: host synchronization in a hot path "
             "without a # sync-point waiver",
    "RA004": "donated-arg-reuse: variable reused after being donated to "
             "a jitted call",
}

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_]\w*)")
_HOLDS_RE = re.compile(r"#\s*holds:\s*([A-Za-z_]\w*)")
_HOT_RE = re.compile(r"#\s*hot-path\b")
_SYNC_OK_RE = re.compile(r"#\s*sync-point\b")
_THREAD_OK_RE = re.compile(r"#\s*thread-ok:")
_DONATE_OK_RE = re.compile(r"#\s*donate-ok\b")
_UNGUARDED_OK_RE = re.compile(r"#\s*unguarded-ok:")
_SELF_FIELD_RE = re.compile(r"self\.([A-Za-z_]\w*)\s*[:=]")


@dataclass(frozen=True)
class Violation:
    path: str
    line: int
    col: int
    code: str
    message: str

    def __str__(self):
        return f"{self.path}:{self.line}:{self.col}: {self.code} " \
               f"{self.message}"


# ---------------------------------------------------------------------------
# parsed-file context
# ---------------------------------------------------------------------------

class FileCtx:
    """One parsed source file: AST with parent links + comment map."""

    def __init__(self, source: str, path: str):
        self.path = path
        self.source = source
        self.tree = ast.parse(source, filename=path)
        self.comments: Dict[int, str] = {}
        try:
            for tok in tokenize.generate_tokens(
                    io.StringIO(source).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string
        except tokenize.TokenError:  # pragma: no cover - parse-able files
            pass                     # tokenize fine in practice
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                child._ra_parent = node  # type: ignore[attr-defined]

    # -- comment helpers ---------------------------------------------------
    def comment_at(self, line: int) -> str:
        return self.comments.get(line, "")

    def line_waived(self, line: int, pattern: re.Pattern) -> bool:
        """A waiver applies on the node's line or the line above it."""
        return bool(pattern.search(self.comment_at(line))
                    or pattern.search(self.comment_at(line - 1)))

    def def_annotated(self, fn: ast.AST, pattern: re.Pattern) -> bool:
        """Annotation on a def: the ``def`` line, or the line above the
        def / its first decorator."""
        first = fn.lineno
        for dec in getattr(fn, "decorator_list", []):
            first = min(first, dec.lineno)
        return bool(pattern.search(self.comment_at(fn.lineno))
                    or pattern.search(self.comment_at(first - 1)))

    # -- ancestry helpers --------------------------------------------------
    @staticmethod
    def parents(node: ast.AST):
        cur = getattr(node, "_ra_parent", None)
        while cur is not None:
            yield cur
            cur = getattr(cur, "_ra_parent", None)

    def enclosing_functions(self, node: ast.AST):
        for p in self.parents(node):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield p

    def enclosing_class(self, node: ast.AST) -> Optional[ast.ClassDef]:
        for p in self.parents(node):
            if isinstance(p, ast.ClassDef):
                return p
        return None


def _is_self_attr(node: ast.AST, attr: Optional[str] = None) -> bool:
    return (isinstance(node, ast.Attribute)
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
            and (attr is None or node.attr == attr))


def _pos(node: ast.AST) -> Tuple[int, int]:
    return (node.lineno, node.col_offset)


def _end_pos(node: ast.AST) -> Tuple[int, int]:
    return (getattr(node, "end_lineno", node.lineno),
            getattr(node, "end_col_offset", node.col_offset))


# ---------------------------------------------------------------------------
# RA001 — guarded-by lock discipline
# ---------------------------------------------------------------------------

def _guarded_fields(ctx: FileCtx, cls: ast.ClassDef) -> Dict[str, str]:
    """``# guarded-by: <lock>`` declarations inside this class body:
    field name -> lock attribute name.  The comment sits on the line of the
    field's ``self.<field> = ...`` assignment (conventionally in
    ``__init__``)."""
    fields: Dict[str, str] = {}
    start = cls.lineno
    end = getattr(cls, "end_lineno", start)
    for line in range(start, end + 1):
        m = _GUARDED_RE.search(ctx.comment_at(line))
        if not m:
            continue
        src_line = ctx.source.splitlines()[line - 1]
        fm = _SELF_FIELD_RE.search(src_line)
        if fm:
            fields[fm.group(1)] = m.group(1)
    return fields


def _holds_lock(ctx: FileCtx, fn: ast.AST) -> Optional[str]:
    """The lock named by a ``# holds: <lock>`` annotation on ``fn``'s def
    line (or the line above it / its first decorator), else None."""
    first = fn.lineno
    for dec in getattr(fn, "decorator_list", []):
        first = min(first, dec.lineno)
    for line in (fn.lineno, first - 1):
        m = _HOLDS_RE.search(ctx.comment_at(line))
        if m:
            return m.group(1)
    return None


def _inside_with_lock(ctx: FileCtx, node: ast.AST, lock: str) -> bool:
    """Is ``node`` lexically inside a ``with self.<lock>:`` block?"""
    for p in ctx.parents(node):
        if isinstance(p, (ast.With, ast.AsyncWith)):
            for item in p.items:
                expr = item.context_expr
                if _is_self_attr(expr, lock):
                    return True
                # ``with self._lock.something():`` style — not used, but a
                # Call on the lock attribute still counts as holding it
                if isinstance(expr, ast.Call) and \
                        _is_self_attr(expr.func) and \
                        _is_self_attr(getattr(expr.func, "value", None),
                                      lock):
                    return True
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # a nested def/lambda may escape the with-block it is defined
            # in, but conservatively we keep walking: the convention is
            # that closures created under the lock run under the lock
            continue
    return False


def _check_guarded_by(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    for cls in ast.walk(ctx.tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        fields = _guarded_fields(ctx, cls)
        if not fields:
            continue
        for node in ast.walk(cls):
            if not isinstance(node, ast.Attribute) or \
                    not _is_self_attr(node):
                continue
            lock = fields.get(node.attr)
            if lock is None:
                continue
            fns = list(ctx.enclosing_functions(node))
            if ctx.enclosing_class(node) is not cls:
                continue
            # construction happens-before any thread start: __init__ of the
            # declaring class is exempt
            if fns and fns[-1].name == "__init__":
                continue
            if _inside_with_lock(ctx, node, lock):
                continue
            if any(_holds_lock(ctx, fn) == lock for fn in fns):
                continue
            if ctx.line_waived(node.lineno, _UNGUARDED_OK_RE):
                continue
            out.append(Violation(
                ctx.path, node.lineno, node.col_offset, "RA001",
                f"self.{node.attr} is declared guarded-by self.{lock} but "
                f"is touched outside 'with self.{lock}:' (wrap the access, "
                f"annotate the function '# holds: {lock}', or waive with "
                f"'# unguarded-ok: <why>')"))
    return out


# ---------------------------------------------------------------------------
# RA002 — thread lifecycle
# ---------------------------------------------------------------------------

def _callee_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _binding_target(ctx: FileCtx, call: ast.Call) -> Optional[ast.AST]:
    """The assignment target the constructed object is bound to (walks
    through ternaries): ``self.X`` Attribute or Name node, else None."""
    for p in ctx.parents(call):
        if isinstance(p, ast.Assign) and p.targets:
            t = p.targets[0]
            if isinstance(t, (ast.Attribute, ast.Name)):
                return t
            return None
        if isinstance(p, (ast.IfExp,)):
            continue
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.ClassDef, ast.Module)):
            return None
    return None


def _scope_of(ctx: FileCtx, node: ast.AST, want_class: bool) -> ast.AST:
    for p in ctx.parents(node):
        if want_class and isinstance(p, ast.ClassDef):
            return p
        if not want_class and isinstance(
                p, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return p
    return ctx.tree


def _has_lifecycle_call(scope: ast.AST, target: ast.AST,
                        methods: Tuple[str, ...]) -> bool:
    """Does ``scope`` contain ``<target>.join(...)`` / ``.shutdown(...)``?"""
    want_attr = target.attr if isinstance(target, ast.Attribute) else None
    want_name = target.id if isinstance(target, ast.Name) else None
    for node in ast.walk(scope):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in methods):
            continue
        obj = node.func.value
        if want_attr is not None and _is_self_attr(obj, want_attr):
            return True
        if want_name is not None and isinstance(obj, ast.Name) and \
                obj.id == want_name:
            return True
    return False


def _resolve_target_fn(ctx: FileCtx, call: ast.Call
                       ) -> Tuple[Optional[ast.AST], bool]:
    """Resolve the ``target=`` of a Thread(...) construction to a function
    node.  Returns (fn_node_or_None, resolvable)."""
    target_expr = None
    for kw in call.keywords:
        if kw.arg == "target":
            target_expr = kw.value
    if target_expr is None and call.args:
        target_expr = call.args[0]
    if target_expr is None:
        return None, False
    name = None
    if _is_self_attr(target_expr):
        name = target_expr.attr
        scope: Optional[ast.AST] = ctx.enclosing_class(call)
    elif isinstance(target_expr, ast.Name):
        name = target_expr.id
        scope = _scope_of(ctx, call, want_class=False)
    else:
        return None, False
    if scope is None:
        return None, False
    for node in ast.walk(scope):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name == name:
            return node, True
    return None, False


def _surfaces_exceptions(fn: ast.AST) -> bool:
    """The AsyncWriter._error pattern, approximated: the thread body
    contains a try/except whose handler actually *does* something (stores
    the exception / notifies a waiter) rather than swallowing it."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Try):
            continue
        for handler in node.handlers:
            body = [s for s in handler.body
                    if not isinstance(s, ast.Pass)]
            if body:
                return True
    return False


def _check_thread_lifecycle(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    for call in ast.walk(ctx.tree):
        if not isinstance(call, ast.Call):
            continue
        name = _callee_name(call)
        if name not in ("Thread", "ThreadPoolExecutor"):
            continue
        if ctx.line_waived(call.lineno, _THREAD_OK_RE):
            continue
        target = _binding_target(ctx, call)
        if target is None:
            out.append(Violation(
                ctx.path, call.lineno, call.col_offset, "RA002",
                f"{name} constructed without a binding — nothing can "
                f"join/shutdown it (bind it, or waive with "
                f"'# thread-ok: <why>')"))
            continue
        scope = _scope_of(ctx, call,
                          want_class=isinstance(target, ast.Attribute))
        methods = ("join",) if name == "Thread" else ("shutdown",)
        if not _has_lifecycle_call(scope, target, methods):
            out.append(Violation(
                ctx.path, call.lineno, call.col_offset, "RA002",
                f"{name} bound to "
                f"{ast.unparse(target)} has no reachable "
                f"{' or '.join(m + '()' for m in methods)} in its owning "
                f"scope"))
        if name == "Thread":
            fn, resolvable = _resolve_target_fn(ctx, call)
            if resolvable and fn is not None and \
                    not _surfaces_exceptions(fn):
                out.append(Violation(
                    ctx.path, call.lineno, call.col_offset, "RA002",
                    f"Thread target '{getattr(fn, 'name', '?')}' has no "
                    f"exception-surfacing try/except — an unhandled "
                    f"exception kills the thread silently (store it like "
                    f"AsyncWriter._error and re-raise at the next "
                    f"synchronization point)"))
    return out


# ---------------------------------------------------------------------------
# RA003 — host syncs in hot paths
# ---------------------------------------------------------------------------

def _is_host_sync(node: ast.Call) -> Optional[str]:
    f = node.func
    if isinstance(f, ast.Name) and f.id in ("float", "int"):
        if node.args and not isinstance(node.args[0], ast.Constant):
            return f"{f.id}()"
        return None
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name) and f.value.id in ("np", "numpy") \
                and f.attr in ("asarray", "array"):
            return f"np.{f.attr}()"
        if isinstance(f.value, ast.Name) and f.value.id == "jax" and \
                f.attr == "device_get":
            return "jax.device_get()"
        if f.attr in ("item", "block_until_ready"):
            return f".{f.attr}()"
    return None


def _check_hot_path(ctx: FileCtx) -> List[Violation]:
    out: List[Violation] = []
    norm = ctx.path.replace(os.sep, "/")
    required: Tuple[str, ...] = ()
    for suffix, names in REQUIRED_HOT_PATH.items():
        if norm.endswith(suffix):
            required = names
    hot_fns: List[ast.AST] = []
    seen_required: Set[str] = set()
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        annotated = ctx.def_annotated(node, _HOT_RE)
        if annotated:
            hot_fns.append(node)
        if node.name in required:
            seen_required.add(node.name)
            if not annotated:
                out.append(Violation(
                    ctx.path, node.lineno, node.col_offset, "RA003",
                    f"'{node.name}' is a designated hot path in this file "
                    f"and must be annotated '# hot-path' (on or above its "
                    f"def line)"))
    for fn in hot_fns:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            what = _is_host_sync(node)
            if what is None:
                continue
            if ctx.line_waived(node.lineno, _SYNC_OK_RE):
                continue
            out.append(Violation(
                ctx.path, node.lineno, node.col_offset, "RA003",
                f"{what} in hot path '{fn.name}' forces a host sync on "
                f"the overlap-pipelined path — move it off the critical "
                f"path or whitelist the line with '# sync-point'"))
    return out


# ---------------------------------------------------------------------------
# RA004 — donated-argument reuse
# ---------------------------------------------------------------------------

def _donated_indices(call: ast.Call) -> Optional[Set[int]]:
    """``jax.jit(..., donate_argnums=...)`` or
    ``functools.partial(jax.jit, donate_argnums=...)`` -> donated
    positions, else None."""
    f = call.func
    is_jit = (isinstance(f, ast.Attribute) and f.attr == "jit") or \
             (isinstance(f, ast.Name) and f.id == "jit")
    is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") or \
                 (isinstance(f, ast.Name) and f.id == "partial")
    if is_partial:
        # partial(jax.jit, donate_argnums=...) — first arg must be jit
        if not (call.args and (
                (isinstance(call.args[0], ast.Attribute)
                 and call.args[0].attr == "jit")
                or (isinstance(call.args[0], ast.Name)
                    and call.args[0].id == "jit"))):
            return None
    elif not is_jit:
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return {v.value}
        if isinstance(v, (ast.Tuple, ast.List)):
            idx = set()
            for el in v.elts:
                if isinstance(el, ast.Constant) and \
                        isinstance(el.value, int):
                    idx.add(el.value)
            return idx
    return None


def _binding_scope(ctx: FileCtx, node: ast.AST) -> ast.AST:
    for p in ctx.parents(node):
        if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                          ast.Lambda)):
            return p
    return ctx.tree


def _collect_donating_fns(ctx: FileCtx
                          ) -> Dict[str, List[Tuple[ast.AST, Set[int]]]]:
    """Names bound to jitted functions with donated argnums — decorated
    defs and ``name = jax.jit(f, donate_argnums=...)`` (or the partial
    form) assignments — keyed by name, each with its *binding scope* so a
    same-named variable in another function never matches."""
    donating: Dict[str, List[Tuple[ast.AST, Set[int]]]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    idx = _donated_indices(dec)
                    if idx:
                        donating.setdefault(node.name, []).append(
                            (_binding_scope(ctx, node), idx))
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.targets[0], ast.Name):
            v = node.value
            if isinstance(v, ast.Call):
                idx = _donated_indices(v)
                if idx is None and isinstance(v.func, ast.Call):
                    # partial(jax.jit, ...)(f)
                    idx = _donated_indices(v.func)
                if idx:
                    donating.setdefault(node.targets[0].id, []).append(
                        (_binding_scope(ctx, node), idx))
    return donating


def _assign_targets_names(stmt: ast.AST) -> Set[str]:
    names: Set[str] = set()
    targets = []
    if isinstance(stmt, ast.Assign):
        targets = stmt.targets
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                names.add(n.id)
    return names


def _check_donation(ctx: FileCtx) -> List[Violation]:
    donating = _collect_donating_fns(ctx)
    if not donating:
        return []
    out: List[Violation] = []
    for call in ast.walk(ctx.tree):
        if not (isinstance(call, ast.Call)
                and isinstance(call.func, ast.Name)
                and call.func.id in donating):
            continue
        if ctx.line_waived(call.lineno, _DONATE_OK_RE):
            continue
        # scope: nearest enclosing function / lambda / module
        scope: ast.AST = ctx.tree
        for p in ctx.parents(call):
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                scope = p
                break
        # the donated binding must be visible from the call: bound in the
        # call's own scope or an enclosing one (a same-named local in a
        # *different* function is a different object)
        visible_scopes = {scope, ctx.tree}
        visible_scopes.update(
            p for p in ctx.parents(call)
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)))
        indices: Optional[Set[int]] = None
        for bscope, idx in donating[call.func.id]:
            if bscope in visible_scopes:
                indices = idx
                break
        if indices is None:
            continue
        # is the result rebound onto the donated name at the call site?
        rebound: Set[str] = set()
        for p in ctx.parents(call):
            if isinstance(p, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                rebound = _assign_targets_names(p)
                break
            if isinstance(p, ast.stmt):
                break
        loop: Optional[ast.AST] = None
        for p in ctx.parents(call):
            if isinstance(p, (ast.For, ast.While)):
                loop = p
                break
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                break
        call_end = _end_pos(call)
        for i in sorted(indices):
            if i >= len(call.args):
                continue
            arg = call.args[i]
            if not isinstance(arg, ast.Name):
                continue
            var = arg.id
            if var in rebound:
                continue
            stores = []
            loads = []
            for n in ast.walk(scope):
                if isinstance(n, ast.Name) and n.id == var:
                    if isinstance(n.ctx, ast.Store):
                        stores.append(_pos(n))
                    elif isinstance(n.ctx, ast.Load) and n is not arg:
                        loads.append(_pos(n))
            bad = None
            for lp in sorted(loads):
                if lp > call_end and not any(
                        call_end < sp <= lp for sp in stores):
                    bad = lp
                    break
            if bad is None and loop is not None:
                # wraparound: inside a loop with no rebinding of the
                # donated name anywhere in the loop body, any load in the
                # loop — including the donating call site itself —
                # re-executes after the donation
                loop_start, loop_end = _pos(loop), _end_pos(loop)
                in_loop = lambda p: loop_start <= p <= loop_end  # noqa: E731
                if not any(in_loop(sp) for sp in stores):
                    for lp in sorted(loads + [_pos(arg)]):
                        if in_loop(lp):
                            bad = lp
                            break
            if bad is not None:
                out.append(Violation(
                    ctx.path, call.lineno, call.col_offset, "RA004",
                    f"'{var}' is donated (argument {i} of "
                    f"{call.func.id}, donate_argnums) but read again at "
                    f"line {bad[0]} — its buffer may be invalidated by "
                    f"the jit; rebind the result or waive with "
                    f"'# donate-ok'"))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

ALL_CHECKS = {
    "RA001": _check_guarded_by,
    "RA002": _check_thread_lifecycle,
    "RA003": _check_hot_path,
    "RA004": _check_donation,
}


def lint_source(source: str, path: str = "<string>",
                select: Optional[Sequence[str]] = None) -> List[Violation]:
    """Lint one source string (fixture-level entry point for tests)."""
    ctx = FileCtx(source, path)
    out: List[Violation] = []
    for code, check in ALL_CHECKS.items():
        if select is None or code in select:
            out.extend(check(ctx))
    return sorted(out, key=lambda v: (v.path, v.line, v.col, v.code))


def lint_file(path: str, select: Optional[Sequence[str]] = None
              ) -> List[Violation]:
    with open(path, encoding="utf-8") as f:
        src = f.read()
    try:
        return lint_source(src, path, select)
    except SyntaxError as e:
        return [Violation(path, e.lineno or 0, e.offset or 0, "RA000",
                          f"syntax error: {e.msg}")]


def iter_py_files(paths: Sequence[str]):
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = [d for d in dirs
                           if d not in ("__pycache__", ".git")]
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def run_lint(paths: Sequence[str],
             select: Optional[Sequence[str]] = None) -> List[Violation]:
    out: List[Violation] = []
    for path in iter_py_files(paths):
        out.extend(lint_file(path, select))
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_analysis.lint",
        description="repo-specific concurrency lint (RA001-RA004)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes (default: all)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)
    if args.list_rules:
        for code, desc in RULES.items():
            print(f"{code}  {desc}")
        return 0
    select = args.select.split(",") if args.select else None
    violations = run_lint(args.paths or ["src"], select)
    for v in violations:
        print(v)
    n_files = sum(1 for _ in iter_py_files(args.paths or ["src"]))
    if violations:
        print(f"\n{len(violations)} violation(s) across {n_files} files",
              file=sys.stderr)
        return 1
    print(f"clean: {n_files} files, 0 violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
