"""Schedule-fuzzing race scenarios (``python -m tools.repro_analysis.race``).

Each scenario builds the *real* threaded components — OffloadEngine,
Prefetcher, AsyncWriter, StreamedBase — under
:func:`tools.repro_analysis.schedules.fuzzed_primitives`, drives a seeded
operation sequence through them, and asserts the conservation invariants
the paper's preemption-heavy setting depends on:

- **no lost dirty bytes**: after ``close()`` the segment files hold
  exactly the shadow model's last-written value for every dirtied segment
  (and the original bytes for everything else);
- **window consistency**: every ``acquire`` observes the shadow value —
  a recycled/pooled buffer must never leak stale bytes into a pull;
- **pool accounting exact**: ``_pool_sets`` equals the summed free-list
  lengths and no emptied signature list survives (the PR 5 IndexError
  class);
- **stats monotone**: counters sampled mid-run never decrease;
- **no deadlock**: every run finishes inside a watchdog budget, with all
  thread stacks dumped on timeout.

``--quick`` sweeps a fixed seed set (>= 200 interleavings per scenario)
sized for CI; ``--full`` is the nightly-style long sweep.  Both modes
also run the pinned PR 5 regression replays in both directions
(``tools.repro_analysis.replays``): fail on pre-fix logic, pass current.
"""
from __future__ import annotations

import argparse
import os
import random
import sys
import tempfile
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.offload.engine import AsyncWriter, OffloadEngine
from repro.serve.base import StreamedBase

from tools.repro_analysis import replays
from tools.repro_analysis.schedules import (MonotoneStats, Schedule,
                                            fuzzed_primitives,
                                            run_with_watchdog)

N_SEGMENTS = 6
MONOTONE_KEYS = ("hits", "misses", "write_hits", "prefetch_hits",
                 "sync_loads", "bytes_read", "bytes_written",
                 "peak_resident_bytes")


def _check_pool_accounting(engine: OffloadEngine, where: str = "") -> None:
    pf = engine._prefetcher
    if pf is None:
        return
    with pf._lock:
        total = sum(len(v) for v in pf._pool.values())
        assert pf._pool_sets == total, (
            f"pool accounting drifted {where}: _pool_sets={pf._pool_sets} "
            f"vs {total} listed sets")
        assert all(pf._pool.values()), (
            f"emptied signature list left in the pool {where} "
            f"(the PR 5 IndexError precondition)")


def _expected(shadow: Dict[int, float], original, seg: int, name: str):
    if seg in shadow:
        return np.full(original[seg][name].shape, shadow[seg],
                       original[seg][name].dtype)
    return original[seg][name]


# ---------------------------------------------------------------------------
# scenario: mixed acquire/dirty/release/flush vs concurrent prefetch
# ---------------------------------------------------------------------------

def scenario_engine_mixed(seed: int, tmpdir: str) -> None:
    sched = Schedule(seed)
    store = replays.make_store(os.path.join(tmpdir, "s"),
                               n_segments=N_SEGMENTS, seed=seed)
    original = {s: store.read_segment(s, copy=True, window=True)
                for s in range(N_SEGMENTS)}
    with fuzzed_primitives(sched):
        eng = OffloadEngine(store, max_resident=2, prefetch=True,
                            async_writeback=True)
    rng = random.Random(seed * 7919 + 1)
    shadow: Dict[int, float] = {}
    mono = MonotoneStats(MONOTONE_KEYS)
    # writable-window contract: one owner thread issues every window call
    # (incl. prefetch — cross-thread prefetch is a read-only-window
    # affordance, exercised by scenario_serve_walk).  The races under test
    # here are owner vs the engine's *internal* Prefetcher reader and
    # AsyncWriter threads, which the fuzzed locks stretch apart.
    for op_i in range(28):
        seg = rng.randrange(N_SEGMENTS)
        r = rng.random()
        if r < 0.45:                           # mutate + dirty
            data = eng.acquire(seg)
            val = float(seed % 1000) + op_i + 0.5
            for name in data:
                data[name][...] = val
            eng.mark_dirty(seg)
            shadow[seg] = val
        elif r < 0.65:                         # window-consistency read
            data = eng.acquire(seg)
            for name in data:
                want = _expected(shadow, original, seg, name)
                assert np.allclose(data[name], want), (
                    f"seed {seed} op {op_i}: acquire({seg})[{name}] "
                    f"saw stale bytes")
        elif r < 0.78:                         # overlap: hint the reader
            eng.prefetch((seg + 1) % N_SEGMENTS)
        elif r < 0.88:
            eng.release(seg)
        elif r < 0.96:
            eng.flush()
        else:
            _check_pool_accounting(eng, f"(seed {seed} op {op_i})")
        mono.sample(eng.stats(), f"(seed {seed} op {op_i})")
        sched.pause("mixed.op")
    eng.close()
    _check_pool_accounting(eng, f"(seed {seed} final)")
    for seg in range(N_SEGMENTS):              # no lost dirty bytes
        back = store.read_segment(seg, copy=True, window=True)
        for name in back:
            want = _expected(shadow, original, seg, name)
            assert np.allclose(back[name], want), (
                f"seed {seed}: segment {seg} leaf {name} lost dirty bytes")


# ---------------------------------------------------------------------------
# scenario: AsyncWriter submit/steal/barrier churn
# ---------------------------------------------------------------------------

def scenario_writer_churn(seed: int, tmpdir: str) -> None:
    sched = Schedule(seed)
    store = replays.make_store(os.path.join(tmpdir, "s"),
                               n_segments=N_SEGMENTS, seed=seed)
    template = {s: store.read_segment(s, copy=True, window=True)
                for s in range(N_SEGMENTS)}
    recycled: List[int] = []
    with fuzzed_primitives(sched):
        w = AsyncWriter(store, max_pending=2,
                        recycle=lambda seg, data: recycled.append(seg))
    rng = random.Random(seed * 7919 + 3)
    shadow: Dict[int, float] = {}
    last_writes = 0

    def fresh(seg: int, val: float):
        return {name: np.full(a.shape, val, a.dtype)
                for name, a in template[seg].items()}

    for op_i in range(30):
        seg = rng.randrange(N_SEGMENTS)
        r = rng.random()
        if r < 0.55:
            val = float(seed % 1000) + op_i + 0.25
            w.submit(seg, fresh(seg, val))
            shadow[seg] = val
        elif r < 0.8:
            hit = w.steal(seg)
            if hit is not None:
                data, dirty = hit
                if dirty:                      # stolen bytes never landed:
                    val = float(seed % 1000) + op_i + 0.75
                    w.submit(seg, fresh(seg, val))   # conserve by resubmit
                    shadow[seg] = val
        else:
            w.barrier()
            assert not w._pending and w._writing is None
        assert w.writes >= last_writes, "writes went backwards"
        last_writes = w.writes
        sched.pause("churn.op")
    w.close()
    for seg, val in shadow.items():            # no lost dirty bytes
        back = store.read_segment(seg, copy=True, window=True)
        for name, a in back.items():
            assert np.allclose(a, val), (
                f"seed {seed}: segment {seg} leaf {name} lost bytes "
                f"(want {val})")


# ---------------------------------------------------------------------------
# scenario: StreamedBase layer walk (staging worker vs dispatch thread)
# ---------------------------------------------------------------------------

class _FakeLState:
    """Minimal LayerStreamedState stand-in over a real read-only
    OffloadEngine — the StreamedBase contract surface without a model."""

    frozen = True
    base_quant = ""

    def __init__(self, store, n_layers: int, gate: Optional[Dict] = None):
        self.n_layers = n_layers
        self.head_segment = n_layers
        self.engine = OffloadEngine(store, max_resident=2, prefetch=True,
                                    read_only=True)
        self._gate = gate or {}

    def layer_params(self, i: int):
        g = self._gate.get(i)
        if g is not None:
            if not g["event"].wait(timeout=20.0):
                raise TimeoutError(f"gate for layer {i} never released")
            if g.get("raise"):
                raise RuntimeError(f"injected pull failure (layer {i})")
        return {k: np.array(v) for k, v in self.engine.acquire(i).items()}

    def head_params(self):
        return {k: np.array(v)
                for k, v in self.engine.acquire(self.head_segment).items()}

    def prefetch_layer(self, i: int):
        self.engine.prefetch(i)

    def stats(self):
        return self.engine.stats()

    def close(self):
        self.engine.close()


def _serve_store(tmpdir: str, n_layers: int, seed: int):
    # n_layers block segments + one head segment
    return replays.make_store(os.path.join(tmpdir, "s"),
                              n_segments=n_layers + 1, seed=seed)


def scenario_serve_walk(seed: int, tmpdir: str) -> None:
    n_layers = N_SEGMENTS - 1
    sched = Schedule(seed)
    store = _serve_store(tmpdir, n_layers, seed)
    original = {s: store.read_segment(s, copy=True, window=True)
                for s in range(n_layers + 1)}
    with fuzzed_primitives(sched):
        base = StreamedBase(_FakeLState(store, n_layers))
    mono = MonotoneStats(MONOTONE_KEYS)
    for sweep in range(2):
        head = base.head()
        for name, a in head.items():
            assert np.allclose(a, original[n_layers][name]), \
                f"seed {seed}: head leaf {name} corrupted"
        for i in range(n_layers):
            base.prefetch(i + 2)
            base.stage(i + 1)
            blk = base.block(i)
            for name, a in blk.items():
                assert np.allclose(a, original[i][name]), (
                    f"seed {seed} sweep {sweep}: block {i} leaf {name} "
                    f"corrupted")
            mono.sample(base.lstate.stats(),
                        f"(seed {seed} sweep {sweep} block {i})")
    stats = base.stats()
    assert stats["head_reads"] == 1, (
        f"seed {seed}: pinned head segment read {stats['head_reads']} "
        f"times (want exactly 1)")
    base.close()


# ---------------------------------------------------------------------------
# scenario: StreamedBase.close with a stage future in flight (satellite)
# ---------------------------------------------------------------------------

def scenario_close_inflight_stage(seed: int, tmpdir: str) -> None:
    n_layers = 4
    rng = random.Random(seed * 7919 + 5)
    for inject_error in (False, True):
        sched = Schedule(seed + (1_000_000 if inject_error else 0))
        sub = os.path.join(tmpdir, "err" if inject_error else "ok")
        store = _serve_store(sub, n_layers, seed)
        gate = {1: {"event": threading.Event(), "raise": inject_error}}
        with fuzzed_primitives(sched):
            base = StreamedBase(_FakeLState(store, n_layers, gate=gate))
        base.stage(1)                          # worker parks on the gate
        releaser = threading.Timer(rng.random() * 0.02,
                                   gate[1]["event"].set)
        releaser.start()
        try:
            if inject_error:
                try:
                    base.close()
                    raise AssertionError(
                        f"seed {seed}: close() swallowed the in-flight "
                        f"stage failure")
                except RuntimeError:
                    pass                       # surfaced after cleanup
            else:
                base.close()                   # must drain, not hang
        finally:
            releaser.join()
        assert base._worker is None, "worker must be shut down"
        base.stage(2)                          # post-close: a no-op
        with base._lock:
            assert not base._staged, "post-close stage() resurrected pool"
        try:
            base.block(0)
            raise AssertionError("block() after close must raise")
        except RuntimeError:
            pass


# ---------------------------------------------------------------------------
# scenario: OffloadEngine.close with a non-empty write queue (satellite)
# ---------------------------------------------------------------------------

def scenario_close_pending_writes(seed: int, tmpdir: str) -> None:
    sched = Schedule(seed)
    store = replays.make_store(os.path.join(tmpdir, "s"),
                               n_segments=N_SEGMENTS, seed=seed)
    with fuzzed_primitives(sched):
        eng = OffloadEngine(store, max_resident=1, prefetch=True,
                            async_writeback=True)
    shadow: Dict[int, float] = {}
    # a max_resident=1 window dirties + evicts on every acquire, so the
    # write queue is busy right up to the close() barrier
    for op_i, seg in enumerate(range(N_SEGMENTS)):
        data = eng.acquire(seg)
        val = float(seed % 1000) + op_i + 0.125
        for name in data:
            data[name][...] = val
        eng.mark_dirty(seg)
        shadow[seg] = val
    eng.close()                                # fence + join, queue loaded
    for seg, val in shadow.items():
        back = store.read_segment(seg, copy=True, window=True)
        for name, a in back.items():
            assert np.allclose(a, val), (
                f"seed {seed}: close() lost dirty bytes for segment "
                f"{seg} leaf {name}")


# ---------------------------------------------------------------------------
# scenario: ActivationStore sink/prefetch/take churn (writer vs prefetcher)
# ---------------------------------------------------------------------------

ACT_MONOTONE_KEYS = ("write_hits", "prefetch_hits", "sync_loads", "takes",
                     "bytes_sunk", "bytes_taken", "peak_inflight_bytes")


def scenario_act_store_churn(seed: int, tmpdir: str) -> None:
    """The activation-spill interleavings the streamed two-sweep driver
    produces: a boundary can be re-sunk while its write is queued or
    mid-flight, prefetched while the writer still holds it (the store must
    skip, then steal), and taken from any of the three sources (steal /
    prefetch buffer / sync read) — every take must observe the *last* sunk
    value and micro-batch churn must never leak stale lookahead bytes."""
    from repro.offload.act_store import ActivationStore

    sched = Schedule(seed)
    n, shape = N_SEGMENTS, (4, 3)
    with fuzzed_primitives(sched):
        store = ActivationStore(os.path.join(tmpdir, "acts"), n, shape,
                                codec="identity", depth=2, max_pending=2)
    rng = random.Random(seed * 7919 + 7)
    shadow: Dict[int, float] = {}
    consumed: set = set()
    mono = MonotoneStats(ACT_MONOTONE_KEYS)
    for op_i in range(30):
        i = rng.randrange(n)
        r = rng.random()
        if r < 0.45:                           # (re-)sink a fresh value
            val = float(seed % 1000) + op_i + 0.5
            store.sink(i, np.full(shape, val, np.float32))
            shadow[i] = val
            consumed.discard(i)                # a re-sink re-arms take
        elif r < 0.65:                         # reverse-walk lookahead hint
            store.prefetch(i)
        elif r < 0.9:
            if i in shadow:                    # consume: must see last sink
                got = store.take(i)
                assert np.allclose(got, shadow[i]), (
                    f"seed {seed} op {op_i}: take({i}) saw stale bytes "
                    f"(want {shadow[i]})")
                store.recycle(i, got)
                # takes are consume-once: a dirty steal hands over bytes
                # that never landed on flash, so the store un-sinks the
                # boundary (a second take would read the older spill)
                del shadow[i]
                consumed.add(i)
        else:
            store.barrier()
        mono.sample(store.stats(), f"(seed {seed} op {op_i})")
        sched.pause("act.op")
    # durability through the API: after a barrier every still-sunk
    # boundary must read back its last value (no steal path left — the
    # queue is drained), and a consumed boundary must refuse a re-take
    # instead of serving whatever older spill the file holds
    store.barrier()
    for i, val in sorted(shadow.items(), reverse=True):
        got = store.take(i)
        assert np.allclose(got, val), (
            f"seed {seed}: final take({i}) lost sunk bytes (want {val})")
        store.recycle(i, got)
    for i in sorted(consumed):
        try:
            store.take(i)
        except KeyError:
            pass
        else:
            raise AssertionError(
                f"seed {seed}: take({i}) after consumption must raise "
                "(consume-once contract)")
    # every take was served by exactly one source
    s = store.stats()
    assert s["write_hits"] + s["prefetch_hits"] + s["sync_loads"] == \
        s["takes"], f"seed {seed}: take source accounting drifted: {s}"
    # prefetcher pool accounting exact (the PR 5 IndexError class)
    pf = store._pf
    with pf._lock:
        total = sum(len(v) for v in pf._pool.values())
        assert pf._pool_sets == total, (
            f"seed {seed}: act-store pool accounting drifted "
            f"({pf._pool_sets} vs {total})")
        assert all(pf._pool.values()), (
            f"seed {seed}: emptied signature list left in act-store pool")
    # close with whatever is still queued/in flight: drain, not deadlock
    store.close()


# ---------------------------------------------------------------------------
# scenario: raw reader backends under the Prefetcher (shared SegmentReader)
# ---------------------------------------------------------------------------

def scenario_reader_backends(seed: int, tmpdir: str) -> None:
    """Raw read transports (offload/readers.py) under concurrent pulls:
    the engine's Prefetcher reader thread and the owner's sync loads — plus
    a direct ``store.read_segment`` consumer — share one ``SegmentReader``
    (lock-guarded aligned pool; lock-guarded uring ring).  Every pull must
    be bit-identical to the creation bytes: a recycled staging chunk
    leaking across leaves, a lost short-read tail, or a CQE matched to the
    wrong request all show up as stale/zeroed leaves.  ``drop_cache``
    interleaves so some reads really hit the block layer mid-schedule."""
    from repro.offload.readers import backend_available

    backends = [b for b in ("pread", "uring", "direct")
                if backend_available(b, tmpdir)]
    backend = backends[seed % len(backends)]
    sched = Schedule(seed)
    store = replays.make_store(os.path.join(tmpdir, "s"),
                               n_segments=N_SEGMENTS, mixed=True, seed=seed)
    assert store.set_io_backend(backend) == backend
    window = {s: store.read_segment(s, copy=True, window=True)
              for s in range(N_SEGMENTS)}
    decoded = {s: store.read_segment(s, copy=True)
               for s in range(N_SEGMENTS)}
    with fuzzed_primitives(sched):
        eng = OffloadEngine(store, max_resident=2, prefetch=True)
    rng = random.Random(seed * 7919 + 11)
    mono = MonotoneStats(MONOTONE_KEYS + ("io_bytes_read",
                                          "io_batched_reads"))
    for op_i in range(26):
        seg = rng.randrange(N_SEGMENTS)
        r = rng.random()
        if r < 0.35:                           # window pull via the engine
            data = eng.acquire(seg)
            for name in data:
                assert np.array_equal(data[name], window[seg][name]), (
                    f"seed {seed} op {op_i}: io={backend} acquire({seg})"
                    f"[{name}] returned non-identical bytes")
        elif r < 0.55:                         # overlap: hint the reader
            eng.prefetch((seg + 1) % N_SEGMENTS)
        elif r < 0.7:                          # second consumer, same reader
            got = store.read_segment(seg)
            for name in got:
                assert np.array_equal(got[name], decoded[seg][name]), (
                    f"seed {seed} op {op_i}: io={backend} read_segment"
                    f"({seg})[{name}] returned non-identical bytes")
        elif r < 0.8:
            eng.release(seg)
        elif r < 0.9:                          # force real block-layer reads
            store.drop_cache()
        else:
            _check_pool_accounting(eng, f"(seed {seed} op {op_i})")
        mono.sample(eng.stats(), f"(seed {seed} op {op_i})")
        sched.pause("reader.op")
    stats = eng.stats()
    eng.close()
    _check_pool_accounting(eng, f"(seed {seed} final)")
    assert stats["io_fallbacks"] == 0, (
        f"seed {seed}: io={backend} silently degraded mid-run: {stats}")
    assert store.io_backend == backend


SCENARIOS: Dict[str, Callable[[int, str], None]] = {
    "engine_mixed": scenario_engine_mixed,
    "reader_backends": scenario_reader_backends,
    "writer_churn": scenario_writer_churn,
    "serve_walk": scenario_serve_walk,
    "close_inflight_stage": scenario_close_inflight_stage,
    "close_pending_writes": scenario_close_pending_writes,
    "act_store_churn": scenario_act_store_churn,
}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_scenario(name: str, seed: int, watchdog_s: float = 60.0) -> None:
    fn = SCENARIOS[name]
    with tempfile.TemporaryDirectory(prefix=f"race_{name}_") as tmp:
        run_with_watchdog(lambda: fn(seed, tmp), timeout_s=watchdog_s,
                          label=f"{name}[seed={seed}]")


def run_sweep(names, seeds, watchdog_s: float = 60.0,
              verbose: bool = False) -> int:
    total = 0
    for name in names:
        t0 = time.perf_counter()
        for seed in seeds:
            run_scenario(name, seed, watchdog_s=watchdog_s)
            total += 1
        if verbose:
            print(f"  {name}: {len(list(seeds))} interleavings ok "
                  f"({time.perf_counter() - t0:.1f}s)")
    return total


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_analysis.race",
        description="seeded schedule-fuzzing race harness")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: fixed seeds, >=200 interleavings per "
                         "scenario")
    ap.add_argument("--full", action="store_true",
                    help="nightly-style long sweep (1000 seeds/scenario)")
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None)
    ap.add_argument("--seeds", default=None, metavar="A:B",
                    help="explicit seed range, e.g. 0:50")
    ap.add_argument("--watchdog", type=float, default=60.0,
                    help="per-run deadlock budget in seconds")
    ap.add_argument("--skip-replays", action="store_true",
                    help="skip the pinned PR 5 pre-fix/current replays")
    args = ap.parse_args(argv)

    if args.seeds:
        a, _, b = args.seeds.partition(":")
        seeds = range(int(a), int(b or int(a) + 1))
    elif args.full:
        seeds = range(1000)
    else:
        seeds = range(200)      # --quick default: the CI gate floor
    names = [args.scenario] if args.scenario else sorted(SCENARIOS)

    if not args.skip_replays:
        t0 = time.perf_counter()
        replays.run_all(pre_fix=True)     # the three PR 5 bugs reproduce
        replays.run_all(pre_fix=False)    # ... and are absent today
        print(f"replays: 3 pre-fix bugs reproduced, 0 present "
              f"({time.perf_counter() - t0:.1f}s)")

    t0 = time.perf_counter()
    total = run_sweep(names, seeds, watchdog_s=args.watchdog, verbose=True)
    print(f"race harness: {total} interleavings across {len(names)} "
          f"scenario(s), 0 failures ({time.perf_counter() - t0:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
