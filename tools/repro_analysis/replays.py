"""Deterministic replays of the PR 5 review concurrency bugs.

Each replay pairs a *pre-fix* variant of the component (the exact logic
the PR 5 review found shipping) with a driver sequence that makes the bug
fire every run — no timing, no fuzzing; the same sequence passes against
the current code.  The harness keeps these pinned so a refactor that
silently reintroduces one of the patterns fails CI deterministically:

1. ``PreFixPoolPrefetcher``   buffer-pool ``IndexError``: ``_read`` popped
   a signature's free-list empty without deleting the key, and the
   ``recycle`` evictor popped from whatever signature sat at the front of
   the pool — an emptied-but-present list crashes it.
2. ``PreFixSilentWriter``     the recycle hook ran *outside* the
   ``_run`` try/except: a raising hook killed the writer thread with
   ``_error`` still ``None`` — the next bounded ``submit`` (or
   ``barrier``) then blocks forever with nobody left to drain the queue.
3. ``PreFixDroppyPrefetcher`` ``take()`` dropped the oldest buffered
   segment on *every* wakeup while its segment was still queued — each
   spurious wakeup bled one still-useful prefetched segment back to a
   flash re-read (the fix caps forced drops at one per ``take`` and
   front-runs the queue).

The drivers use only public/engine-internal calls plus explicit
event-style sequencing, so "fails pre-fix, passes current" is a property
of the logic, not the scheduler.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.offload.engine import AsyncWriter, Prefetcher
from repro.offload.segments import SegmentStore


# ---------------------------------------------------------------------------
# shared fixture store
# ---------------------------------------------------------------------------

def make_store(directory: str, n_segments: int = 6, shape=(4, 3),
               mixed: bool = False, seed: int = 0) -> SegmentStore:
    """A small layer-aligned store.  ``mixed=True`` alternates two leaf
    geometries so consecutive segments have different signatures (the
    shape the pool bug needs)."""
    rng = np.random.default_rng(seed)
    groups = []
    for i in range(n_segments):
        shp = (shape[0] + 1, shape[1]) if (mixed and i % 2) else shape
        groups.append([
            (f"p.l{i}", rng.standard_normal(shp).astype(np.float32)),
            (f"m.l{i}", rng.standard_normal(shp).astype(np.float32)),
        ])
    return SegmentStore.create(directory, groups, num_segments=n_segments)


# ---------------------------------------------------------------------------
# 1. buffer-pool IndexError
# ---------------------------------------------------------------------------

class PreFixPoolPrefetcher(Prefetcher):
    """Prefetcher with the pre-fix pool logic: ``_read`` leaves emptied
    free-lists behind and the evictor pops without the defensive
    empty-list check."""

    def _read(self, seg):
        bufs = None
        if self._pooling:
            sig = self._store.segment_signature(seg)
            with self._lock:
                free = self._pool.get(sig)
                if free:
                    bufs = free.pop()
                    self._pool_sets -= 1
                    # PRE-FIX: the emptied list stays keyed in the pool
        data = self._store.read_segment(
            seg, copy=True, encoded=self._encoded,
            window=not self._encoded, out=bufs)
        if bufs is not None:
            self.buffer_reuses += 1
        return data

    def recycle(self, seg, data):
        if not self._pooling or not data:
            return
        arrs = list(data.values())
        if not all(isinstance(a, np.ndarray) for a in arrs):
            return
        sig = self._store.segment_signature(seg)
        with self._lock:
            while self._pool_sets >= self._depth + 1 and self._pool:
                old_sig, free = next(iter(self._pool.items()))
                free.pop()        # PRE-FIX: IndexError on an emptied list
                self._pool_sets -= 1
                if not free:
                    del self._pool[old_sig]
            self._pool.setdefault(sig, []).append(arrs)
            self._pool.move_to_end(sig)
            self._pool_sets += 1


def drive_pool_sequence(pf: Prefetcher, store: SegmentStore) -> None:
    """The crashing sequence (depth=1, mixed signatures A/B):

    recycle(A) -> pool {A:[set]}; _read(A) pops it empty; then three
    recycles of B-signature sets trip the global bound with the emptied
    ``A`` entry at the front of the pool.  Pre-fix the evictor pops the
    empty list (``IndexError``); current code deleted the key in ``_read``
    and skips defensively."""
    def fresh(seg):
        return store.read_segment(seg, copy=True, window=True)

    pf.recycle(0, fresh(0))              # signature A enters the pool
    pf._read(0)                          # pops A's only set
    for _ in range(3):                   # B-signature sets hit the bound
        pf.recycle(1, fresh(1))


def replay_pool_indexerror(tmpdir: str, pre_fix: bool) -> None:
    """Raises ``IndexError`` iff ``pre_fix`` (asserts the dichotomy)."""
    os.environ["REPRO_OFFLOAD_BUFFER_POOL"] = "1"
    try:
        store = make_store(os.path.join(tmpdir, "pool"), n_segments=2,
                           mixed=True)
        cls = PreFixPoolPrefetcher if pre_fix else Prefetcher
        pf = cls(store, depth=1)
        try:
            try:
                drive_pool_sequence(pf, store)
            except IndexError:
                if not pre_fix:
                    raise AssertionError(
                        "current Prefetcher crashed on the pool sequence")
                return
            if pre_fix:
                raise AssertionError(
                    "pre-fix pool logic did not raise IndexError — the "
                    "replay sequence no longer matches the bug")
        finally:
            pf.close()
    finally:
        os.environ.pop("REPRO_OFFLOAD_BUFFER_POOL", None)


# ---------------------------------------------------------------------------
# 2. silent AsyncWriter death
# ---------------------------------------------------------------------------

class PreFixSilentWriter(AsyncWriter):
    """AsyncWriter with the pre-fix ``_run``: the recycle hook runs outside
    any try/except, so a raising hook kills the thread with ``_error``
    still unset."""

    def _run(self):
        while True:
            with self._lock:
                while not self._pending and not self._closed:
                    self._lock.wait()
                if not self._pending:
                    return
                seg, data = self._pending.popitem(last=False)
                self._writing, self._writing_data = seg, data
                self._stolen = False
                self._lock.notify_all()
            t0 = time.perf_counter()
            err = None
            try:
                self._store.pwrite_segment(seg, data)
            except BaseException as e:
                err = e
            self.busy_s += time.perf_counter() - t0
            with self._lock:
                stolen = self._stolen
                self._writing = self._writing_data = None
                if err is not None:
                    self._error = err
                else:
                    self.writes += 1
                    self.bytes_landed += self._store.seg_nbytes[seg]
                    self._unsynced.add(seg)
                self._lock.notify_all()
            if err is None and not stolen and self._recycle is not None:
                self._recycle(seg, data)   # PRE-FIX: unprotected hook


def replay_silent_writer_death(tmpdir: str, pre_fix: bool) -> None:
    """A raising recycle hook must surface on the next submit — pre-fix
    the thread dies silently (``_error`` None, queue never drains)."""
    store = make_store(os.path.join(tmpdir, "writer"), n_segments=4)

    def bad_recycle(seg, data):
        raise RuntimeError("recycle hook exploded")

    cls = PreFixSilentWriter if pre_fix else AsyncWriter
    w = cls(store, max_pending=1, recycle=bad_recycle)
    data = store.read_segment(0, copy=True, window=True)
    if pre_fix:
        # the unprotected hook is *expected* to kill the thread here —
        # keep the default excepthook's traceback out of the test output
        old_hook, threading.excepthook = threading.excepthook, \
            lambda args: None
        try:
            w.submit(0, data)
            w._thread.join(timeout=10.0)  # the hook kills the thread
        finally:
            threading.excepthook = old_hook
        assert not w._thread.is_alive(), \
            "pre-fix writer thread should be dead after the hook raised"
        with w._lock:
            assert w._error is None, \
                "pre-fix writer should have died *silently* (no _error)"
        # the queue is now undrainable: a second bounded submit would
        # block forever — that is the deadlock the watchdog half of the
        # harness exists for, so we stop at the silent-death assertions.
        return
    w.submit(0, data)
    deadline = time.monotonic() + 10.0   # hook error lands in _error
    while time.monotonic() < deadline:
        with w._lock:
            if w._error is not None:
                break
        time.sleep(1e-3)
    assert w._thread.is_alive(), \
        "current writer thread must survive a raising recycle hook"
    try:
        w.submit(1, store.read_segment(1, copy=True, window=True))
        raise AssertionError("current writer must re-raise the stored "
                             "recycle error on the next submit")
    except RuntimeError:
        pass
    w.close()                            # error consumed above; drains


# ---------------------------------------------------------------------------
# 3. take() over-dropping
# ---------------------------------------------------------------------------

class PreFixDroppyPrefetcher(Prefetcher):
    """Prefetcher with the pre-fix ``take``: no single-drop cap and no
    queue front-running — every wakeup with full buffers drops the oldest
    buffered segment while the wanted one is still queued."""

    def take(self, seg):
        with self._lock:
            while not self._closed:
                if seg in self._buffers:
                    self.prefetch_hits += 1
                    data = self._buffers.pop(seg)
                    self._lock.notify_all()
                    return data
                if seg in self._inflight:
                    self._lock.wait()
                elif seg in self._queue:
                    if len(self._buffers) >= self._depth:
                        # PRE-FIX: drop on *every* pass, no front-running
                        self.forced_drops += 1
                        old, old_data = self._buffers.popitem(last=False)
                        self.recycle(old, old_data)
                        self._lock.notify_all()
                    self._lock.wait()
                else:
                    break
            if seg in self._queue:
                self._queue.remove(seg)
        self.sync_loads += 1
        return self._read(seg)


def replay_take_overdrop(tmpdir: str, pre_fix: bool) -> None:
    """Buffers full of {0,1}, queue [3,4,2], then ``take(2)``.

    Pre-fix: each read completion wakes ``take`` which drops another
    still-buffered segment while 2 sits behind 3 and 4 in the queue —
    three forced drops.  Current: 2 is front-run to the queue head and at
    most one stranded buffer is dropped."""
    store = make_store(os.path.join(tmpdir, "droppy"), n_segments=6)
    cls = PreFixDroppyPrefetcher if pre_fix else Prefetcher
    pf = cls(store, depth=2)
    try:
        pf.schedule(0)
        pf.schedule(1)
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            with pf._lock:
                if set(pf._buffers) == {0, 1} and not pf._inflight:
                    break
            time.sleep(1e-3)
        with pf._lock:
            assert set(pf._buffers) == {0, 1}, dict(pf._buffers)
        # buffers are full, so the reader parks and these only queue up
        pf.schedule(3)
        pf.schedule(4)
        pf.schedule(2)
        with pf._lock:
            assert pf._queue == [3, 4, 2], pf._queue
        data = pf.take(2)
        want = store.read_segment(2, copy=True, window=True)
        for name in want:
            assert np.allclose(data[name], want[name]), name
        if pre_fix:
            assert pf.forced_drops >= 2, (
                f"pre-fix take() should cascade-drop (got "
                f"{pf.forced_drops}) — the replay no longer matches")
        else:
            assert pf.forced_drops <= 1, (
                f"current take() must drop at most once per call, got "
                f"{pf.forced_drops}")
    finally:
        pf.close()


REPLAYS = {
    "pool_indexerror": replay_pool_indexerror,
    "silent_writer_death": replay_silent_writer_death,
    "take_overdrop": replay_take_overdrop,
}


def run_all(pre_fix: bool) -> None:
    with tempfile.TemporaryDirectory() as tmp:
        for name, fn in REPLAYS.items():
            sub = os.path.join(tmp, name)
            os.makedirs(sub, exist_ok=True)
            fn(sub, pre_fix)
