"""Concurrency-correctness tier: repo lint rules + schedule-fuzzing race
harness for the offload/serving threads.

Two halves (see CONCURRENCY.md for the thread/lock ownership map):

- ``tools.repro_analysis.lint``   AST lint pass enforcing the repo's
  concurrency conventions (``# guarded-by:``, thread lifecycle,
  hot-path host syncs, jit donation safety)
- ``tools.repro_analysis.race``   deterministic schedule-fuzzing harness
  driving the real OffloadEngine / Prefetcher / AsyncWriter /
  StreamedBase through seeded interleavings under invariant checks,
  plus pinned replays of historical (pre-fix) concurrency bugs
"""
