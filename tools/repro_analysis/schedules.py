"""Seeded schedule fuzzing for the repo's threaded components.

CPython will happily run the Prefetcher/AsyncWriter/StreamedBase threads in
near-lockstep on an idle CI box, so a plain stress test explores a handful
of interleavings forever.  This module widens the schedule space on
purpose: every lock/condition operation passes through a :class:`Schedule`
pause point that (seeded, per thread) yields the GIL or sleeps a few
hundred microseconds, and every ``Condition.wait`` is bounded so spurious
wakeups — which the real code must tolerate anyway — are injected
constantly instead of almost never.

Determinism is *seed-level*: the pause decisions are a pure function of
``(seed, thread-arrival-order, call-count)``, so a failing seed replays
the same perturbation sequence.  (Exact thread interleavings are not
replayable on CPython — the pinned regression replays in
``tools.repro_analysis.replays`` use explicit event gating instead, which
is fully deterministic.)

Injection happens at *construction*: :func:`fuzzed_primitives` patches
``threading.Condition`` / ``threading.Lock`` while the component under
test builds, so the instances it creates are the instrumented wrappers
for their whole lifetime, with no change to the production modules.

``run_with_watchdog`` is the no-deadlock invariant: the scenario runs on a
worker thread and a join timeout converts a hang into a loud failure with
every thread's current stack attached.
"""
from __future__ import annotations

import contextlib
import random
import sys
import threading
import time
import traceback
from typing import Callable, Dict, Optional

_REAL_CONDITION = threading.Condition
_REAL_LOCK = threading.Lock


class Schedule:
    """Seeded per-thread pause-point generator.

    Each thread that reaches a pause point gets its own ``random.Random``
    derived from ``(seed, arrival-order)``; each pause independently
    chooses between running on, yielding the GIL, and a short sleep.  The
    instance counts pause points (``points``) so harness runs can report
    how much schedule space a sweep actually touched.
    """

    def __init__(self, seed: int, max_sleep_s: float = 300e-6,
                 p_sleep: float = 0.25, p_yield: float = 0.5,
                 wait_slice_s: float = 2e-3):
        self.seed = int(seed)
        self.max_sleep_s = float(max_sleep_s)
        self.p_sleep = float(p_sleep)
        self.p_yield = float(p_yield)
        self.wait_slice_s = float(wait_slice_s)
        self.points = 0                      # total pause points hit
        self._meta = threading.Lock()        # orders thread arrival only
        self._n_threads = 0
        self._local = threading.local()

    def _rng(self) -> random.Random:
        rng = getattr(self._local, "rng", None)
        if rng is None:
            with self._meta:
                order = self._n_threads
                self._n_threads += 1
            rng = random.Random((self.seed + 1) * 1_000_003 + order)
            self._local.rng = rng
        return rng

    def pause(self, point: str = "") -> None:
        """One scheduling decision: continue, yield, or micro-sleep."""
        rng = self._rng()
        self.points += 1
        r = rng.random()
        if r < self.p_sleep:
            time.sleep(rng.random() * self.max_sleep_s)
        elif r < self.p_sleep + self.p_yield:
            time.sleep(0)                    # bare GIL yield

    def wait_timeout(self, timeout: Optional[float]) -> float:
        """Bound a ``Condition.wait``: forces periodic spurious wakeups,
        which the repo's wait loops must tolerate by contract."""
        rng = self._rng()
        slice_s = self.wait_slice_s * (0.5 + rng.random())
        if timeout is None:
            return slice_s
        return min(timeout, slice_s)


class FuzzedLock:
    """``threading.Lock`` wrapper pausing around acquire/release."""

    def __init__(self, sched: Schedule):
        self._sched = sched
        self._lock = _REAL_LOCK()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._sched.pause("lock.acquire")
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._sched.pause("lock.acquired")
        return got

    def release(self) -> None:
        self._sched.pause("lock.release")
        self._lock.release()

    def locked(self) -> bool:
        return self._lock.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False


class FuzzedCondition:
    """``threading.Condition`` wrapper pausing at acquire/release/wait/
    notify boundaries and bounding every wait (spurious-wakeup
    injection).  Delegates to a real Condition (whose default RLock keeps
    the repo's nested ``with self._lock`` uses working)."""

    def __init__(self, sched: Schedule, lock=None):
        self._sched = sched
        self._cond = _REAL_CONDITION(lock)

    # -- lock protocol ----------------------------------------------------
    def acquire(self, *args):
        self._sched.pause("cond.acquire")
        got = self._cond.acquire(*args)
        self._sched.pause("cond.acquired")
        return got

    def release(self):
        self._sched.pause("cond.release")
        self._cond.release()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # -- condition protocol ----------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> bool:
        self._sched.pause("cond.wait")
        got = self._cond.wait(self._sched.wait_timeout(timeout))
        self._sched.pause("cond.woke")
        return got

    def wait_for(self, predicate, timeout: Optional[float] = None):
        end = None if timeout is None else time.monotonic() + timeout
        result = predicate()
        while not result:
            remaining = None if end is None else end - time.monotonic()
            if remaining is not None and remaining <= 0:
                break
            self.wait(remaining)
            result = predicate()
        return result

    def notify(self, n: int = 1):
        self._sched.pause("cond.notify")
        self._cond.notify(n)

    def notify_all(self):
        self._sched.pause("cond.notify_all")
        self._cond.notify_all()


@contextlib.contextmanager
def fuzzed_primitives(sched: Schedule):
    """Patch ``threading.Condition`` / ``threading.Lock`` so objects
    constructed inside the block are schedule-instrumented for life.
    Patching is process-global — construction windows from concurrent
    tests must not overlap, so entry is serialized on a module lock."""
    with _PATCH_LOCK:
        threading.Condition = lambda lock=None: FuzzedCondition(sched, lock)
        threading.Lock = lambda: FuzzedLock(sched)
        try:
            yield sched
        finally:
            threading.Condition = _REAL_CONDITION
            threading.Lock = _REAL_LOCK


_PATCH_LOCK = _REAL_LOCK()


class DeadlockError(AssertionError):
    """A scenario failed to finish inside its watchdog budget."""


def _dump_frames() -> str:
    lines = []
    for tid, frame in sys._current_frames().items():
        lines.append(f"--- thread {tid} ---")
        lines.extend(l.rstrip() for l in traceback.format_stack(frame))
    return "\n".join(lines)


def run_with_watchdog(fn: Callable[[], None], timeout_s: float = 30.0,
                      label: str = "scenario") -> None:
    """Run ``fn`` on a worker thread; a join timeout is reported as a
    deadlock with every live thread's stack (the harness's no-deadlock
    invariant).  Exceptions from ``fn`` re-raise on the caller."""
    box: Dict[str, BaseException] = {}

    def _body():
        try:
            fn()
        except BaseException as e:  # surfaced on join below
            box["err"] = e

    t = threading.Thread(target=_body, daemon=True, name=f"wd-{label}")
    t.start()
    t.join(timeout_s)
    if t.is_alive():
        raise DeadlockError(
            f"{label!r} did not finish within {timeout_s:.0f}s — "
            f"deadlock or livelock.  Live threads:\n{_dump_frames()}")
    if "err" in box:
        raise box["err"]


class MonotoneStats:
    """Asserts that a set of counters sampled over time never decreases
    (the 'stats monotone' conservation invariant)."""

    def __init__(self, keys):
        self.keys = tuple(keys)
        self._last: Dict[str, float] = {}

    def sample(self, stats: Dict[str, float], where: str = "") -> None:
        for k in self.keys:
            cur = float(stats.get(k, 0))
            prev = self._last.get(k)
            if prev is not None and cur < prev:
                raise AssertionError(
                    f"stat {k!r} went backwards ({prev} -> {cur}) {where}")
            self._last[k] = cur
