"""Paper-feature unit/property tests: C2 grad accumulation, C5 energy
governor, C6 LoRA, optimizer, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stub

from repro import configs
from repro.config import TrainConfig
from repro.core.accumulate import value_and_grad_accumulated
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.core.lora import export_merged, lora_specs, merge_lora
from repro.core.step import init_state, make_train_step
from repro.models import registry
from repro.optim import adamw_init, adamw_update, clip_by_global_norm
from repro.optim.schedule import lr_schedule
from repro.param import init_params

hypothesis, st = hypothesis_or_stub()


# ---------------------------------------------------------------------------
# C2: gradient accumulation == full batch (paper Tab 7 invariant)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n_micro", [2, 4, 8])
def test_grad_accum_equals_full_batch(n_micro):
    cfg = configs.get_smoke("qwen15_05b")
    tcfg = TrainConfig(global_batch=8, seq_len=8, compute_dtype="float32",
                       attention_impl="streaming", attn_chunk=4)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 8, 8)
    def loss_fn(p, b):
        return registry.loss_fn(cfg)(p, b, cfg, tcfg)

    l1, _, g1 = value_and_grad_accumulated(loss_fn, params, batch, 1)
    lk, _, gk = value_and_grad_accumulated(loss_fn, params, batch, n_micro)
    np.testing.assert_allclose(float(l1), float(lk), rtol=1e-5)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(gk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-5)


def test_grad_compression_dtype():
    cfg = configs.get_smoke("qwen15_05b")
    tcfg = TrainConfig(global_batch=4, seq_len=8, compute_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 4, 8)
    def loss_fn(p, b):
        return registry.loss_fn(cfg)(p, b, cfg, tcfg)
    _, _, g = value_and_grad_accumulated(loss_fn, params, batch, 2,
                                         reduce_dtype=jnp.bfloat16)
    assert all(x.dtype == jnp.bfloat16 for x in jax.tree.leaves(g))


# ---------------------------------------------------------------------------
# C6: LoRA
# ---------------------------------------------------------------------------
def test_lora_zero_init_is_identity():
    """B=0 at init => merged model == base model."""
    cfg = configs.get_smoke("qwen25_05b")
    specs = registry.param_specs(cfg)
    base = init_params(jax.random.PRNGKey(0), specs)
    ls = lora_specs(specs, ("wq", "wv"), rank=4)
    lora = init_params(jax.random.PRNGKey(1), ls)
    merged = export_merged(base, lora, rank=4, alpha=32.0)
    for (na, a), (nb, b) in zip(
            __import__("repro.param", fromlist=["flatten_names"]).flatten_names(base),
            __import__("repro.param", fromlist=["flatten_names"]).flatten_names(merged)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_lora_merge_math():
    w = jnp.eye(4)
    a = jnp.ones((4, 2)) * 0.5
    b = jnp.ones((2, 4)) * 0.25
    base = {"wq": w}
    lora = {"wq": {"a": a, "b": b}}
    merged = merge_lora(base, lora, rank=2, alpha=4.0, train=False)
    expect = w + (4.0 / 2) * (a @ b)
    np.testing.assert_allclose(np.asarray(merged["wq"]), np.asarray(expect),
                               rtol=1e-6)


def test_lora_rejects_targets_matching_nothing():
    """A typo'd (or wrong-family) target list used to produce an empty
    adapter that silently trained zero parameters."""
    cfg = configs.get_smoke("mamba2_130m")     # no wq/wk/wv/wo leaves
    tcfg = TrainConfig(global_batch=2, seq_len=8, lora_rank=4,
                       compute_dtype="float32")
    with pytest.raises(ValueError, match="lora_targets"):
        init_state(jax.random.PRNGKey(0), cfg, tcfg)


def test_lora_trains_only_adapter():
    cfg = configs.get_smoke("qwen25_05b")
    tcfg = TrainConfig(global_batch=2, seq_len=8, lora_rank=4,
                       compute_dtype="float32", learning_rate=1e-2,
                       warmup_steps=0, total_steps=4)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    base_before = jax.tree.map(jnp.copy, state["base"])
    lora_before = jax.tree.map(jnp.copy, state["lora"])
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 2, 8)
    for _ in range(2):
        state, m = step(state, batch)
    for a, b in zip(jax.tree.leaves(base_before),
                    jax.tree.leaves(state["base"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    moved = any(float(jnp.abs(a - b).max()) > 0 for a, b in
                zip(jax.tree.leaves(lora_before),
                    jax.tree.leaves(state["lora"])))
    assert moved


# ---------------------------------------------------------------------------
# Optimizer + schedule
# ---------------------------------------------------------------------------
def test_adamw_against_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(5, 3)).astype(np.float32)
    g = rng.normal(size=(5, 3)).astype(np.float32)
    params = {"w": jnp.asarray(p0)}
    opt = adamw_init(params)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.999, 1e-8, 0.01
    new_p, new_opt = adamw_update({"w": jnp.asarray(g)}, opt, params, lr=lr,
                                  beta1=b1, beta2=b2, eps=eps,
                                  weight_decay=wd)
    m = (1 - b1) * g
    v = (1 - b2) * g * g
    mh = m / (1 - b1)
    vh = v / (1 - b2)
    ref = p0 - lr * (mh / (np.sqrt(vh) + eps) + wd * p0)
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)
    assert int(new_opt["count"]) == 1


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(norm_cap=st.floats(0.1, 10.0), scale=st.floats(0.01, 100.0))
def test_clip_by_global_norm(norm_cap, scale):
    g = {"a": jnp.ones((4,)) * scale, "b": jnp.ones((2, 2)) * scale}
    clipped, norm = clip_by_global_norm(g, norm_cap)
    from repro.optim import global_norm
    assert float(global_norm(clipped)) <= norm_cap * (1 + 1e-4)


def test_lr_schedule_shapes():
    lrs = [float(lr_schedule(s, base_lr=1.0, warmup_steps=10,
                             total_steps=100, kind="cosine"))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(max(lrs) - 1.0) < 1e-6
    assert lrs[10] == max(lrs)
    assert lrs[-1] < 0.2


# ---------------------------------------------------------------------------
# C5: energy governor (paper Fig 11 behavior)
# ---------------------------------------------------------------------------
def test_governor_stretches_interval_below_threshold():
    sleeps = []
    gov = EnergyGovernor(check_every=1, threshold=0.6, reduction=0.5,
                         monitor=SimulatedBattery(level=100.0,
                                                  drain_per_unit=5.0),
                         sleep_fn=sleeps.append)
    step_time = 0.08
    for step in range(20):
        gov.after_step(step, step_time)
    # battery crosses 60% at step 8 (100 - 5/step)
    pre = [h for h in gov.history if not h["throttled"]]
    post = [h for h in gov.history if h["throttled"]]
    assert pre and post
    assert all(h["delay"] == 0 for h in pre)
    # interval stretches to t/(1-rho) = 2x
    for h in post:
        np.testing.assert_allclose(h["interval"], step_time / 0.5, rtol=1e-6)


def test_governor_check_every_k():
    gov = EnergyGovernor(check_every=5, threshold=0.99, reduction=0.5,
                         monitor=SimulatedBattery(level=100.0,
                                                  drain_per_unit=50.0),
                         sleep_fn=lambda s: None)
    gov.after_step(1, 0.1)  # below threshold but not a check step
    assert not gov.throttled
    gov.after_step(5, 0.1)
    assert gov.throttled


def test_governor_rejects_degenerate_reduction():
    """rho >= 1 makes the stretch t/(1-rho) diverge (regression: used to
    reach after_step and die with ZeroDivisionError at rho = 1)."""
    for rho in (1.0, 1.5, -0.1):
        with pytest.raises(ValueError, match="rho"):
            EnergyGovernor(reduction=rho)
    # construction-time validation can be bypassed by mutating the (mutable)
    # dataclass afterwards; after_step must clamp instead of dividing by 0
    gov = EnergyGovernor(check_every=1, threshold=0.99, reduction=0.5,
                         monitor=SimulatedBattery(level=10.0),
                         sleep_fn=lambda s: None)
    gov.reduction = 1.0
    delay = gov.after_step(0, 0.1)       # throttled; must not raise
    assert np.isfinite(delay)


# ---------------------------------------------------------------------------
# C2: split_batch input validation (regression: bare assert, stripped
# under python -O and reporting an opaque tuple)
# ---------------------------------------------------------------------------
def test_split_batch_rejects_indivisible_batch():
    from repro.core.accumulate import split_batch
    batch = {"tokens": jnp.zeros((5, 8), jnp.int32)}
    with pytest.raises(ValueError, match="not divisible"):
        split_batch(batch, 2)
    with pytest.raises(ValueError, match="microbatches"):
        split_batch(batch, 0)
    out = split_batch({"tokens": jnp.zeros((6, 8), jnp.int32)}, 3)
    assert out["tokens"].shape == (3, 2, 8)
