"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, output shapes + finiteness; decode step against a cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.core.step import init_state, make_train_step
from repro.models import registry
from repro.param import init_params

TCFG = TrainConfig(global_batch=2, seq_len=16, compute_dtype="float32",
                   attention_impl="streaming", attn_chunk=8, total_steps=4,
                   warmup_steps=1, learning_rate=1e-3)


@pytest.mark.parametrize("arch", configs.ALL)
def test_smoke_forward_shapes(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    logits, aux = registry.forward_fn(cfg)(params, batch, cfg, TCFG)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all()), arch
    # padded-vocab logits can never win
    assert int(jnp.argmax(logits, -1).max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_smoke_train_step(arch):
    cfg = configs.get_smoke(arch)
    state = init_state(jax.random.PRNGKey(0), cfg, TCFG)
    step = jax.jit(make_train_step(cfg, TCFG))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    losses = []
    for _ in range(3):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses)), (arch, losses)
    assert losses[-1] < losses[0], (arch, losses)  # memorizes one batch


@pytest.mark.parametrize("arch", configs.ASSIGNED)
def test_smoke_decode(arch):
    cfg = configs.get_smoke(arch)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    cache = init_params(jax.random.PRNGKey(1),
                        registry.cache_specs(cfg, 2, 24, jnp.float32))
    logits, new_cache = registry.decode_fn(cfg)(
        params, cache, jnp.ones((2, 1), jnp.int32), jnp.int32(3), cfg, TCFG)
    assert logits.shape == (2, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_decode_matches_forward_dense():
    """Teacher-forced forward and step-by-step decode agree (dense)."""
    cfg = configs.get_smoke("qwen15_05b")
    tcfg = TCFG
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 3,
                              cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    logits_tf, _ = registry.forward_fn(cfg)(params, batch, cfg, tcfg)
    cache = init_params(jax.random.PRNGKey(2),
                        registry.cache_specs(cfg, 2, 12, jnp.float32))
    outs = []
    for i in range(8):
        lg, cache = registry.decode_fn(cfg)(params, cache, toks[:, i:i + 1],
                                            jnp.int32(i), cfg, tcfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_tf),
                               rtol=5e-4, atol=5e-4)


def test_decode_matches_forward_ssm():
    """Recurrent decode == chunked SSD teacher forcing (mamba2)."""
    cfg = configs.get_smoke("mamba2_130m")
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 3,
                              cfg.vocab_size, jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    logits_tf, _ = registry.forward_fn(cfg)(params, batch, cfg, TCFG)
    cache = init_params(jax.random.PRNGKey(2),
                        registry.cache_specs(cfg, 2, 12, jnp.float32))
    outs = []
    for i in range(8):
        lg, cache = registry.decode_fn(cfg)(params, cache, toks[:, i:i + 1],
                                            jnp.int32(i), cfg, TCFG)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_tf),
                               rtol=2e-3, atol=2e-3)


def test_scan_vs_unrolled_layers():
    cfg = configs.get_smoke("minitron_8b")
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    import dataclasses
    t_scan = TCFG
    t_unroll = dataclasses.replace(TCFG, scan_layers=False)
    l1, _ = registry.forward_fn(cfg)(params, batch, cfg, t_scan)
    l2, _ = registry.forward_fn(cfg)(params, batch, cfg, t_unroll)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)


def test_remat_is_exact():
    """C3: activation checkpointing must not change values."""
    import dataclasses
    cfg = configs.get_smoke("qwen25_05b")
    state = init_state(jax.random.PRNGKey(0), cfg, TCFG)
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg, 2, 16)
    outs = {}
    for policy in ("none", "dots", "full"):
        tcfg = dataclasses.replace(TCFG, remat_policy=policy)
        step = jax.jit(make_train_step(cfg, tcfg))
        s2, m = step(jax.tree.map(jnp.copy, state), batch)
        outs[policy] = (float(m["loss"]), float(m["grad_norm"]))
    for policy in ("dots", "full"):
        np.testing.assert_allclose(outs[policy], outs["none"], rtol=1e-5)


def test_moe_routing_properties():
    """Every token gets k experts; gates renormalized; aux loss near 1."""
    from repro.models.moe import apply_moe
    cfg = configs.get_smoke("dbrx_132b")
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = apply_moe(p, x, cfg, TCFG)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # balanced-uniform routing aux ~= coef (Switch normalization)
    assert 0.0 < float(aux) < 10 * cfg.router_aux_coef
