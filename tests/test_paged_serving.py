"""Paged KV cache + decode-side staging (serving perf tier).

Pins the PR 7 invariants:
- mixed-length requests sharing one page pool decode token-identically to
  isolated runs (incl. the hybrid family's paged attention + ssm state path)
- a smaller-than-dense pool admits more concurrent requests than the
  dense-equivalent slot count at the same byte budget
- pages recycle after ``_reap`` and pool exhaustion applies admission
  backpressure instead of rejecting or deadlocking
- the pinned head segment survives a window-size-2 layer walk with zero
  flash re-reads, and the staged streamed base matches the sync one
  bit-for-bit
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.models import registry
from repro.param import init_params
from repro.serve import Request, ServeEngine, StreamedBase

TCFG = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                   attn_chunk=64)


def _params(arch):
    cfg = configs.get_smoke(arch)
    return cfg, init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))


def _run_solo(cfg, params, rid, toks, n, **kw):
    eng = ServeEngine(cfg, TCFG, params, slots=1, max_len=48, chunk=5, **kw)
    eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    return eng.run()[rid]


# ---------------------------------------------------------------------------
# paged KV: batched == isolated with a shared pool
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen15_05b", "hymba_15b"])
def test_paged_mixed_lengths_share_pool(arch):
    """Short and long requests share one page pool, concurrently, and stay
    token-identical to isolated runs."""
    cfg, params = _params(arch)
    reqs = [(0, list(range(3, 7)), 12),      # 15 positions -> 2 pages
            (1, list(range(5, 17)), 6),      # 17 positions -> 3 pages
            (2, list(range(4, 20)), 8)]      # 23 positions -> 3 pages
    # 8 usable pages < the dense-equivalent 3 slots * 6 pages: the pool is
    # genuinely shared, not worst-case provisioned
    eng = ServeEngine(cfg, TCFG, params, slots=3, max_len=48, chunk=5,
                      page_size=8, pool_pages=8)
    for rid, toks, n in reqs:
        eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    out = eng.run()
    st = eng.stats()
    assert st["completed"] == 3
    assert st["peak_active"] == 3            # all three in flight at once
    assert st["peak_pages_used"] <= 8
    assert st["free_pages"] == 8             # every page returned
    for rid, toks, n in reqs:
        ref = _run_solo(cfg, params, rid, toks, n, page_size=8)
        assert np.array_equal(out[rid], ref), (rid, out[rid], ref)


def test_paged_admits_more_than_dense_at_same_bytes():
    """At a fixed cache-byte budget (pool_pages), paging admits more
    concurrent requests than dense worst-case slots would."""
    cfg, params = _params("qwen15_05b")
    # dense equivalent at this budget: 8 pages / (max_len=32 -> 4 pages per
    # worst-case slot) = 2 slots.  Paged: the same 8 pages hold 4 real
    # (half-length) requests at once.
    reqs = [(i, list(range(3 + i, 13 + i)), 4) for i in range(4)]  # 2 pages ea
    eng = ServeEngine(cfg, TCFG, params, slots=4, max_len=32, chunk=8,
                      page_size=8, pool_pages=8)
    for rid, toks, n in reqs:
        eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    out = eng.run()
    st = eng.stats()
    assert st["peak_active"] == 4 > 8 // 4   # beats the dense-slot budget
    for rid, toks, n in reqs:
        ref = _run_solo(cfg, params, rid, toks, n, page_size=8)
        assert np.array_equal(out[rid], ref)


# ---------------------------------------------------------------------------
# page lifecycle
# ---------------------------------------------------------------------------
def test_page_recycle_after_reap():
    """Slots recycled mid-flight hand their pages back: more total requests
    than the pool could ever hold at once all complete."""
    cfg, params = _params("qwen15_05b")
    reqs = [(i, list(range(3, 13 + i)), 4) for i in range(5)]   # 2 pages ea
    eng = ServeEngine(cfg, TCFG, params, slots=2, max_len=32, chunk=5,
                      page_size=8, pool_pages=4)
    for rid, toks, n in reqs:
        eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    out = eng.run()
    st = eng.stats()
    assert st["completed"] == 5
    assert st["peak_pages_used"] <= 4
    assert st["free_pages"] == 4             # full recycle after drain
    for rid, toks, n in reqs:
        ref = _run_solo(cfg, params, rid, toks, n, page_size=8)
        assert np.array_equal(out[rid], ref)


def test_pool_exhaustion_backpressure():
    """A pool with room for one request at a time serializes admissions
    (backpressure), completes everything, and counts the waits."""
    cfg, params = _params("qwen15_05b")
    reqs = [(0, list(range(3, 13)), 4), (1, list(range(5, 15)), 4)]
    eng = ServeEngine(cfg, TCFG, params, slots=2, max_len=32, chunk=5,
                      page_size=8, pool_pages=2)        # 2 pages per request
    for rid, toks, n in reqs:
        eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    out = eng.run()
    st = eng.stats()
    assert st["completed"] == 2
    assert st["peak_active"] == 1            # never both in flight
    assert st["admission_waits"] >= 1
    for rid, toks, n in reqs:
        ref = _run_solo(cfg, params, rid, toks, n, page_size=8)
        assert np.array_equal(out[rid], ref)


def test_submit_rejects_impossible_requests():
    cfg, params = _params("qwen15_05b")
    eng = ServeEngine(cfg, TCFG, params, slots=1, max_len=32, chunk=5,
                      page_size=8, pool_pages=2)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit(Request(rid=0, tokens=list(range(3, 33)), max_new=8))
    with pytest.raises(ValueError, match="pages"):
        # fits max_len but needs 3 pages; the pool will only ever hold 2
        eng.submit(Request(rid=1, tokens=list(range(3, 21)), max_new=6))


# ---------------------------------------------------------------------------
# streamed base: head pinning + staging
# ---------------------------------------------------------------------------
def test_head_pinned_under_window_pressure(tmp_path):
    """A window-size-2 streamed base walks every layer each step; the
    pinned head segment must be read from flash exactly once per run."""
    cfg, params = _params("qwen15_05b")
    from repro.offload.state import LayerStreamedState
    ls = LayerStreamedState.create_frozen(params, str(tmp_path / "fp32"),
                                          max_resident=2, base_tag="t")
    eng = ServeEngine(cfg, TCFG, StreamedBase(ls), slots=2, max_len=48,
                      chunk=5)
    eng.submit(Request(rid=0, tokens=list(range(3, 13)), max_new=5))
    eng.run()
    st = eng.stats()
    # the layer walk paged blocks through a 2-deep window for several
    # steps, but the head segment never fell out: 1 read, 0 re-reads
    assert st["base_head_reads"] == 1, st["base_head_reads"]
    assert st["base_stage_h2d_s"] >= 0.0
    eng.close()


@pytest.mark.parametrize("staging", [True, False])
def test_staged_streamed_base_matches_inmemory(tmp_path, staging):
    """The staged (async h2d) and sync streamed walks produce bit-identical
    tokens — staging moves work, never changes it."""
    cfg, params = _params("qwen15_05b")
    prompt = list(range(3, 13))
    ref = _run_solo(cfg, params, 0, prompt, 5)
    from repro.offload.state import LayerStreamedState
    ls = LayerStreamedState.create_frozen(
        params, str(tmp_path / f"s{int(staging)}"), max_resident=2,
        base_tag="t")
    eng = ServeEngine(cfg, TCFG, StreamedBase(ls, staging=staging),
                      slots=2, max_len=48, chunk=5)
    eng.submit(Request(rid=0, tokens=prompt, max_new=5))
    out = eng.run()[0]
    eng.close()
    assert np.array_equal(out, ref)


def test_decode_defers_token_sync():
    """The decode loop must not pull tokens to host per step: the deferred
    trace drains only at reap time."""
    cfg, params = _params("qwen15_05b")
    eng = ServeEngine(cfg, TCFG, params, slots=1, max_len=48, chunk=16)
    eng.submit(Request(rid=0, tokens=list(range(3, 11)), max_new=6))
    seen = []
    orig = eng._materialize
    eng._materialize = lambda: (seen.append(eng.decode_steps), orig())[1]
    out = eng.run()[0]
    assert out.shape == (6,)
    # one flush for the whole request (5 decode steps + prefill token),
    # not one per step
    assert seen == [5], seen
