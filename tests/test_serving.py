"""Serving tier: chunked prefill, continuous batching, multi-LoRA multiplex.

Pins the tentpole invariants of repro/serve/:
- chunked prefill == step-wise prefill (the reference oracle), dense + ssm
- continuous batching (join/leave/slot-recycle) is token-identical to
  isolated single-request runs
- adapter hot-swap through a bounded AdapterCache returns per-user outputs
  matching isolated runs; base_tag / rank mismatches raise
- the streamed frozen base (fp32 and int8) matches the in-memory engine
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.checkpoint.safetensors import load_adapter, save_adapter
from repro.core.lora import lora_specs
from repro.launch import serve
from repro.models import registry
from repro.param import init_params
from repro.serve import AdapterCache, Request, ServeEngine, StreamedBase

TCFG = TrainConfig(compute_dtype="float32", attention_impl="streaming",
                   attn_chunk=64)
RANK, ALPHA, TARGETS = 2, 16.0, ("wq", "wv")
TAG = "unit|seed0|float32"


def _params(arch):
    cfg = configs.get_smoke(arch)
    return cfg, init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))


def _prompts(cfg, b=2, n=13):
    return jax.random.randint(jax.random.PRNGKey(1), (b, n), 3,
                              cfg.vocab_size, jnp.int32)


def _adapter_file(cfg, path, seed, *, targets=TARGETS, rank=RANK,
                  alpha=ALPHA, base_tag=TAG, base_quant=""):
    lt = init_params(jax.random.PRNGKey(seed),
                     lora_specs(registry.param_specs(cfg), targets, rank))
    # b initializes to zeros; shift it so the adapter actually changes logits
    lt = jax.tree.map(lambda a: a + 0.02, lt)
    save_adapter(path, lt, rank=rank, alpha=alpha, targets=targets,
                 base_quant=base_quant, base_tag=base_tag)
    return path


# ---------------------------------------------------------------------------
# chunked prefill vs step-wise oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen15_05b", "mamba2_130m"])
def test_chunked_prefill_matches_stepwise(arch):
    cfg, params = _params(arch)
    prompts = _prompts(cfg)          # length 13: exercises a remainder slab
    lo_c, cache_c = serve.prefill(params, prompts, cfg, TCFG, 32, chunk=5)
    lo_s, cache_s = serve.prefill_stepwise(params, prompts, cfg, TCFG, 32)
    np.testing.assert_allclose(np.asarray(lo_c), np.asarray(lo_s),
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_c), jax.tree.leaves(cache_s)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_generate_sampled_without_rng():
    cfg, params = _params("qwen15_05b")
    toks = serve.generate(params, _prompts(cfg), cfg, TCFG, n_new=3,
                          greedy=False)   # rng=None used to crash
    assert toks.shape == (2, 4)


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------
def test_engine_matches_generate():
    cfg, params = _params("qwen15_05b")
    prompt = list(range(3, 13))
    eng = ServeEngine(cfg, TCFG, params, slots=2, max_len=48, chunk=5)
    eng.submit(Request(rid=0, tokens=prompt, max_new=6))
    out = eng.run()[0]
    ref = np.asarray(serve.generate(
        params, jnp.asarray([prompt], jnp.int32), cfg, TCFG, n_new=5,
        chunk=5))[0]
    assert np.array_equal(out, ref)


@pytest.mark.parametrize("arch", ["qwen15_05b", "mamba2_130m"])
def test_continuous_batching_join_leave_recycle(arch):
    cfg, params = _params(arch)
    reqs = [(0, list(range(3, 13)), 6), (1, list(range(5, 17)), 4),
            (2, list(range(4, 11)), 5), (3, list(range(7, 15)), 3)]
    eng = ServeEngine(cfg, TCFG, params, slots=2, max_len=48, chunk=5)
    for rid, toks, n in reqs:
        eng.submit(Request(rid=rid, tokens=toks, max_new=n))
    out = eng.run()
    st = eng.stats()
    assert st["admitted"] == st["completed"] == 4
    assert st["peak_active"] <= 2          # 4 requests through 2 slots:
    #                                        slots were recycled mid-flight
    for rid, toks, n in reqs:
        solo = ServeEngine(cfg, TCFG, params, slots=1, max_len=48, chunk=5)
        solo.submit(Request(rid=rid, tokens=toks, max_new=n))
        ref = solo.run()[rid]
        assert np.array_equal(out[rid], ref), (rid, out[rid], ref)


# ---------------------------------------------------------------------------
# multi-LoRA multiplexing
# ---------------------------------------------------------------------------
def test_adapter_hotswap_matches_isolated(tmp_path):
    cfg, params = _params("qwen15_05b")
    paths = [_adapter_file(cfg, str(tmp_path / f"a{i}.safetensors"), 100 + i)
             for i in range(3)]
    prompts = [list(range(3, 13)), list(range(5, 14)), list(range(4, 11))]

    def cache():
        return AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                            base_tag=TAG, capacity=2)

    eng = ServeEngine(cfg, TCFG, params, slots=3, max_len=48, chunk=5,
                      adapters=cache())
    for i, (p, a) in enumerate(zip(prompts, paths)):
        eng.submit(Request(rid=i, tokens=p, max_new=5, adapter=a))
    out = eng.run()
    # 3 adapters through a capacity-2 cache: at least one hot-swap happened
    assert eng.stats()["adapter_evictions"] >= 1
    # adapters actually personalize (otherwise this test is vacuous)
    assert not np.array_equal(out[0], out[2])
    for i, (p, a) in enumerate(zip(prompts, paths)):
        solo = ServeEngine(cfg, TCFG, params, slots=1, max_len=48, chunk=5,
                           adapters=cache())
        solo.submit(Request(rid=i, tokens=p, max_new=5, adapter=a))
        assert np.array_equal(out[i], solo.run()[i])


def test_adapter_roundtrip_and_mismatches(tmp_path):
    cfg, _ = _params("qwen15_05b")
    path = _adapter_file(cfg, str(tmp_path / "a.safetensors"), 7)
    lora, meta = load_adapter(path)
    assert meta == {"rank": RANK, "alpha": ALPHA, "targets": TARGETS,
                    "base_quant": "", "base_tag": TAG}
    assert "blocks" in lora and sorted(
        lora["blocks"]["attn"].keys()) == ["wq", "wv"]

    good = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                        base_tag=TAG)
    assert good.get(path) is good.get(path)   # LRU hit returns same tree

    other_tag = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                             base_tag="other|seed1|float32")
    with pytest.raises(ValueError, match="base_tag"):
        other_tag.get(path)
    wrong_rank = AdapterCache(cfg, rank=RANK + 2, alpha=ALPHA,
                              targets=TARGETS, base_tag=TAG)
    with pytest.raises(ValueError, match="lora_rank"):
        wrong_rank.get(path)
    int8_base = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                             base_quant="int8", base_tag=TAG)
    with pytest.raises(ValueError, match="base_quant"):
        int8_base.get(path)


# ---------------------------------------------------------------------------
# streamed frozen base
# ---------------------------------------------------------------------------
def test_streamed_base_matches_inmemory(tmp_path):
    cfg, params = _params("qwen15_05b")
    prompt = list(range(3, 13))
    ref_eng = ServeEngine(cfg, TCFG, params, slots=2, max_len=48, chunk=5)
    ref_eng.submit(Request(rid=0, tokens=prompt, max_new=5))
    ref = ref_eng.run()[0]

    from repro.offload.state import LayerStreamedState
    ls = LayerStreamedState.create_frozen(params, str(tmp_path / "fp32"),
                                          max_resident=2, base_tag="t")
    eng = ServeEngine(cfg, TCFG, StreamedBase(ls), slots=2, max_len=48,
                      chunk=5)
    eng.submit(Request(rid=0, tokens=prompt, max_new=5))
    out = eng.run()[0]
    eng.close()
    assert np.array_equal(out, ref)


def test_streamed_int8_base_matches_dequantized(tmp_path):
    cfg, params = _params("qwen15_05b")
    prompt = list(range(3, 13))
    from repro.offload.state import LayerStreamedState
    ls = LayerStreamedState.create_frozen(params, str(tmp_path / "int8"),
                                          max_resident=2, base_tag="t",
                                          quant="int8")
    deq = ls.materialize_params()     # the exact weights int8 decode sees
    eng = ServeEngine(cfg, TCFG, StreamedBase(ls), slots=2, max_len=48,
                      chunk=5)
    eng.submit(Request(rid=0, tokens=prompt, max_new=5))
    out = eng.run()[0]
    eng.close()
    ref_eng = ServeEngine(cfg, TCFG, deq, slots=2, max_len=48, chunk=5)
    ref_eng.submit(Request(rid=0, tokens=prompt, max_new=5))
    assert np.array_equal(out, ref_eng.run()[0])


def test_engine_rejects_quant_mismatched_adapter_cache():
    cfg, params = _params("qwen15_05b")
    ac = AdapterCache(cfg, rank=RANK, alpha=ALPHA, targets=TARGETS,
                      base_quant="int8", base_tag=TAG)
    with pytest.raises(ValueError, match="base_quant"):
        ServeEngine(cfg, TCFG, params, slots=1, adapters=ac)
