"""End-to-end behaviour tests for the whole system (paper Application layer):
training loop with observer + governor + checkpoints, LoRA case-study
pipeline, batched serving, dry-run unit pieces."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import SHAPES, TrainConfig, cells_for
from repro.core.energy import EnergyGovernor, SimulatedBattery
from repro.launch.train import train_loop
from repro.models import registry
from repro.param import init_params


def _tcfg(**kw):
    base = dict(global_batch=4, seq_len=32, compute_dtype="float32",
                attention_impl="streaming", attn_chunk=16, total_steps=8,
                warmup_steps=1, learning_rate=3e-3)
    base.update(kw)
    return TrainConfig(**base)


def test_train_loop_end_to_end(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg(checkpoint_every=4)
    out = str(tmp_path / "run")
    state, obs = train_loop(cfg, tcfg, out_dir=out, print_fn=None)
    assert obs.rows[-1]["loss"] < obs.rows[0]["loss"]
    assert os.path.exists(os.path.join(out, "metrics.jsonl"))
    assert os.path.exists(os.path.join(out, "dashboard.html"))
    assert os.path.exists(os.path.join(out, "ckpt", "step_00000008"))
    assert int(state["step"]) == 8


def test_train_loop_resume_after_kill(tmp_path):
    """Fault tolerance: a killed run resumes and reaches the same final loss
    as an uninterrupted one (same data order)."""
    cfg = configs.get_smoke("gpt2_124m")
    out_a = str(tmp_path / "a")
    out_b = str(tmp_path / "b")
    # constant schedule: the interrupted run's shorter horizon must not
    # change the lr trajectory
    full = _tcfg(total_steps=8, checkpoint_every=100, schedule="constant",
                 warmup_steps=0)
    _, obs_full = train_loop(cfg, full, out_dir=out_a, print_fn=None)

    half = dataclasses.replace(full, total_steps=4, checkpoint_every=4)
    train_loop(cfg, half, out_dir=out_b, print_fn=None)
    rest = dataclasses.replace(full, total_steps=8, checkpoint_every=4)
    _, obs_res = train_loop(cfg, rest, out_dir=out_b, print_fn=None)
    np.testing.assert_allclose(obs_res.rows[-1]["loss"],
                               obs_full.rows[-1]["loss"], rtol=1e-5)


def test_train_loop_with_governor():
    cfg = configs.get_smoke("qwen25_05b")
    gov = EnergyGovernor(check_every=1, threshold=0.6, reduction=0.5,
                         monitor=SimulatedBattery(level=65.0,
                                                  drain_per_unit=2.0),
                         sleep_fn=lambda s: None)
    tcfg = _tcfg(total_steps=6)
    train_loop(cfg, tcfg, out_dir=None, governor=gov, print_fn=None)
    assert any(h["throttled"] for h in gov.history)


def test_lora_health_agent_pipeline(tmp_path):
    """CHQA case study end-to-end (paper §5): templates -> QA dataset ->
    LoRA fine-tune -> answer-token loss drops."""
    from repro.data.corpus import chqa_pairs
    from repro.data.dataset import QADataset
    from repro.data.tokenizer import ByteTokenizer
    cfg = configs.get_smoke("qwen25_05b")
    tok = ByteTokenizer()
    qa = QADataset(chqa_pairs(0, 32), tok, seq_len=64)
    tcfg = _tcfg(seq_len=64, lora_rank=4, total_steps=8, learning_rate=1e-2)
    state, obs = train_loop(cfg, tcfg, out_dir=None, dataset=qa,
                            print_fn=None)
    assert obs.rows[-1]["loss"] < obs.rows[0]["loss"]
    assert "lora" in state


def test_serve_generate_all_families():
    from repro.launch.serve import generate
    for arch in ("qwen15_05b", "mamba2_130m", "hymba_15b", "whisper_large_v3"):
        cfg = configs.get_smoke(arch)
        tcfg = TrainConfig(compute_dtype="float32",
                           attention_impl="streaming", attn_chunk=8)
        params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 3,
                                     cfg.vocab_size, jnp.int32)
        toks = generate(params, prompts, cfg, tcfg, n_new=4)
        assert toks.shape == (2, 5)
        assert int(toks.max()) < cfg.vocab_size


# ---------------------------------------------------------------------------
# dry-run machinery units (the 512-device run itself happens out of process)
# ---------------------------------------------------------------------------
def test_cells_for_long_context_rule():
    cells = dict(cells_for(configs.get("command_r_plus_104b")))
    assert cells["long_500k"].startswith("SKIP")
    cells = dict(cells_for(configs.get("mamba2_130m")))
    assert cells["long_500k"] == "RUN"
    cells = dict(cells_for(configs.get("hymba_15b")))
    assert cells["long_500k"] == "RUN"


def test_parse_collectives_on_synthetic_hlo():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ag = f32[128,256]{1,0} all-gather(%p), replica_groups=[4,2]<=[8], dimensions={0}
  %ar = bf16[64]{0} all-reduce(%x), replica_groups={{0,1},{2,3}}, to_apply=%sum
  %rs = f32[32,8]{1,0} reduce-scatter(%y), replica_groups=[2,4]<=[8], dimensions={0}
  %dn = f32[4] all-gather-done(%h)
"""
    out = parse_collectives(hlo)
    assert out["counts"] == {"all-gather": 1, "all-reduce": 1,
                             "reduce-scatter": 1}
    assert out["per_kind"]["all-gather"] == 128 * 256 * 4
    assert out["per_kind"]["all-reduce"] == 2 * 64 * 2
    assert out["per_kind"]["reduce-scatter"] == 32 * 8 * 4 * 4


def test_analytic_model_matches_6nd_for_dense():
    """matmul-flops-per-token derived from ParamSpecs ~ 6N for training."""
    from repro.analysis import matmul_flops_per_token, step_flops
    cfg = configs.get("minitron_8b")
    tcfg = TrainConfig(remat_policy="none")
    shape = SHAPES["train_4k"]
    per_tok = matmul_flops_per_token(cfg)["dec"]
    n = cfg.param_count()
    # embedding tables don't matmul; ratio ~ 2*(N - embed)/N
    assert 1.0 < per_tok / n < 2.05
    fl = step_flops(cfg, tcfg, shape)
    assert fl["total"] == pytest.approx(3 * fl["fwd"])


def test_input_specs_zero_allocation():
    from repro.models.registry import input_specs
    spec = input_specs(configs.get("dbrx_132b"), SHAPES["train_4k"])
    assert spec["tokens"].shape == (256, 4096)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in spec.values())
