"""PEFT on the streamed offload engine (paper C6 over C1; repro/core/stream.py).

Covers: streamed-LoRA vs in-memory-LoRA loss/grad equivalence (dense and
ssm families), the frozen param-only layout (p-segments without m/v, a
read-only window that never writes back), the analytic frozen-layout
resident bound, adapter-only checkpoint resume determinism, the
cross-layout resume guards, and the adapter/merged safetensors exports.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.core.lora import lora_specs, merge_lora
from repro.core.step import init_state, make_stream_step
from repro.core.zero import lora_stream_resident_bytes, stream_resident_bytes
from repro.launch.train import train_loop
from repro.models import registry
from repro.offload import LayerStreamedState
from repro.param import flatten_names

SSM_TARGETS = ("w_x", "w_out")


def _batch(cfg, batch=4, seq=32, seed=1):
    b = registry.make_batch(jax.random.PRNGKey(seed), cfg, batch, seq)
    b["labels"] = b["tokens"]
    return b


def _tcfg(**kw):
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-3,
                total_steps=10, warmup_steps=1, compute_dtype="float32",
                lora_rank=4, lora_alpha=16.0)
    base.update(kw)
    return TrainConfig(**base)


def _adapter_of(state):
    return {"lora": state["lora"], "opt": state["opt"],
            "step": state["step"]}


# ---------------------------------------------------------------------------
# adapter grad + loss equivalence vs the in-memory LoRA jit path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,targets", [
    ("gpt2_124m", ("wq", "wk", "wv", "wo")),
    ("mamba2_130m", SSM_TARGETS),
], ids=["dense", "ssm"])
def test_streamed_lora_grads_match_jit_path(arch, targets, tmp_path):
    cfg = configs.get_smoke(arch)
    tcfg = _tcfg(grad_clip=0.0, lora_targets=targets)
    batch = _batch(cfg)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)

    # reference adapter gradients straight off the merged in-memory loss
    model_loss = registry.loss_fn(cfg)

    def loss_of(lora):
        params = merge_lora(state["base"], lora, rank=tcfg.lora_rank,
                            alpha=tcfg.lora_alpha)
        loss, _ = model_loss(params, batch, cfg, tcfg)
        return loss

    loss_mem, grads_mem = jax.jit(jax.value_and_grad(loss_of))(state["lora"])
    gnamed = {n: np.asarray(g, np.float32)
              for n, g in flatten_names(grads_mem)}

    lstate = LayerStreamedState.create_frozen(state["base"],
                                              str(tmp_path / "segs"))
    step_fn = make_stream_step(cfg, tcfg, lstate, "",
                               adapter=_adapter_of(state))
    loss_eval, _ = step_fn.loss_only(batch)       # streamed eval, pre-update
    np.testing.assert_allclose(float(loss_mem), float(loss_eval), atol=1e-5)

    # one two-sweep pass fills the in-memory adapter-grad accumulator
    loss_s, _, _ = step_fn._two_sweeps(batch, True, True, 1)
    np.testing.assert_allclose(float(loss_mem), float(loss_s), atol=1e-5)
    for name, g in flatten_names(step_fn._acc):
        np.testing.assert_allclose(np.asarray(g, np.float32), gnamed[name],
                                   atol=1e-5, rtol=1e-4)
    # the frozen base never sees a write
    assert step_fn.stats()["param_bytes_written"] == 0
    step_fn.close()
    lstate.close()


# ---------------------------------------------------------------------------
# smoke-train equivalence (acceptance bar: <=1e-5/step over >=10 steps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("micro", [1, 2])
def test_stream_lora_smoke_train_matches_in_memory(tmp_path, micro):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-3,
                microbatches=micro, total_steps=10, warmup_steps=1,
                compute_dtype="float32", lora_rank=4, lora_alpha=16.0)
    _, obs_mem = train_loop(cfg, TrainConfig(**base), out_dir=None,
                            print_fn=None)
    _, obs_str = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_dir=str(tmp_path / "segs")),
        out_dir=None, print_fn=None)
    losses_mem = [r["loss"] for r in obs_mem.rows]
    losses_str = [r["loss"] for r in obs_str.rows]
    assert len(losses_str) == 10
    np.testing.assert_allclose(losses_mem, losses_str, atol=1e-5)


# ---------------------------------------------------------------------------
# frozen layout: p-only segments, read-only window, resident bound
# ---------------------------------------------------------------------------
def test_frozen_layout_is_param_only_and_read_only(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg()
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    lstate = LayerStreamedState.create_frozen(state["base"],
                                              str(tmp_path / "segs"))
    # param bytes only: exactly 1/3 of the (p, m, v) fp32 layout
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["base"]))
    assert lstate.store.total_bytes == n * 4
    assert lstate.frozen and lstate.engine.read_only
    assert lstate.store.num_segments == cfg.n_layers + 1
    # every segment holds p.* leaves only — no m/v records anywhere
    assert all(r.name.startswith("p.") for r in lstate.store.records)
    # the window refuses writes
    lstate.engine.acquire(0)
    with pytest.raises(RuntimeError, match="read-only"):
        lstate.engine.mark_dirty(0)
    # and the streamed AdamW path refuses the frozen layout
    with pytest.raises(RuntimeError, match="frozen"):
        lstate._update_segment(0, {}, jnp.zeros((), jnp.int32), lr=1e-3,
                               beta1=0.9, beta2=0.999, eps=1e-8,
                               weight_decay=0.0)
    # materialized base is bit-identical to what was paged out
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state["base"], lstate.materialize_params())
    lstate.close()


def test_mode_layout_mismatches_are_rejected(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    state = init_state(jax.random.PRNGKey(0), cfg, _tcfg())
    frozen = LayerStreamedState.create_frozen(state["base"],
                                              str(tmp_path / "f"))
    # Full-FT streaming over a frozen store: no optimizer state to stream
    with pytest.raises(ValueError, match="frozen"):
        make_stream_step(cfg, _tcfg(lora_rank=0), frozen,
                         str(tmp_path / "g"))
    # LoRA without the adapter state
    with pytest.raises(ValueError, match="adapter"):
        make_stream_step(cfg, _tcfg(), frozen, "")
    frozen.close()
    # LoRA over a full (p, m, v) layout: wrong store kind
    full_state = init_state(jax.random.PRNGKey(0), cfg, _tcfg(lora_rank=0))
    full = LayerStreamedState.create(full_state, str(tmp_path / "pmv"))
    with pytest.raises(ValueError, match="frozen"):
        make_stream_step(cfg, _tcfg(), full, "", adapter=_adapter_of(state))
    full.close()
    # microbatches must be validated, not silently clamped
    with pytest.raises(ValueError, match="microbatches"):
        make_stream_step(cfg, _tcfg(microbatches=0), frozen, "",
                         adapter=_adapter_of(state))


def test_frozen_store_reuse_on_restart(tmp_path):
    """Restarting a streamed-LoRA run must reattach to the existing frozen
    segments (they are read-only and seed-derived) instead of re-paging the
    whole base to flash — guarded by the base_tag stamp."""
    cfg = configs.get_smoke("gpt2_124m")
    state = init_state(jax.random.PRNGKey(0), cfg, _tcfg())
    d = str(tmp_path / "segs")
    lst = LayerStreamedState.create_frozen(state["base"], d,
                                           base_tag="gpt2|seed0|float32")
    lst.close()
    re = LayerStreamedState.open_frozen_if_matching(
        d, state["base"], base_tag="gpt2|seed0|float32")
    assert re is not None and re.frozen
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state["base"], re.materialize_params())
    re.close()
    # a different tag (other seed/arch/dtype) must refuse the stale store
    assert LayerStreamedState.open_frozen_if_matching(
        d, state["base"], base_tag="gpt2|seed1|float32") is None
    # and a Full-FT (p, m, v) store is never treated as a frozen base
    full = LayerStreamedState.create(
        init_state(jax.random.PRNGKey(0), cfg, _tcfg(lora_rank=0)),
        str(tmp_path / "pmv"))
    full.close()
    assert LayerStreamedState.open_frozen_if_matching(
        str(tmp_path / "pmv"), state["base"],
        base_tag="gpt2|seed0|float32") is None


def test_frozen_resident_bound(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg(total_steps=2)
    specs = registry.param_specs(cfg)
    lspecs = lora_specs(specs, tcfg.lora_targets, tcfg.lora_rank)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    adapter = _adapter_of(state)
    from repro.param import tree_bytes
    adapter_b = tree_bytes({"lora": adapter["lora"],
                            "opt": adapter["opt"]})
    lstate = LayerStreamedState.create_frozen(
        state["base"], str(tmp_path / "segs"),
        max_resident=tcfg.offload_resident)
    step_fn = make_stream_step(cfg, tcfg, lstate, "", adapter=adapter)
    batch = _batch(cfg)
    for step in range(2):
        step_fn(batch, step)
    measured = step_fn.stats()["param_peak_resident_bytes"] + adapter_b
    full, analytic = lora_stream_resident_bytes(
        specs, lspecs, window=tcfg.offload_resident)
    assert measured <= analytic
    # the frozen bound undercuts the Full-FT streamed bound (m/v vanish)
    _, full_ft = stream_resident_bytes(specs, window=tcfg.offload_resident)
    assert analytic < full_ft
    step_fn.close()
    lstate.close()


# ---------------------------------------------------------------------------
# adapter-only checkpoints: resume determinism + cross-layout guards
# ---------------------------------------------------------------------------
def test_adapter_checkpoint_resume_determinism(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-3,
                schedule="constant", warmup_steps=1, compute_dtype="float32",
                lora_rank=4, lora_alpha=16.0, offload_stream_params=True)
    tA = TrainConfig(**base, total_steps=6)
    _, oA = train_loop(cfg, tA, out_dir=str(tmp_path / "a"), print_fn=None)
    out = str(tmp_path / "run")
    tB1 = TrainConfig(**base, total_steps=3, checkpoint_every=3)
    _, oB1 = train_loop(cfg, tB1, out_dir=out, print_fn=None)
    # the checkpoint is adapter-only: lora.* leaves, no base/params tree
    from repro.checkpoint.store import is_adapter_checkpoint, latest_step
    ckdir = os.path.join(out, "ckpt")
    last = latest_step(ckdir)
    assert is_adapter_checkpoint(ckdir, last)
    import json
    with open(os.path.join(ckdir, f"step_{last:08d}",
                           "manifest.json")) as f:
        leaves = json.load(f)["leaves"]
    assert any(k.startswith("lora.") for k in leaves)
    assert not any(k.startswith(("base.", "params.")) for k in leaves)
    tB2 = TrainConfig(**base, total_steps=6, checkpoint_every=3)
    _, oB2 = train_loop(cfg, tB2, out_dir=out, print_fn=None)
    assert oB2.rows[0]["step"] == 3            # actually resumed
    lossesA = [r["loss"] for r in oA.rows]
    lossesB = ([r["loss"] for r in oB1.rows] +
               [r["loss"] for r in oB2.rows])
    np.testing.assert_allclose(lossesA, lossesB, atol=1e-6)


def test_cross_layout_resume_guards(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, total_steps=2,
                checkpoint_every=2, warmup_steps=1, compute_dtype="float32")
    out = str(tmp_path / "run")
    train_loop(cfg, TrainConfig(**base, offload_stream_params=True,
                                lora_rank=4, lora_alpha=16.0),
               out_dir=out, print_fn=None)
    # an adapter-only checkpoint refuses the Full-FT streamed resume path...
    with pytest.raises(ValueError, match="adapter-only"):
        train_loop(cfg, TrainConfig(**base, offload_stream_params=True),
                   out_dir=out, print_fn=None)
    # ...and the in-memory one
    with pytest.raises(ValueError, match="adapter-only"):
        train_loop(cfg, TrainConfig(**base), out_dir=out, print_fn=None)
    # a Full-FT layer-streamed checkpoint refuses the streamed-LoRA resume
    out2 = str(tmp_path / "run2")
    train_loop(cfg, TrainConfig(**base, offload_stream_params=True),
               out_dir=out2, print_fn=None)
    with pytest.raises(ValueError, match="layer-aligned"):
        train_loop(cfg, TrainConfig(**base, offload_stream_params=True,
                                    lora_rank=4, lora_alpha=16.0),
                   out_dir=out2, print_fn=None)
    # an in-memory LoRA checkpoint (full state) is NOT adapter-only
    out3 = str(tmp_path / "run3")
    train_loop(cfg, TrainConfig(**base, lora_rank=4, lora_alpha=16.0),
               out_dir=out3, print_fn=None)
    with pytest.raises(ValueError, match="in-memory"):
        train_loop(cfg, TrainConfig(**base, offload_stream_params=True,
                                    lora_rank=4, lora_alpha=16.0),
                   out_dir=out3, print_fn=None)


def test_adapter_resume_rejects_mismatched_peft_settings(tmp_path):
    """The frozen base is re-derived from the seed and the merge math from
    rank/alpha — resuming an adapter checkpoint under different settings
    must hard-error, not silently train against the wrong base."""
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, total_steps=2,
                checkpoint_every=2, warmup_steps=1, compute_dtype="float32",
                offload_stream_params=True, lora_rank=4)
    out = str(tmp_path / "run")
    train_loop(cfg, TrainConfig(**base, lora_alpha=16.0), out_dir=out,
               seed=0, print_fn=None)
    longer = {**base, "total_steps": 4}
    with pytest.raises(ValueError, match="seed"):
        train_loop(cfg, TrainConfig(**longer, lora_alpha=16.0),
                   out_dir=out, seed=1, print_fn=None)
    with pytest.raises(ValueError, match="lora_alpha"):
        train_loop(cfg, TrainConfig(**longer, lora_alpha=32.0),
                   out_dir=out, seed=0, print_fn=None)
    # matching settings still resume fine
    _, obs = train_loop(cfg, TrainConfig(**longer, lora_alpha=16.0),
                        out_dir=out, seed=0, print_fn=None)
    assert obs.rows[0]["step"] == 2


# ---------------------------------------------------------------------------
# adapter / merged exports
# ---------------------------------------------------------------------------
def test_adapter_export_and_merged_export(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg(total_steps=2, checkpoint_every=0)
    out = str(tmp_path / "run")
    state, _ = train_loop(
        cfg, TrainConfig(**dict(
            global_batch=2, seq_len=16, total_steps=2, warmup_steps=1,
            compute_dtype="float32", lora_rank=4, lora_alpha=16.0,
            offload_stream_params=True)),
        out_dir=out, print_fn=None)
    # the loop exports the bare adapter next to the run artifacts
    from repro.checkpoint.safetensors import (load_safetensors, save_merged)
    tensors, meta = load_safetensors(os.path.join(out,
                                                  "adapter.safetensors"))
    assert meta["format"] == "lora_adapter" and meta["lora_rank"] == "4"
    assert all(k.startswith("lora.") for k in tensors)
    named_lora = dict(flatten_names(state["lora"]))
    for k, v in tensors.items():
        np.testing.assert_array_equal(v, np.asarray(named_lora[k[5:]]))
    # merged export equals merge_lora(train=False) applied to the state
    mpath = save_merged(str(tmp_path / "merged.safetensors"),
                        state["base"], state["lora"],
                        rank=tcfg.lora_rank, alpha=tcfg.lora_alpha)
    merged, mmeta = load_safetensors(mpath)
    assert mmeta["format"] == "merged_model"
    ref = merge_lora(state["base"], state["lora"], rank=tcfg.lora_rank,
                     alpha=tcfg.lora_alpha, train=False)
    for n, leaf in flatten_names(ref):
        np.testing.assert_allclose(merged[n], np.asarray(leaf), atol=1e-6)
