"""Extra hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stub

from repro.models import layers as L

hypothesis, st = hypothesis_or_stub()


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(s=st.integers(1, 16), d=st.sampled_from([4, 8, 16]),
                  theta=st.sampled_from([100.0, 10000.0]))
def test_rope_preserves_norm_and_relative_positions(s, d, theta):
    """RoPE is a rotation: per-pair norms unchanged; q.k depends only on the
    positional difference."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, s, 1, d))
    pos = jnp.arange(s)[None]
    y = L.apply_rope(x, pos, theta)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # shift invariance of inner products
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, d))
    for shift in (0, 3):
        qa = L.apply_rope(q, jnp.array([[5 + shift]]), theta)
        ka = L.apply_rope(k, jnp.array([[2 + shift]]), theta)
        if shift == 0:
            base = float(jnp.sum(qa * ka))
        else:
            np.testing.assert_allclose(float(jnp.sum(qa * ka)), base,
                                       rtol=1e-4, atol=1e-5)


def test_mrope_equals_rope_for_text_positions():
    """When all three m-rope streams share a position (pure text), M-RoPE
    must reduce to standard RoPE."""
    d = 16
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 6, 3, d))
    pos = jnp.tile(jnp.arange(6)[None], (2, 1))
    pos3 = jnp.stack([pos, pos, pos], axis=1)
    a = L.apply_rope(x, pos, 10000.0)
    b = L.apply_mrope(x, pos3, (3, 3, 2), 10000.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                               atol=1e-6)


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(v=st.integers(8, 64), pad=st.integers(0, 32))
def test_padded_vocab_logits_never_win(v, pad):
    p = {"tok": jnp.eye(v + pad, 8)}
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8))
    out = L.unembed(p, x, tie=True, true_vocab=v)
    assert int(jnp.argmax(out, -1).max()) < v


@hypothesis.settings(max_examples=20, deadline=None)
@hypothesis.given(b=st.integers(1, 3), s=st.integers(2, 12),
                  d=st.sampled_from([8, 16]))
def test_norms_finite_and_scale_invariant_rms(b, s, d):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, s, d)) * 100
    p = {"scale": jnp.ones((d,))}
    y1 = L.apply_norm(p, x, "rmsnorm")
    y2 = L.apply_norm(p, x * 7.0, "rmsnorm")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-5)
    assert bool(jnp.isfinite(y1).all())


def test_fp8_param_cast_roundtrip_small_error():
    """Serving with fp8-stored weights (hc_d1): dequant error bounded."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.05
    w8 = w.astype(jnp.float8_e4m3fn).astype(jnp.bfloat16)
    rel = float(jnp.abs(w8.astype(jnp.float32) - w).max() /
                jnp.abs(w).max())
    assert rel < 0.08  # e4m3 relative step


def test_moe_seq_chunks_equivalence():
    """Sequence-chunked MoE ~= unchunked when capacity is not binding."""
    import dataclasses
    from repro import configs
    from repro.config import TrainConfig
    from repro.models.moe import apply_moe
    from repro.models import registry
    from repro.param import init_params
    cfg = dataclasses.replace(configs.get_smoke("dbrx_132b"),
                              capacity_factor=4.0)
    params = init_params(jax.random.PRNGKey(0), registry.param_specs(cfg))
    p = jax.tree.map(lambda a: a[0], params["blocks"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.1
    t1 = TrainConfig(compute_dtype="float32", moe_seq_chunks=1)
    t4 = TrainConfig(compute_dtype="float32", moe_seq_chunks=4)
    y1, _ = apply_moe(p, x, cfg, t1)
    y4, _ = apply_moe(p, x, cfg, t4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=2e-4,
                               atol=2e-5)
