"""Pluggable segment codecs (repro/offload/codecs.py) and the int8-quantized
frozen base (streamed QLoRA).

Covers: identity/bf16 encode-decode round-trip exactness, per-channel int8
quantization error bounds, the mapping-table version upgrade (v1 tables from
before the codec column still open, with their bf16 moments re-expressed as
the bf16 codec) and the unknown-version guard, engine pull/write-back
through every codec, the encoded (int8-resident) window, the quantized
analytic bounds, and streamed int8-LoRA training: loss tracks the fp32
frozen-base run within tolerance over 10 steps (dense + ssm), adapter-only
resume is deterministic, and a codec mismatch on resume hard-errors.
"""
import json
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.core.lora import lora_specs
from repro.core.step import init_state, make_stream_step
from repro.core.zero import frozen_base_bytes, lora_stream_resident_bytes
from repro.launch.train import train_loop
from repro.models import registry
from repro.offload import LayerStreamedState, OffloadEngine, SegmentStore
from repro.offload.codecs import (QuantLeaf, dequant_np, get_codec,
                                  moment_codec)

# streamed int8-LoRA must track the fp32 frozen-base run at least this
# closely over 10 smoke steps (measured drift is ~1e-3; the bound leaves
# an order of magnitude of headroom without ever hiding a real break)
INT8_LOSS_ATOL = 1e-2


# ---------------------------------------------------------------------------
# codec round trips
# ---------------------------------------------------------------------------
def test_identity_roundtrip_exact():
    rng = np.random.RandomState(0)
    c = get_codec("identity")
    for shape in [(7, 3), (5,), (2, 3, 4)]:
        x = rng.randn(*shape).astype(np.float32)
        buf = c.encode(x, "float32")
        assert buf.nbytes == c.encoded_nbytes(shape, "float32") == x.nbytes
        np.testing.assert_array_equal(c.decode(buf, shape, "float32"), x)
        np.testing.assert_array_equal(c.storage_roundtrip(x), x)


def test_bf16_roundtrip_exact_on_representable_values():
    import ml_dtypes
    rng = np.random.RandomState(1)
    c = get_codec("bf16")
    x = rng.randn(6, 4).astype(np.float32)
    xb = x.astype(ml_dtypes.bfloat16).astype(np.float32)  # representable
    buf = c.encode(xb, "float32")
    assert buf.nbytes == c.encoded_nbytes(xb.shape, "float32") == xb.size * 2
    np.testing.assert_array_equal(c.decode(buf, xb.shape, "float32"), xb)
    # storage_roundtrip == what a write/read trip would produce
    np.testing.assert_array_equal(c.storage_roundtrip(x), xb)


def test_int8_per_channel_error_bound():
    rng = np.random.RandomState(2)
    c = get_codec("int8")
    # mixed channel magnitudes: per-channel scaling must bound each channel
    # by its own absmax/254, not the tensor-wide one
    x = rng.randn(64, 8).astype(np.float32) * np.logspace(-2, 2, 8,
                                                          dtype=np.float32)
    buf = c.encode(x, "float32")
    assert buf.nbytes == c.encoded_nbytes(x.shape, "float32") == x.size + 8 * 4
    y = c.decode(buf, x.shape, "float32")
    half_step = np.abs(x).max(axis=0) / 127.0 / 2.0
    assert np.all(np.abs(x - y) <= half_step[None, :] * (1 + 1e-6) + 1e-12)
    # encoded view: int8 codes + one fp32 scale per channel
    q = c.decode_encoded(buf, x.shape, "float32")
    assert q.codes.dtype == np.int8 and q.scales.shape == (8,)
    np.testing.assert_allclose(dequant_np(q), y)


def test_int8_edge_cases():
    c = get_codec("int8")
    # an all-zero channel must decode to zeros, not NaN
    z = np.zeros((4, 3), np.float32)
    np.testing.assert_array_equal(c.decode(c.encode(z, "float32"),
                                           z.shape, "float32"), z)
    # 1-D leaves quantize per tensor (one scale)
    v = np.linspace(-2, 2, 33, dtype=np.float32)
    buf = c.encode(v, "float32")
    assert buf.nbytes == 33 + 4
    assert np.abs(c.decode(buf, v.shape, "float32") - v).max() <= 2 / 254 * 1.01
    with pytest.raises(ValueError, match="0-d"):
        c.encode(np.float32(1.0), "float32")


def test_unknown_codec_is_actionable():
    with pytest.raises(ValueError, match="unknown segment codec"):
        get_codec("nf4")
    assert moment_codec("bfloat16") == "bf16"
    assert moment_codec("float32") == "identity"


# ---------------------------------------------------------------------------
# mapping table: version upgrade + unknown-version guard
# ---------------------------------------------------------------------------
def _mixed_store(d):
    rng = np.random.RandomState(3)
    groups = [[("p.w", rng.randn(8, 4).astype(np.float32)),
               ("m.w", rng.randn(8, 4).astype(np.float32), "bf16"),
               ("v.w", np.abs(rng.randn(8, 4)).astype(np.float32), "bf16")]]
    return SegmentStore.create(d, groups, 1,
                               meta={"moment_dtype": "bfloat16"})


def test_v1_table_upgrades_on_open(tmp_path):
    """A version-1 table (pre-codec) must open with its bf16-stored moments
    re-expressed as bf16-codec leaves — same bytes, same decoded values."""
    d = str(tmp_path / "s")
    store = _mixed_store(d)
    want = store.read_segment(0)
    # rewrite the table exactly as PR 2 wrote it: version 1, no codec
    # column, moments recorded at their storage dtype
    path = os.path.join(d, SegmentStore.TABLE)
    with open(path) as f:
        table = json.load(f)
    table["version"] = 1
    for r in table["leaves"]:
        del r["codec"]
        if r["name"].startswith(("m.", "v.")):
            r["dtype"] = "bfloat16"
    with open(path, "w") as f:
        json.dump(table, f)
    re = SegmentStore.open(d)
    assert re.record("m.w").codec == "bf16"
    assert re.record("m.w").dtype == "float32"     # logical dtype
    assert re.record("p.w").codec == "identity"
    got = re.read_segment(0)
    for n in want:
        np.testing.assert_array_equal(got[n], want[n])
    # a meta rewrite persists the upgraded table as version 2
    re.write_meta(step=1)
    with open(path) as f:
        assert json.load(f)["version"] == 2


def test_newer_table_version_raises_actionable_error(tmp_path):
    d = str(tmp_path / "s")
    _mixed_store(d)
    path = os.path.join(d, SegmentStore.TABLE)
    with open(path) as f:
        table = json.load(f)
    table["version"] = 99
    with open(path, "w") as f:
        json.dump(table, f)
    with pytest.raises(ValueError, match="version 99"):
        SegmentStore.open(d)


# ---------------------------------------------------------------------------
# engine pull / write-back through each codec
# ---------------------------------------------------------------------------
def test_engine_decodes_on_pull_and_encodes_on_writeback(tmp_path):
    import ml_dtypes
    rng = np.random.RandomState(4)
    x = rng.randn(8, 4).astype(np.float32)
    d = str(tmp_path / "s")
    SegmentStore.create(d, [[("p.w", x, "int8"), ("m.w", x, "bf16"),
                             ("v.w", x)]], 1)
    store = SegmentStore.open(d)
    eng = OffloadEngine(store, max_resident=1, prefetch=False)
    data = eng.acquire(0)
    # pull hands each leaf's *window* form: identity/int8 decode to fp32,
    # bf16 stays bf16-resident (its halved window bytes must survive)
    np.testing.assert_array_equal(data["v.w"], x)
    assert data["v.w"].dtype == np.float32
    assert data["m.w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(
        np.asarray(data["m.w"], np.float32),
        x.astype(ml_dtypes.bfloat16).astype(np.float32))
    half_step = np.abs(x).max(axis=0) / 254.0
    assert data["p.w"].dtype == np.float32
    assert np.all(np.abs(data["p.w"] - x) <= half_step[None, :] * 1.01)
    # mutate through the window; write-back re-encodes through the codecs
    data["m.w"][...] = x + 1
    data["v.w"][...] = x - 1
    data["p.w"][...] = 2 * x
    eng.mark_dirty(0)
    eng.flush()
    eng.close()
    fresh = SegmentStore.open(d).read_segment(0)
    np.testing.assert_array_equal(
        fresh["m.w"], (x + 1).astype(ml_dtypes.bfloat16).astype(np.float32))
    np.testing.assert_array_equal(fresh["v.w"], x - 1)
    assert np.all(np.abs(fresh["p.w"] - 2 * x) <= 2 * half_step[None, :] * 1.01)


def test_encoded_window_stays_int8_resident(tmp_path):
    rng = np.random.RandomState(5)
    x = rng.randn(32, 16).astype(np.float32)
    d = str(tmp_path / "s")
    SegmentStore.create(d, [[("p.w", x, "int8")], [("p.b", x[0])]], 2,
                        meta={"frozen": True})
    store = SegmentStore.open(d)
    eng = OffloadEngine(store, max_resident=2, prefetch=False,
                        read_only=True, encoded=True)
    data = eng.acquire(0)
    q = data["p.w"]
    assert isinstance(q, QuantLeaf) and q.codes.dtype == np.int8
    # identity leaves pass through with empty scales
    plain = eng.acquire(1)["p.b"]
    assert isinstance(plain, QuantLeaf) and plain.scales.size == 0
    # resident accounting bills the encoded bytes, not decoded fp32
    assert eng.peak_resident_bytes <= store.total_bytes < x.nbytes * 2
    eng.close()
    # an encoded window that could write back would corrupt the store
    with pytest.raises(ValueError, match="read_only"):
        OffloadEngine(store, read_only=False, encoded=True)


# ---------------------------------------------------------------------------
# quantized frozen base layout
# ---------------------------------------------------------------------------
def _tcfg(**kw):
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-3,
                total_steps=10, warmup_steps=1, compute_dtype="float32",
                lora_rank=4, lora_alpha=16.0)
    base.update(kw)
    return TrainConfig(**base)


def test_quantized_frozen_layout_bytes_and_decode(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    state = init_state(jax.random.PRNGKey(0), cfg, _tcfg())
    f32 = LayerStreamedState.create_frozen(state["base"],
                                           str(tmp_path / "f32"))
    i8 = LayerStreamedState.create_frozen(state["base"], str(tmp_path / "i8"),
                                          quant="int8")
    assert i8.base_quant == "int8" and i8.engine.encoded
    # matrix leaves went int8, vector leaves stayed identity
    codecs = {r.name: r.codec for r in i8.store.records}
    assert any(c == "int8" for c in codecs.values())
    for r in i8.store.records:
        assert r.codec == ("int8" if len(r.shape) >= 2 else "identity")
    # on-flash bytes ~4x down, matching the analytic accounting exactly
    specs = registry.param_specs(cfg)
    seg8, head8, n_layers = frozen_base_bytes(specs, base_quant="int8")
    assert i8.store.total_bytes == seg8 * n_layers + head8
    assert f32.store.total_bytes > 3.5 * i8.store.total_bytes
    # materialize dequantizes: close to the fp32 base, channel-bounded
    deq = i8.materialize_params()
    err = jax.tree.map(lambda a, b: float(np.abs(np.asarray(a)
                                                - np.asarray(b)).max()),
                       deq, state["base"])
    assert max(jax.tree.leaves(err)) < 0.05
    with pytest.raises(ValueError, match="quantization"):
        LayerStreamedState.create_frozen(state["base"],
                                         str(tmp_path / "bad"), quant="nf4")
    f32.close()
    i8.close()


def test_quantized_resident_bound_and_mode_guard(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg(total_steps=2, base_quant="int8")
    specs = registry.param_specs(cfg)
    lspecs = lora_specs(specs, tcfg.lora_targets, tcfg.lora_rank)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    adapter = {"lora": state["lora"], "opt": state["opt"],
               "step": state["step"]}
    from repro.param import tree_bytes
    adapter_b = tree_bytes({"lora": adapter["lora"], "opt": adapter["opt"]})
    lstate = LayerStreamedState.create_frozen(
        state["base"], str(tmp_path / "segs"), quant="int8",
        max_resident=tcfg.offload_resident)
    step_fn = make_stream_step(cfg, tcfg, lstate, "", adapter=adapter)
    batch = registry.make_batch(jax.random.PRNGKey(1), cfg,
                                tcfg.global_batch, tcfg.seq_len)
    batch["labels"] = batch["tokens"]
    for step in range(2):
        step_fn(batch, step)
    measured = step_fn.stats()["param_peak_resident_bytes"] + adapter_b
    _, analytic8 = lora_stream_resident_bytes(
        specs, lspecs, window=tcfg.offload_resident, base_quant="int8")
    _, analytic32 = lora_stream_resident_bytes(
        specs, lspecs, window=tcfg.offload_resident)
    assert measured <= analytic8 < analytic32
    assert step_fn.stats()["param_bytes_written"] == 0
    step_fn.close()
    lstate.close()
    # feeding a quantized store to a program built without --base-quant
    # (or vice versa) must fail loudly, not shapes-deep inside jax
    re = LayerStreamedState.open(str(tmp_path / "segs"), state["base"])
    with pytest.raises(ValueError, match="base-quant"):
        make_stream_step(cfg, _tcfg(), re, "", adapter=adapter)
    re.close()
    # and --base-quant without LoRA is rejected outright
    from repro.models.lm import make_layer_program
    with pytest.raises(ValueError, match="base-quant"):
        make_layer_program(cfg, _tcfg(lora_rank=0, base_quant="int8"))


# ---------------------------------------------------------------------------
# streamed int8-LoRA training (acceptance: tracks fp32 base over 10 steps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,targets", [
    ("gpt2_124m", ("wq", "wk", "wv", "wo")),
    ("mamba2_130m", ("w_x", "w_out")),
], ids=["dense", "ssm"])
def test_int8_lora_loss_tracks_fp32_base(arch, targets, tmp_path):
    cfg = configs.get_smoke(arch)
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-3,
                total_steps=10, warmup_steps=1, compute_dtype="float32",
                lora_rank=4, lora_alpha=16.0, lora_targets=targets,
                offload_stream_params=True)
    _, o32 = train_loop(cfg, TrainConfig(**base,
                                         offload_dir=str(tmp_path / "f32")),
                        out_dir=None, print_fn=None)
    _, o8 = train_loop(cfg, TrainConfig(**base, base_quant="int8",
                                        offload_dir=str(tmp_path / "i8")),
                       out_dir=None, print_fn=None)
    l32 = [r["loss"] for r in o32.rows]
    l8 = [r["loss"] for r in o8.rows]
    assert len(l8) == 10
    np.testing.assert_allclose(l32, l8, atol=INT8_LOSS_ATOL)


def test_int8_adapter_resume_deterministic_and_guarded(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-3,
                schedule="constant", warmup_steps=1, compute_dtype="float32",
                lora_rank=4, lora_alpha=16.0, offload_stream_params=True,
                base_quant="int8")
    tA = TrainConfig(**base, total_steps=6)
    _, oA = train_loop(cfg, tA, out_dir=str(tmp_path / "a"), print_fn=None)
    out = str(tmp_path / "run")
    tB1 = TrainConfig(**base, total_steps=3, checkpoint_every=3)
    _, oB1 = train_loop(cfg, tB1, out_dir=out, print_fn=None)
    # resuming against a different base codec must hard-error: the adapter
    # learned around the int8 quantization error
    fp32 = {**base, "base_quant": "", "total_steps": 6,
            "checkpoint_every": 3}
    with pytest.raises(ValueError, match="base_quant|base_tag"):
        train_loop(cfg, TrainConfig(**fp32), out_dir=out, print_fn=None)
    # matching codec resumes bit-deterministically
    tB2 = TrainConfig(**base, total_steps=6, checkpoint_every=3)
    _, oB2 = train_loop(cfg, tB2, out_dir=out, print_fn=None)
    assert oB2.rows[0]["step"] == 3
    lossesA = [r["loss"] for r in oA.rows]
    lossesB = ([r["loss"] for r in oB1.rows] + [r["loss"] for r in oB2.rows])
    np.testing.assert_allclose(lossesA, lossesB, atol=1e-6)


def test_bf16_moment_equivalence_through_codec_layer(tmp_path):
    """The bf16 moment path now runs through the codec layer: storage bytes
    halve and the numerics match the pre-codec cast behavior (fp32 math,
    bf16-rounded storage each step)."""
    from repro.offload import OffloadedTrainState
    import jax.numpy as jnp
    cfg = configs.get_smoke("gpt2_124m")
    state = init_state(jax.random.PRNGKey(0), cfg, _tcfg(lora_rank=0))
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    ost = OffloadedTrainState.create(state, str(tmp_path / "b"), 4,
                                     moment_dtype="bfloat16")
    assert ost.state_bytes == n * 8            # fp32 p + bf16 m + v
    assert all(r.codec == ("bf16" if r.name.startswith(("m.", "v."))
                           else "identity") for r in ost.store.records)
    grads = jax.tree.map(lambda p: jnp.full_like(p, 1e-3), state["params"])
    ost.apply_update(grads, lr=1e-3)
    ost.flush()
    # window precision equals on-flash precision: a fresh reopen sees the
    # very values the resident window holds
    fresh = OffloadedTrainState.open(ost.store.directory, state["params"])
    for seg in range(ost.store.num_segments):
        want = ost.engine.acquire(seg)
        got = fresh.engine.acquire(seg)
        for k in want:
            np.testing.assert_array_equal(np.asarray(want[k]),
                                          np.asarray(got[k]))
    ost.close()
    fresh.close()
