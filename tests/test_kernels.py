"""Per-kernel allclose sweeps (interpret mode) against the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.flash_attention import flash_bwd, flash_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd_chunked_pallas
from repro.kernels.ssd.ref import ssd_ref
from repro.models.mamba2 import ssd_chunked


def _qkv(b, h, kvh, sq, skv, d, dtype):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, sq, d), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (b, kvh, skv, d), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, kvh, skv, d), jnp.float32)
    return q.astype(dtype), k.astype(dtype), v.astype(dtype)


FWD_CASES = [
    # b, h, kvh, sq, skv, d, causal, window, bq, bk
    (1, 1, 1, 8, 8, 4, True, 0, 4, 4),
    (2, 4, 2, 16, 16, 8, True, 0, 4, 8),
    (1, 4, 1, 16, 16, 8, True, 5, 8, 4),   # MQA + sliding window
    (2, 2, 2, 12, 20, 8, False, 0, 4, 4),  # cross-attention shape
    (1, 8, 4, 32, 32, 16, True, 0, 16, 16),
]


@pytest.mark.parametrize("case", FWD_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_fwd_sweep(case, dtype):
    b, h, kvh, sq, skv, d, causal, window, bq, bk = case
    q, k, v = _qkv(b, h, kvh, sq, skv, d, dtype)
    q_off = skv - sq if causal else 0
    o, _ = flash_fwd(q, k, v, scale=d ** -0.5, causal=causal, window=window,
                     q_offset=q_off, kv_len=skv, block_q=bq, block_k=bk,
                     interpret=True)
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=causal, window=window,
                        q_offset=q_off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(o.astype(jnp.float32), ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("case", FWD_CASES[:3])
def test_flash_bwd_sweep(case):
    b, h, kvh, sq, skv, d, causal, window, bq, bk = case
    q, k, v = _qkv(b, h, kvh, sq, skv, d, jnp.float32)
    q_off = skv - sq if causal else 0
    o, lse = flash_fwd(q, k, v, scale=d ** -0.5, causal=causal, window=window,
                       q_offset=q_off, kv_len=skv, block_q=bq, block_k=bk,
                       interpret=True)
    do = jax.random.normal(jax.random.PRNGKey(3), o.shape, jnp.float32)
    dq, dk, dv = flash_bwd(q, k, v, o, lse, do, scale=d ** -0.5,
                           causal=causal, window=window, q_offset=q_off,
                           kv_len=skv, block_q=bq, block_k=bk, interpret=True)

    def f(q_, k_, v_):
        return (attention_ref(q_, k_, v_, causal=causal, window=window,
                              q_offset=q_off) * do).sum()

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(dq, gq, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(dk, gk, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(dv, gv, rtol=2e-4, atol=2e-5)


def test_flash_wrapper_padding_and_vjp():
    """Model-layout wrapper: non-multiple seq lengths get padded/cropped."""
    b, sq, h, d = 2, 10, 4, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sq, 2, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sq, 2, d))
    from repro.kernels.flash_attention.ops import flash_attention
    out = flash_attention(q, k, v, causal=True, block_q=4, block_k=4,
                          interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(out.transpose(0, 2, 1, 3), ref, rtol=2e-5,
                               atol=2e-5)
    g = jax.grad(lambda x: (flash_attention(x, k, v, causal=True, block_q=4,
                                            block_k=4, interpret=True)
                            ** 2).sum())(q)
    assert bool(jnp.isfinite(g).all())


SSD_CASES = [
    # b, s, nh, hd, ds, chunk
    (1, 8, 1, 4, 4, 4),
    (2, 32, 3, 8, 16, 8),
    (1, 24, 2, 16, 8, 8),   # s not a power of two multiple
    (2, 16, 4, 8, 32, 16),
]


@pytest.mark.parametrize("case", SSD_CASES)
@pytest.mark.parametrize("with_init", [False, True])
def test_ssd_pallas_vs_sequential_ref(case, with_init):
    b, s, nh, hd, ds, chunk = case
    xh = jax.random.normal(jax.random.PRNGKey(0), (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (nh,)) * 0.5)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, s, ds))
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, s, ds))
    h0 = (jax.random.normal(jax.random.PRNGKey(5), (b, nh, hd, ds))
          if with_init else None)
    y_ref, h_ref = ssd_ref(xh, dt, A, B_, C_, initial_state=h0)
    y_pal, h_pal = ssd_chunked_pallas(xh, dt, A, B_, C_, chunk=chunk,
                                      initial_state=h0, interpret=True)
    np.testing.assert_allclose(y_pal, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_pal, h_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("case", SSD_CASES[:2])
def test_ssd_jnp_chunked_matches_ref(case):
    """The model's jnp chunked SSD (used in training) vs the sequential ref."""
    b, s, nh, hd, ds, chunk = case
    xh = jax.random.normal(jax.random.PRNGKey(0), (b, s, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, nh)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (nh,)) * 0.5)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, s, ds))
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, s, ds))
    y_ref, h_ref = ssd_ref(xh, dt, A, B_, C_)
    y_jnp, h_jnp = ssd_chunked(xh, dt, A, B_, C_, chunk)
    np.testing.assert_allclose(y_jnp, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(h_jnp, h_ref, rtol=2e-4, atol=2e-4)
