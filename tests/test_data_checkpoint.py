"""Data pipeline + checkpoint/fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stub

from repro.checkpoint import (latest_step, load_safetensors, restore, save,
                              save_safetensors)
from repro.checkpoint.store import CheckpointStore
from repro.data.corpus import CHQA_CATEGORIES, chqa_pairs, synthetic_wikitext
from repro.data.dataset import IGNORE, LMDataset, QADataset, packed_batches
from repro.data.tokenizer import ByteTokenizer

hypothesis, st = hypothesis_or_stub()


# ---------------------------------------------------------------------------
# tokenizer
# ---------------------------------------------------------------------------
@hypothesis.settings(max_examples=50, deadline=None)
@hypothesis.given(st.text(max_size=200))
def test_tokenizer_roundtrip_any_unicode(s):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(s)) == s


def test_tokenizer_merges_roundtrip_and_shrink():
    corpus = [synthetic_wikitext(30, seed=i) for i in range(3)]
    tok = ByteTokenizer.train(corpus, n_merges=64)
    s = synthetic_wikitext(10, seed=9)
    ids = tok.encode(s, bos=True, eos=True)
    assert tok.decode(ids) == s
    assert len(ids) < len(ByteTokenizer().encode(s)) + 2
    assert tok.vocab_size == 3 + 256 + 64


def test_tokenizer_save_load(tmp_path):
    tok = ByteTokenizer.train(["aaab aaab aaab"], n_merges=8)
    p = str(tmp_path / "tok.json")
    tok.save(p)
    tok2 = ByteTokenizer.load(p)
    s = "aaab test"
    assert tok.encode(s) == tok2.encode(s)


# ---------------------------------------------------------------------------
# datasets
# ---------------------------------------------------------------------------
def test_lm_dataset_shift():
    tok = ByteTokenizer()
    ds = LMDataset("abcdefghij" * 20, tok, seq_len=16)
    ex = ds.example(0)
    np.testing.assert_array_equal(ex["tokens"][1:], ex["labels"][:-1])


def test_qa_dataset_masks_prompt():
    tok = ByteTokenizer()
    qa = QADataset(chqa_pairs(1, 10), tok, seq_len=256)
    ex = qa.example(0)
    labels = ex["labels"]
    assert (labels[:5] == IGNORE).all()        # prompt region masked
    assert (labels >= 0).sum() > 10            # answer region supervised


def test_chqa_categories_and_privacy():
    pairs = chqa_pairs(3, 25)
    assert {p["category"] for p in pairs} == set(CHQA_CATEGORIES)
    # deterministic per user, different across users
    assert chqa_pairs(3, 5) == chqa_pairs(3, 5)
    assert chqa_pairs(3, 5) != chqa_pairs(4, 5)


def test_packed_batches_deterministic():
    tok = ByteTokenizer()
    ds = LMDataset(synthetic_wikitext(100), tok, 32)
    b1 = list(packed_batches(ds, 4, seed=7, epochs=1))
    b2 = list(packed_batches(ds, 4, seed=7, epochs=1))
    assert len(b1) == len(b2) > 0
    np.testing.assert_array_equal(b1[0]["tokens"], b2[0]["tokens"])


# ---------------------------------------------------------------------------
# safetensors
# ---------------------------------------------------------------------------
def test_safetensors_roundtrip(tmp_path):
    import ml_dtypes
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.arange(4, dtype=np.int64),
        "c": np.linspace(0, 1, 8).astype(ml_dtypes.bfloat16),
    }
    p = str(tmp_path / "x.safetensors")
    save_safetensors(p, tensors, metadata={"step": "3"})
    got, meta = load_safetensors(p)
    assert meta["step"] == "3"
    for k in tensors:
        np.testing.assert_array_equal(np.asarray(got[k], dtype=np.float64),
                                      np.asarray(tensors[k], dtype=np.float64))


def test_safetensors_header_format(tmp_path):
    """Byte-level format check: 8-byte LE length + JSON header."""
    import json
    import struct
    p = str(tmp_path / "x.safetensors")
    save_safetensors(p, {"w": np.zeros((2, 2), np.float32)})
    raw = open(p, "rb").read()
    (hlen,) = struct.unpack("<Q", raw[:8])
    header = json.loads(raw[8:8 + hlen])
    assert header["w"]["dtype"] == "F32"
    assert header["w"]["shape"] == [2, 2]
    assert len(raw) == 8 + hlen + 16


# ---------------------------------------------------------------------------
# checkpoint store / fault tolerance
# ---------------------------------------------------------------------------
def _state(x=0.0):
    return {"params": {"w": jnp.full((4, 2), x), "b": jnp.zeros((2,))},
            "opt": {"m": {"w": jnp.ones((4, 2))}},
            "step": jnp.int32(0)}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    d = str(tmp_path / "ck")
    for step in [1, 2, 3, 4]:
        save(_state(step), d, step, keep=2)
    assert latest_step(d) == 4
    got, step = restore(d, _state())
    assert step == 4
    assert float(got["params"]["w"][0, 0]) == 4.0
    steps = sorted(int(x[5:]) for x in os.listdir(d) if x.startswith("step_"))
    assert steps == [3, 4]  # retention


def test_checkpoint_atomic_no_partial(tmp_path):
    """A leftover tmp dir never shadows a good checkpoint."""
    d = str(tmp_path / "ck")
    save(_state(1.0), d, 1)
    os.makedirs(os.path.join(d, ".tmp-2"))  # simulated crash mid-write
    got, step = restore(d, _state())
    assert step == 1


def test_checkpoint_async_store(tmp_path):
    d = str(tmp_path / "ck")
    store = CheckpointStore(d, keep=3)
    store.save_async(_state(7.0), 10)
    store.wait()
    got, step = restore(d, _state())
    assert step == 10 and float(got["params"]["w"][0, 0]) == 7.0


def test_restore_resume_exact_training(tmp_path):
    """Kill/restart determinism: resume == uninterrupted run (bitwise)."""
    from repro import configs
    from repro.config import TrainConfig
    from repro.core.step import init_state, make_train_step
    from repro.models import registry
    cfg = configs.get_smoke("qwen15_05b")
    tcfg = TrainConfig(global_batch=2, seq_len=8, compute_dtype="float32",
                       total_steps=6, warmup_steps=0, learning_rate=1e-3)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    batches = [registry.make_batch(jax.random.PRNGKey(i), cfg, 2, 8)
               for i in range(6)]

    # uninterrupted
    s = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    for b in batches:
        s, m = step_fn(s, b)
    loss_full = float(m["loss"])

    # interrupted at step 3 + restored
    s2 = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    for b in batches[:3]:
        s2, _ = step_fn(s2, b)
    d = str(tmp_path / "ck")
    save(s2, d, 3)
    s3, _ = restore(d, s2)
    for b in batches[3:]:
        s3, m3 = step_fn(s3, b)
    assert float(m3["loss"]) == loss_full


def test_elastic_reshard_restore(tmp_path):
    """Restore onto different shardings (elastic rescale path)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    d = str(tmp_path / "ck")
    state = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save(state, d, 1)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    got, _ = restore(d, state, shardings=sh)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(state["w"]))
    assert got["w"].sharding == sh["w"]
