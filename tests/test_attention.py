"""Memory-efficient attention (paper C4): streaming == naive exact softmax."""
import jax
import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stub

from repro.core.attention import SENTINEL, attention

hypothesis, st = hypothesis_or_stub()


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@hypothesis.settings(max_examples=25, deadline=None)
@hypothesis.given(
    b=st.integers(1, 3), sq=st.integers(1, 24), h=st.sampled_from([1, 2, 4]),
    kv_groups=st.sampled_from([1, 2]), d=st.sampled_from([4, 8]),
    chunk=st.sampled_from([3, 4, 8, 16]), causal=st.booleans(),
    window=st.sampled_from([0, 5]))
def test_streaming_matches_naive(b, sq, h, kv_groups, d, chunk, causal,
                                 window):
    if h % kv_groups:
        return
    kvh = h // kv_groups
    q = _rand(0, b, sq, h, d)
    k = _rand(1, b, sq, kvh, d)
    v = _rand(2, b, sq, kvh, d)
    out_n = attention(q, k, v, causal=causal, window=window, impl="naive")
    out_s = attention(q, k, v, causal=causal, window=window, impl="streaming",
                      chunk=chunk)
    np.testing.assert_allclose(out_n, out_s, rtol=2e-5, atol=2e-5)


def test_q_blocking_path():
    """sq large enough to trigger the outer q-chunk map."""
    q = _rand(0, 2, 40, 2, 8)
    k = _rand(1, 2, 40, 2, 8)
    v = _rand(2, 2, 40, 2, 8)
    out_n = attention(q, k, v, causal=True, impl="naive")
    out_s = attention(q, k, v, causal=True, impl="streaming", chunk=8)
    np.testing.assert_allclose(out_n, out_s, rtol=2e-5, atol=2e-5)


def test_decode_against_prefix():
    """Decode (sq=1 vs long cache with padding sentinel) == full attention row."""
    b, s, h, d = 2, 12, 2, 8
    q_full = _rand(0, b, s, h, d)
    k = _rand(1, b, s, h, d)
    v = _rand(2, b, s, h, d)
    full = attention(q_full, k, v, causal=True, impl="naive")
    # decode the last position against a padded cache
    smax = s + 5
    kp = jnp.pad(k, ((0, 0), (0, 5), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 5), (0, 0), (0, 0)))
    kv_pos = jnp.broadcast_to(jnp.arange(smax)[None], (b, smax))
    kv_pos = jnp.where(kv_pos < s, kv_pos, SENTINEL)
    q_pos = jnp.full((b, 1), s - 1, jnp.int32)
    row = attention(q_full[:, -1:], kp, vp, q_pos=q_pos, kv_pos=kv_pos,
                    causal=True, impl="streaming", chunk=4)
    np.testing.assert_allclose(full[:, -1:], row, rtol=2e-5, atol=2e-5)


def test_streaming_grad_finite():
    q = _rand(0, 1, 8, 2, 4)
    k = _rand(1, 1, 8, 2, 4)
    v = _rand(2, 1, 8, 2, 4)
    g = jax.grad(lambda q_: (attention(q_, k, v, impl="streaming",
                                       chunk=4) ** 2).sum())(q)
    assert bool(jnp.isfinite(g).all())
    gn = jax.grad(lambda q_: (attention(q_, k, v, impl="naive") ** 2).sum())(q)
    np.testing.assert_allclose(g, gn, rtol=2e-4, atol=2e-5)


def test_traced_window():
    """Hybrid layer scans pass the window as a traced scalar."""
    q = _rand(0, 1, 10, 2, 4)
    k = _rand(1, 1, 10, 2, 4)
    v = _rand(2, 1, 10, 2, 4)

    def f(w):
        return attention(q, k, v, causal=True, window=w, impl="streaming",
                         chunk=4)
    out_t = jax.jit(f)(jnp.int32(4))
    out_s = attention(q, k, v, causal=True, window=4, impl="naive")
    np.testing.assert_allclose(out_t, out_s, rtol=2e-5, atol=2e-5)
    out_t0 = jax.jit(f)(jnp.int32(0))
    out_s0 = attention(q, k, v, causal=True, window=0, impl="naive")
    np.testing.assert_allclose(out_t0, out_s0, rtol=2e-5, atol=2e-5)
