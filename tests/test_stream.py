"""Layer-streamed fwd/bwd (paper §4.1.1 C1, full depth; repro/core/stream.py).

Covers: per-layer loss/grad equivalence of the two-sweep program vs the
in-memory jit path (dense and ssm families), layer-aligned segment mapping
round-trip, bf16 moment segments, the analytic depth-independent resident
bound, TrainerRuntime resume determinism across all three loop variants,
and the checkpoint layout dispatch/guards.
"""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.core.step import init_state, make_grad_step, make_stream_step
from repro.core.zero import stream_resident_bytes
from repro.launch.train import train_loop
from repro.models import registry
from repro.offload import LayerStreamedState, OffloadedTrainState
from repro.param import flatten_names


def _batch(cfg, batch=4, seq=32, seed=1):
    b = registry.make_batch(jax.random.PRNGKey(seed), cfg, batch, seq)
    b["labels"] = b["tokens"]
    return b


def _tcfg(**kw):
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-4,
                total_steps=10, warmup_steps=1, compute_dtype="float32")
    base.update(kw)
    return TrainConfig(**base)


# ---------------------------------------------------------------------------
# per-layer grad + loss equivalence vs the in-memory jit path
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["gpt2_124m", "mamba2_130m"])
def test_streamed_grads_match_jit_path(arch, tmp_path):
    cfg = configs.get_smoke(arch)
    tcfg = _tcfg(grad_clip=0.0)        # compare raw (unclipped) gradients
    batch = _batch(cfg)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    loss_mem, _, grads_mem = jax.jit(make_grad_step(cfg, tcfg))(
        state["params"], batch)
    gnamed = {n: np.asarray(g, np.float32)
              for n, g in flatten_names(grads_mem)}

    lstate = LayerStreamedState.create(state, str(tmp_path / "segs"))
    step_fn = make_stream_step(cfg, tcfg, lstate, str(tmp_path / "grads"))
    loss_eval, _ = step_fn.loss_only(batch)   # streamed eval, pre-update
    np.testing.assert_allclose(float(loss_mem), float(loss_eval), atol=1e-5)
    loss_s, metrics = step_fn(batch, 0)
    np.testing.assert_allclose(float(loss_mem), loss_s, atol=1e-5)

    # per-layer gradient equality, read straight from the scratch segments
    gstore = step_fn.grad_engine.store
    step_fn.grad_engine.flush()
    for seg in range(lstate.n_layers):
        data = gstore.read_segment(seg)
        for name, g in data.items():
            # blocks.<i>.<leaf> <-> stacked blocks.<leaf> row i
            rest = name.split(".", 2)[2]
            ref = gnamed["blocks." + rest][seg]
            np.testing.assert_allclose(g, ref, atol=1e-5, rtol=1e-4)
    head = gstore.read_segment(lstate.head_segment)
    for name, g in head.items():
        np.testing.assert_allclose(g, gnamed[name], atol=1e-5, rtol=1e-4)
    step_fn.close()
    lstate.close()


# ---------------------------------------------------------------------------
# smoke-train equivalence (acceptance criterion: <=1e-5/step over >=10 steps)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("micro", [1, 2])
def test_stream_smoke_train_matches_in_memory(tmp_path, micro):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-4,
                microbatches=micro, total_steps=10, warmup_steps=1,
                compute_dtype="float32")
    _, obs_mem = train_loop(cfg, TrainConfig(**base), out_dir=None,
                            print_fn=None)
    _, obs_str = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_dir=str(tmp_path / "segs")),
        out_dir=None, print_fn=None)
    losses_mem = [r["loss"] for r in obs_mem.rows]
    losses_str = [r["loss"] for r in obs_str.rows]
    assert len(losses_str) == 10
    np.testing.assert_allclose(losses_mem, losses_str, atol=1e-5)


# ---------------------------------------------------------------------------
# layer-aligned mapping round trip
# ---------------------------------------------------------------------------
def test_layer_aligned_segments_roundtrip(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg()
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    lstate = LayerStreamedState.create(state, str(tmp_path / "segs"))
    # one segment per block + one head segment, labelled
    assert lstate.store.num_segments == cfg.n_layers + 1
    assert lstate.store.labels == [f"layer:{i}" for i in
                                   range(cfg.n_layers)] + ["head"]
    # every leaf of segment i belongs to block i (or the head)
    for seg in range(cfg.n_layers):
        for n in lstate.seg_param_names(seg):
            assert n.startswith(f"blocks.{seg}."), (seg, n)
    for n in lstate.seg_param_names(lstate.head_segment):
        assert not n.startswith("blocks."), n
    # materialized tree is bit-identical to what was paged out
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state["params"], lstate.materialize_params())
    # per-layer access equals the stacked rows
    bp1 = lstate.layer_params(1)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b[1]),
                 bp1, state["params"]["blocks"])
    lstate.flush()
    # reopen from the mapping table alone
    re = LayerStreamedState.open(lstate.store.directory, state["params"])
    assert re.n_layers == cfg.n_layers
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state["params"], re.materialize_params())
    lstate.close()
    re.close()


# ---------------------------------------------------------------------------
# bf16 moment segments (halved m/v bytes, fp32 round-trip math)
# ---------------------------------------------------------------------------
def test_bf16_moment_segments(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    tcfg = _tcfg()
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    n = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    ost32 = OffloadedTrainState.create(state, str(tmp_path / "f32"), 4)
    ost16 = OffloadedTrainState.create(state, str(tmp_path / "bf16"), 4,
                                       moment_dtype="bfloat16")
    assert ost32.state_bytes == n * 12          # fp32 p + m + v
    assert ost16.state_bytes == n * 8           # fp32 p + bf16 m + v
    grads = jax.tree.map(lambda p: jnp.full_like(p, 1e-3), state["params"])
    p32 = ost32.apply_update(grads, lr=1e-3)
    p16 = ost16.apply_update(grads, lr=1e-3)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(a, b, atol=1e-4),
                 p32, p16)
    ost32.close()
    ost16.close()


def test_stream_loop_with_bf16_moments(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-4, total_steps=4,
                warmup_steps=1, compute_dtype="float32")
    _, obs_mem = train_loop(cfg, TrainConfig(**base), out_dir=None,
                            print_fn=None)
    _, obs_b16 = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_moment_dtype="bfloat16",
                         offload_dir=str(tmp_path / "segs")),
        out_dir=None, print_fn=None)
    np.testing.assert_allclose([r["loss"] for r in obs_mem.rows],
                               [r["loss"] for r in obs_b16.rows], atol=1e-3)


# ---------------------------------------------------------------------------
# resident bound: a few layer segments + head, independent of depth
# ---------------------------------------------------------------------------
def test_stream_resident_bytes_depth_independent():
    smoke = configs.get_smoke("gpt2_124m")
    shallow = registry.param_specs(smoke)
    deep = registry.param_specs(dataclasses.replace(smoke, n_layers=12))
    full_s, res_s = stream_resident_bytes(shallow, window=2)
    full_d, res_d = stream_resident_bytes(deep, window=2)
    assert full_d > full_s
    assert res_d == res_s                  # depth-independent
    assert res_d < full_d
    # bf16 moments shrink the streamed segments too
    _, res_b16 = stream_resident_bytes(deep, window=2, moment_bytes=4)
    assert res_b16 < res_d


def test_measured_peak_resident_within_analytic_bound(tmp_path):
    cfg = dataclasses.replace(configs.get_smoke("gpt2_124m"), n_layers=6)
    tcfg = _tcfg(total_steps=4)
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    lstate = LayerStreamedState.create(state, str(tmp_path / "segs"),
                                      max_resident=tcfg.offload_resident)
    step_fn = make_stream_step(cfg, tcfg, lstate, str(tmp_path / "grads"))
    batch = _batch(cfg)
    for step in range(2):
        step_fn(batch, step)
    measured = step_fn.stats()["param_peak_resident_bytes"]
    # the async pipeline defers writes and pools recycled buffers, so the
    # bound includes both shares (up to 2*window segments)
    _, analytic = stream_resident_bytes(registry.param_specs(cfg),
                                        window=tcfg.offload_resident,
                                        write_queue=2 * tcfg.offload_resident)
    assert measured <= analytic
    assert measured < lstate.store.total_bytes   # never whole-model resident
    step_fn.close()
    lstate.close()


# ---------------------------------------------------------------------------
# TrainerRuntime resume determinism (all three loop variants)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("extra", [
    {},                                       # in-memory
    {"offload_segments": 3},                  # optimizer offload
    {"offload_stream_params": True},          # layer-streamed
], ids=["memory", "offload", "stream"])
def test_resume_determinism(tmp_path, extra):
    cfg = configs.get_smoke("gpt2_124m")
    # constant schedule: the cosine decay depends on total_steps, which
    # differs between the interrupted and the straight run
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-4,
                schedule="constant", warmup_steps=1, compute_dtype="float32")
    tA = TrainConfig(**base, total_steps=6, **extra)
    _, oA = train_loop(cfg, tA, out_dir=None, print_fn=None)
    out = str(tmp_path / "run")
    tB1 = TrainConfig(**base, total_steps=3, checkpoint_every=3, **extra)
    _, oB1 = train_loop(cfg, tB1, out_dir=out, print_fn=None)
    tB2 = TrainConfig(**base, total_steps=6, checkpoint_every=3, **extra)
    _, oB2 = train_loop(cfg, tB2, out_dir=out, print_fn=None)
    assert oB2.rows[0]["step"] == 3            # actually resumed
    lossesA = [r["loss"] for r in oA.rows]
    lossesB = ([r["loss"] for r in oB1.rows] +
               [r["loss"] for r in oB2.rows])
    np.testing.assert_allclose(lossesA, lossesB, atol=1e-6)


def test_sigterm_preemption_flushes_consistent_checkpoint(tmp_path):
    """A SIGTERM mid-run must flush at the next step *boundary* (the offload
    segments mutate in place mid-step) and resume bit-deterministically."""
    import signal as _signal
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, learning_rate=1e-4,
                schedule="constant", warmup_steps=1, compute_dtype="float32",
                total_steps=6)
    _, oA = train_loop(cfg, TrainConfig(**base, offload_stream_params=True,
                                        offload_dir=str(tmp_path / "a")),
                       out_dir=None, print_fn=None)
    out = str(tmp_path / "run")
    fired = []

    def pfn(msg):
        # raise SIGTERM inside step 1's body; the deferred handler lets the
        # step (and its full update sweep) finish before flushing
        if msg.startswith("step     1") and not fired:
            fired.append(True)
            _signal.raise_signal(_signal.SIGTERM)

    t = TrainConfig(**base, offload_stream_params=True, checkpoint_every=100)
    with pytest.raises(SystemExit) as e:
        train_loop(cfg, t, out_dir=out, print_fn=pfn)
    assert e.value.code == 128 + _signal.SIGTERM.value
    _, oB = train_loop(cfg, t, out_dir=out, print_fn=None)
    assert oB.rows[0]["step"] == 2             # steps 0 and 1 completed
    lossesB = [None, None] + [r["loss"] for r in oB.rows]
    np.testing.assert_allclose([r["loss"] for r in oA.rows][2:], lossesB[2:],
                               atol=1e-6)


def test_checkpoint_layout_guards(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=2, seq_len=16, total_steps=2,
                checkpoint_every=2, warmup_steps=1, compute_dtype="float32")
    out = str(tmp_path / "run")
    train_loop(cfg, TrainConfig(**base, offload_stream_params=True),
               out_dir=out, print_fn=None)
    # a layer-aligned checkpoint refuses the byte-balanced resume path...
    with pytest.raises(ValueError, match="layer-aligned"):
        train_loop(cfg, TrainConfig(**base, offload_segments=3),
                   out_dir=out, print_fn=None)
    # ...and the in-memory one
    with pytest.raises(ValueError, match="offload"):
        train_loop(cfg, TrainConfig(**base), out_dir=out, print_fn=None)
    # byte-balanced checkpoints refuse the streamed resume path
    out2 = str(tmp_path / "run2")
    train_loop(cfg, TrainConfig(**base, offload_segments=3), out_dir=out2,
               print_fn=None)
    with pytest.raises(ValueError, match="byte-balanced"):
        train_loop(cfg, TrainConfig(**base, offload_stream_params=True),
                   out_dir=out2, print_fn=None)
    # restore dispatch hands back the right class
    from repro.checkpoint.store import restore_offload
    from repro.param import abstract_params
    like = abstract_params(registry.param_specs(cfg))
    st, step = restore_offload(os.path.join(out, "ckpt"),
                               str(tmp_path / "w"), like)
    assert isinstance(st, LayerStreamedState) and step == 2
    st.close()
