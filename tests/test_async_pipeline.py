"""Async overlap pipeline for the streamed trainer (PR 5).

Covers: the Prefetcher stale-read race (an in-flight read racing a
write-back must be discarded, not buffered), no-silent-drop of scheduled
prefetches (bounded reader + forced_drops accounting), the allocation-free
reusable-buffer read path, async write-back value transparency (write hits
via steal, flush-barrier-before-hardlink-snapshot), bit-determinism of
async vs synchronous write-back (dense + ssm) including checkpoint resume,
and staging-mode loss equivalence against the non-staged sync-write
streamed path.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.config import TrainConfig
from repro.launch.train import train_loop
from repro.offload.engine import OffloadEngine, Prefetcher
from repro.offload.segments import SegmentStore
from repro.offload.state import OffloadedTrainState
from repro.optim.adamw import adamw_init


def _groups(seed=0, n=5, shape=(7, 3)):
    rng = np.random.RandomState(seed)
    return [[(f"p.l{i}", rng.randn(*shape).astype(np.float32)),
             (f"m.l{i}", rng.randn(*shape).astype(np.float32)),
             (f"v.l{i}", np.abs(rng.randn(*shape)).astype(np.float32))]
            for i in range(n)]


class _GatedReads:
    """SegmentStore proxy whose reads of ``gate_seg`` capture their bytes,
    then park until released — a deterministic handle on the in-flight
    window where the stale-read race lives."""

    def __init__(self, store, gate_seg):
        self._store = store
        self._gate_seg = gate_seg
        self.read_started = threading.Event()
        self.release = threading.Event()
        self._armed = True

    def __getattr__(self, name):
        return getattr(self._store, name)

    def read_segment(self, seg, **kw):
        data = self._store.read_segment(seg, **kw)   # bytes from *before*
        if seg == self._gate_seg and self._armed:
            self._armed = False
            self.read_started.set()
            assert self.release.wait(timeout=10.0)
        return data


# ---------------------------------------------------------------------------
# satellite: stale-read race — invalidate() must poison in-flight reads
# ---------------------------------------------------------------------------
def test_inflight_read_discarded_after_invalidate(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    gated = _GatedReads(store, gate_seg=0)
    pf = Prefetcher(gated, depth=2)
    try:
        pf.schedule(0)
        assert gated.read_started.wait(timeout=10.0)  # read is in flight
        # a write-back lands new bytes while the read is parked mid-flight
        name = store.segment_names(0)[0]
        new = np.full(store.record(name).shape, 42.0, np.float32)
        pf.invalidate(0)                   # what the engine does on write
        store.write_segment(0, {name: new})
        gated.release.set()                # stale read completes now
        data = pf.take(0)                  # must NOT see the stale copy
        np.testing.assert_array_equal(data[name], new)
        assert pf.prefetch_hits == 0       # stale buffer was discarded...
        assert pf.sync_loads == 1          # ...and a fresh load served it
    finally:
        gated.release.set()
        pf.close()


def test_invalidated_then_rescheduled_read_is_fresh(tmp_path):
    """A segment re-scheduled while its poisoned read is still in flight
    must come back with the post-write bytes."""
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    gated = _GatedReads(store, gate_seg=1)
    pf = Prefetcher(gated, depth=2)
    try:
        pf.schedule(1)
        assert gated.read_started.wait(timeout=10.0)
        name = store.segment_names(1)[0]
        new = np.full(store.record(name).shape, -7.0, np.float32)
        pf.invalidate(1)
        store.write_segment(1, {name: new})
        pf.schedule(1)                     # re-request while still in flight
        gated.release.set()
        np.testing.assert_array_equal(pf.take(1)[name], new)
    finally:
        gated.release.set()
        pf.close()


# ---------------------------------------------------------------------------
# satellite: no silent drop of scheduled-not-yet-taken prefetches
# ---------------------------------------------------------------------------
def test_overscheduled_prefetches_all_survive(tmp_path):
    """Scheduling more segments than the buffer holds must not lose any:
    the reader waits for slots instead of dropping completed reads."""
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=6), 6)
    pf = Prefetcher(store, depth=1)
    try:
        for seg in range(6):
            pf.schedule(seg)
        for seg in range(6):               # in-order consumption: no drops
            data = pf.take(seg)
            for name, arr in data.items():
                np.testing.assert_array_equal(arr, store.read_segment(
                    seg, window=True)[name])
        assert pf.forced_drops == 0
        assert pf.prefetch_hits + pf.sync_loads == 6
    finally:
        pf.close()


def test_stranded_buffer_recovers_via_forced_drop(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=4), 4)
    pf = Prefetcher(store, depth=1)
    try:
        pf.schedule(0)                     # buffered, never taken (stranded)
        deadline = time.time() + 10.0
        with pf._lock:
            while 0 not in pf._buffers and time.time() < deadline:
                pf._lock.wait(timeout=0.1)
        pf.schedule(1)
        data = pf.take(1)                  # must not hang behind seg 0
        np.testing.assert_array_equal(
            data[store.segment_names(1)[0]],
            store.read_segment(1)[store.segment_names(1)[0]])
        assert pf.forced_drops >= 1        # the stranded copy was evicted
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# allocation-free reads: reusable-buffer path + engine recycling
# ---------------------------------------------------------------------------
def test_read_segment_into_reused_buffers(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=4), 2)
    first = store.read_segment(0, window=True)
    bufs = list(first.values())
    again = store.read_segment(1, window=True, out=bufs)
    for name, arr in again.items():
        assert any(arr is b for b in bufs)     # filled in place, not fresh
        np.testing.assert_array_equal(arr, store.read_segment(1)[name])
    # mismatched buffers fall back to allocation, never corrupt
    bad = [np.zeros((1,), np.float32)] * len(bufs)
    ok = store.read_segment(0, window=True, out=bad)
    for name, arr in ok.items():
        np.testing.assert_array_equal(arr, store.read_segment(0)[name])


def test_engine_recycles_evicted_buffers(tmp_path):
    from repro.offload.engine import _host_to_device_copies
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=8), 8)
    if not _host_to_device_copies(store):
        pytest.skip("backend zero-copies host buffers; pool disables itself")
    eng = OffloadEngine(store, max_resident=2, prefetch=True)
    eng.prefetch(0)
    for seg in range(8):
        eng.prefetch(seg + 1)
        data = eng.acquire(seg)
        for name, arr in data.items():
            np.testing.assert_array_equal(arr, store.read_segment(
                seg, window=True)[name])
    s = eng.stats()
    eng.close()
    assert s["buffer_reuses"] > 0          # steady state stopped allocating
    assert s["forced_drops"] == 0


def test_pool_survives_emptied_signature(tmp_path):
    """A pooled read that empties a signature's free-list must not leave a
    key whose later eviction crashes ``recycle`` (regression: IndexError
    'pop from empty list' on the reader/writer thread with mixed-geometry
    stores — head + block segments — whenever pooling is enabled)."""
    groups = ([[("head", np.arange(6, dtype=np.float32).reshape(3, 2))]]
              + [[(f"b{i}", np.full((5, 4), float(i), np.float32))]
                 for i in range(4)])
    store = SegmentStore.create(str(tmp_path / "s"), groups, 5)
    pf = Prefetcher(store, depth=2)
    if not pf._pooling:
        pf.close()
        pytest.skip("backend zero-copies host buffers; pool disables itself")
    try:
        # seed the pool with one block-geometry set, then drain it via a
        # pooled read: the emptied signature must not linger in the pool
        pf.recycle(1, store.read_segment(1, window=True))
        drained = pf._read(2)
        assert pf.buffer_reuses == 1
        # now push head-geometry sets past the global bound so the evictor
        # walks from the pool front — where the emptied key used to sit
        for _ in range(pf._depth + 2):
            pf.recycle(0, store.read_segment(0, window=True))
        pf.recycle(2, drained)
        with pf._lock:
            assert all(pf._pool.values())      # no empty free-lists linger
            assert pf._pool_sets == sum(len(v) for v in pf._pool.values())
    finally:
        pf.close()


def test_take_drops_at_most_one_stranded_prefetch(tmp_path):
    """Waiting on a deep-queued segment must cost at most ONE forced drop:
    take() front-runs the queue instead of bleeding every earlier prefetch
    back to flash re-reads (regression: one drop per wakeup)."""
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=5), 5)
    pf = Prefetcher(store, depth=1)
    try:
        for seg in range(4):
            pf.schedule(seg)
        deadline = time.time() + 10.0
        with pf._lock:                   # slot fills with 0; reader blocks
            while 0 not in pf._buffers and time.time() < deadline:
                pf._lock.wait(timeout=0.1)
        data = pf.take(3)                # back of the queue
        name = store.segment_names(3)[0]
        np.testing.assert_array_equal(
            data[name], store.read_segment(3, window=True)[name])
        assert pf.forced_drops == 1      # exactly one, not one per wakeup
    finally:
        pf.close()


def test_writer_recycle_failure_surfaces(tmp_path):
    """An exception in the writer's recycle hook must land in _error and
    surface on the next barrier — not silently kill the thread and leave
    submit()/barrier() deadlocked (regression)."""
    from repro.offload.engine import AsyncWriter
    store = SegmentStore.create(str(tmp_path / "s"), _groups(n=2), 2)

    def bad_recycle(seg, data):
        raise RuntimeError("recycle boom")

    w = AsyncWriter(store, max_pending=1, recycle=bad_recycle)
    try:
        name = store.segment_names(0)[0]
        w.submit(0, {name: np.ones(store.record(name).shape, np.float32)})
        deadline = time.time() + 10.0
        while w._error is None and time.time() < deadline:
            time.sleep(0.01)
        assert w._error is not None
        assert w._thread.is_alive()      # thread survives to keep draining
        with pytest.raises(RuntimeError, match="write-back failed"):
            w.barrier()
    finally:
        w._error = None
        w.close()


# ---------------------------------------------------------------------------
# tentpole: async write-back value transparency
# ---------------------------------------------------------------------------
def test_async_writeback_eviction_and_steal(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    eng = OffloadEngine(store, max_resident=1, prefetch=False,
                        async_writeback=True)
    d0 = eng.acquire(0)
    name = next(iter(d0))
    d0[name][...] = 7.5
    eng.mark_dirty(0)
    eng.acquire(1)                 # evicts 0 into the background writer
    # re-acquiring immediately must hand the bytes back (write hit), never
    # a stale flash read
    d0b = eng.acquire(0)
    np.testing.assert_array_equal(
        d0b[name], np.full(d0b[name].shape, 7.5, np.float32))
    eng.acquire(2)                 # evict again; let it land via close()
    eng.close()
    assert eng.stats()["write_hits"] >= 1
    fresh = SegmentStore.open(store.directory).read_segment(0)
    np.testing.assert_array_equal(
        fresh[name], np.full(fresh[name].shape, 7.5, np.float32))


class _SlowWrites:
    """SegmentStore proxy that delays background writes — widens the race
    a missing flush barrier would lose."""

    def __init__(self, store, delay=0.2):
        self._store = store
        self._delay = delay

    def __getattr__(self, name):
        return getattr(self._store, name)

    def pwrite_segment(self, seg, named, sync=False):
        time.sleep(self._delay)
        return self._store.pwrite_segment(seg, named, sync=sync)


def test_stolen_segment_not_counted_as_written(tmp_path):
    """A segment stolen back out of the write queue never reached flash and
    must not inflate bytes_written (regression: counted at submit time)."""
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    eng = OffloadEngine(_SlowWrites(store), max_resident=1, prefetch=False,
                        async_writeback=True)
    d0 = eng.acquire(0)
    d0[store.segment_names(0)[0]][...] = 1.0
    eng.mark_dirty(0)
    eng.acquire(1)             # evict 0: the writer starts its slow write
    eng.mark_dirty(1)
    eng.acquire(2)             # evict 1: queued behind the slow write of 0
    eng.acquire(1)             # steal 1 back — its bytes never landed
    eng.close()                # flush writes still-dirty 1 inline
    s = eng.stats()
    assert s["write_hits"] >= 1
    assert s["bytes_written"] == store.seg_nbytes[0] + store.seg_nbytes[1]


def test_flush_barrier_fences_writes_before_snapshot(tmp_path):
    store = SegmentStore.create(str(tmp_path / "s"), _groups(), 3)
    slow = _SlowWrites(store)
    eng = OffloadEngine(slow, max_resident=1, prefetch=False,
                        async_writeback=True)
    name0 = store.segment_names(0)[0]
    d0 = eng.acquire(0)
    d0[name0][...] = 3.25
    eng.mark_dirty(0)
    eng.acquire(1)                 # eviction queues a *slow* background write
    eng.flush()                    # barrier: must wait for it to land
    snap = store.snapshot(str(tmp_path / "snap"))
    got = SegmentStore.open(snap).read_segment(0)[name0]
    np.testing.assert_array_equal(got, np.full(got.shape, 3.25, np.float32))
    eng.close()


def test_offload_state_snapshot_with_async_writer(tmp_path):
    params = {"w1": jax.random.normal(jax.random.PRNGKey(0), (16, 8)),
              "b": jnp.zeros((8,))}
    state = {"params": params, "opt": adamw_init(params),
             "step": jnp.zeros((), jnp.int32)}
    ost = OffloadedTrainState.create(state, str(tmp_path / "o"), 3,
                                     max_resident=1, async_writeback=True)
    grads = jax.tree.map(jnp.ones_like, params)
    p1 = ost.apply_update(grads, lr=1e-2)
    snap = ost.snapshot(str(tmp_path / "snap"))      # flush barrier inside
    re = OffloadedTrainState.open(snap, params)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 jax.device_get(p1), jax.device_get(re.materialize_params()))
    re.close()
    ost.close()


# ---------------------------------------------------------------------------
# tentpole: async pipeline bit-determinism + equivalence (dense + ssm)
# ---------------------------------------------------------------------------
def _base(steps):
    return dict(global_batch=2, seq_len=16, learning_rate=1e-4,
                schedule="constant", warmup_steps=1,
                compute_dtype="float32", total_steps=steps)


@pytest.mark.parametrize("arch", ["gpt2_124m", "mamba2_130m"])
def test_async_writeback_bit_matches_sync(arch, tmp_path):
    """Deferring writes must not change a single bit of the training
    trajectory: the window stays authoritative and steals hand queued
    bytes straight back."""
    cfg = configs.get_smoke(arch)
    losses = {}
    for mode, async_wb in (("sync", False), ("async", True)):
        t = TrainConfig(**_base(6), offload_stream_params=True,
                        offload_async_writeback=async_wb,
                        offload_dir=str(tmp_path / mode))
        _, obs = train_loop(cfg, t, out_dir=None, print_fn=None)
        losses[mode] = [r["loss"] for r in obs.rows]
    np.testing.assert_array_equal(losses["sync"], losses["async"])


@pytest.mark.parametrize("arch", ["gpt2_124m", "mamba2_130m"])
def test_async_resume_bit_deterministic(arch, tmp_path):
    """Interrupt + resume under async write-back replays the exact straight
    run (checkpoints hardlink behind the flush barrier)."""
    cfg = configs.get_smoke(arch)
    t_straight = TrainConfig(**_base(6), offload_stream_params=True,
                             offload_dir=str(tmp_path / "a"))
    _, oA = train_loop(cfg, t_straight, out_dir=None, print_fn=None)
    out = str(tmp_path / "run")
    tB1 = TrainConfig(**_base(3), offload_stream_params=True,
                      checkpoint_every=3)
    _, oB1 = train_loop(cfg, tB1, out_dir=out, print_fn=None)
    tB2 = TrainConfig(**_base(6), offload_stream_params=True,
                      checkpoint_every=3)
    _, oB2 = train_loop(cfg, tB2, out_dir=out, print_fn=None)
    assert oB2.rows[0]["step"] == 3
    np.testing.assert_array_equal(
        [r["loss"] for r in oA.rows],
        [r["loss"] for r in oB1.rows] + [r["loss"] for r in oB2.rows])


def test_staging_loss_matches_non_staged_sync_path(tmp_path):
    """The staged step must track the non-staged synchronous-write
    streamed path <= 1e-5 over 10 steps (deferred syncs are unconditional
    and present on both sides; the tolerance covers the staged path's
    device-array reuse ordering)."""
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-4,
                total_steps=10, warmup_steps=1, compute_dtype="float32")
    _, obs_pre = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_staging=False,
                         offload_async_writeback=False,
                         offload_dir=str(tmp_path / "pre")),
        out_dir=None, print_fn=None)
    _, obs_pipe = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_dir=str(tmp_path / "pipe")),
        out_dir=None, print_fn=None)
    np.testing.assert_allclose([r["loss"] for r in obs_pre.rows],
                               [r["loss"] for r in obs_pipe.rows], atol=1e-5)


def test_staging_lora_loss_matches_unstaged(tmp_path):
    cfg = configs.get_smoke("gpt2_124m")
    base = dict(global_batch=4, seq_len=32, learning_rate=1e-4,
                total_steps=6, warmup_steps=1, compute_dtype="float32",
                lora_rank=4)
    _, obs_pre = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_staging=False,
                         offload_dir=str(tmp_path / "pre")),
        out_dir=None, print_fn=None)
    _, obs_pipe = train_loop(
        cfg, TrainConfig(**base, offload_stream_params=True,
                         offload_dir=str(tmp_path / "pipe")),
        out_dir=None, print_fn=None)
    np.testing.assert_allclose([r["loss"] for r in obs_pre.rows],
                               [r["loss"] for r in obs_pipe.rows], atol=1e-5)
